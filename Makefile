# One-word entry points for the verify / bench / lint loops.
#
#   make test        tier-1 suite (the invocation ROADMAP.md pins)
#   make test-mesh   multi-device suites under 4 forced host devices
#   make bench       out-of-core + mesh-farm + polish + CV-grid + disk-tier
#                    curves -> BENCH_streaming.json + BENCH_stage2_stream.json
#                    + BENCH_stage2_mesh.json + BENCH_polish.json +
#                    BENCH_cv_grid.json + BENCH_disk_stream.json
#   make bench-smoke same suites at smoke sizes (fast CI loop) + the
#                    observability smoke (trace coverage / no-op / overhead)
#   make trace-smoke just the observability smoke -> /tmp/trace_smoke.json
#   make bench-all   every benchmark suite (paper tables + streaming)
#   make lint        byte-compile + import smoke over all python trees
#
# The container is CPU-only; Pallas kernels run with interpret=True there and
# compile to Mosaic on TPU — same commands either way.

PY       ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-mesh bench bench-smoke bench-all trace-smoke lint

test:
	$(PY) -m pytest -x -q

# The subprocess helpers inside these files force their own child device
# counts; the env var here additionally multi-devices the in-process parts.
# test_shards.py rides along: the shard chaos suite (torn writes, bit-flips,
# IO faults) includes 2-device farm parity from a shard-backed G.
test-mesh:
	XLA_FLAGS=--xla_force_host_platform_device_count=4 \
	$(PY) -m pytest -x -q tests/test_stage2_mesh.py tests/test_block_cache.py \
	tests/test_resilience.py tests/test_shards.py

bench:
	$(PY) -m benchmarks.run streaming stage2 stage2_mesh polish table3 disk

# smoke-sized records must not clobber the committed BENCH_*.json trajectory
bench-smoke:
	BENCH_SMOKE=1 \
	BENCH_STREAMING_JSON=/tmp/BENCH_streaming.smoke.json \
	BENCH_STAGE2_STREAM_JSON=/tmp/BENCH_stage2_stream.smoke.json \
	BENCH_STAGE2_MESH_JSON=/tmp/BENCH_stage2_mesh.smoke.json \
	BENCH_POLISH_JSON=/tmp/BENCH_polish.smoke.json \
	BENCH_CV_GRID_JSON=/tmp/BENCH_cv_grid.smoke.json \
	BENCH_DISK_STREAM_JSON=/tmp/BENCH_disk_stream.smoke.json \
	$(PY) -m benchmarks.run streaming stage2 stage2_mesh polish table3 \
	disk trace_smoke

# streamed fit under a Tracer: asserts >=1 span per core pipeline category
# in the exported Chrome-trace JSON, zero events on the disabled path, and
# bounded NULL-tracer overhead
trace-smoke:
	$(PY) -m benchmarks.run trace_smoke

bench-all:
	$(PY) -m benchmarks.run

lint:
	$(PY) -m compileall -q src tests benchmarks examples
	$(PY) -c "import repro, repro.core, repro.kernels, repro.launch, \
	repro.models, repro.baselines, repro.data, repro.analysis"
