# One-word entry points for the verify / bench / lint loops.
#
#   make test        tier-1 suite (the invocation ROADMAP.md pins)
#   make bench       stage-1 streaming scaling curve -> BENCH_streaming.json
#   make bench-all   every benchmark suite (paper tables + streaming)
#   make lint        byte-compile + import smoke over all python trees
#
# The container is CPU-only; Pallas kernels run with interpret=True there and
# compile to Mosaic on TPU — same commands either way.

PY       ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test bench bench-all lint

test:
	$(PY) -m pytest -x -q

bench:
	$(PY) -m benchmarks.run streaming

bench-all:
	$(PY) -m benchmarks.run

lint:
	$(PY) -m compileall -q src tests benchmarks examples
	$(PY) -c "import repro, repro.core, repro.kernels, repro.launch, \
	repro.models, repro.baselines, repro.data, repro.analysis"
