"""Hyperparameter tuning the paper's way: grid search + k-fold CV with
stage-1 reuse and warm starts over the C grid (paper sec. 4 / Table 3).

    PYTHONPATH=src python examples/grid_search_cv.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core import KernelParams, LPDSVM, SolverConfig, grid_search
from repro.data import make_multiclass, train_test_split


def main():
    x, y = make_multiclass(2500, p=12, n_classes=5, seed=1)
    xtr, ytr, xte, yte = train_test_split(x, y, 0.25)

    gammas = [0.02, 0.06, 0.18]
    Cs = [1.0, 4.0, 16.0]
    res = grid_search(xtr, ytr, gammas, Cs, budget=300, folds=3,
                      config=SolverConfig(tol=1e-2, max_epochs=800))

    print("CV error surface (rows=gamma, cols=C):")
    for gi, gamma in enumerate(gammas):
        row = "  ".join(f"{res.errors[gi, ci]:.3f}" for ci in range(len(Cs)))
        print(f"  gamma={gamma:<6g} {row}")
    print(f"best: gamma={res.best_gamma}, C={res.best_C} "
          f"(cv err {res.best_error:.4f})")
    print(f"binary SVMs solved: {res.n_binary_solved} "
          f"(stage1 ran {len(gammas)}x, reused {res.n_binary_solved}x)")
    print(f"stage1 {res.stage1_seconds:.2f}s, stage2 {res.stage2_seconds:.2f}s")

    final = LPDSVM(KernelParams("rbf", gamma=res.best_gamma), C=res.best_C,
                   budget=300, tol=1e-3)
    final.fit(xtr, ytr)
    print(f"refit test error: {final.error(xte, yte):.4f}")


if __name__ == "__main__":
    main()
