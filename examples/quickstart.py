"""Quickstart: train an LPD-SVM binary classifier in a few lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import KernelParams, LPDSVM, median_gamma
from repro.data import make_two_spirals, train_test_split


def main():
    # the two-spirals problem: hopeless for a linear model, easy for RBF
    x, y = make_two_spirals(3000, noise=0.05)
    xtr, ytr, xte, yte = train_test_split(x, y, test_frac=0.3)

    # median-distance heuristic as the gamma baseline; the spirals' decision
    # boundary is much finer than the global point-cloud scale, so sharpen it
    gamma = 32.0 * median_gamma(xtr)

    svm = LPDSVM(
        kernel=KernelParams("rbf", gamma=gamma),
        C=32.0,
        budget=400,        # Nystrom landmarks (stage 1)
        tol=1e-2,          # stage-2 KKT stopping criterion
        polish=True,       # coarse-to-fine warm-started stage 2
    )
    svm.fit(xtr, ytr)

    print(f"stage 1 (factor G): {svm.stats.stage1_seconds:.2f}s "
          f"(effective rank {svm.stats.effective_rank})")
    print(f"stage 2 (dual CA) : {svm.stats.stage2_seconds:.2f}s "
          f"({int(svm.stats.epochs.max())} epochs max)")
    print(f"train error: {svm.error(xtr, ytr):.4f}")
    print(f"test  error: {svm.error(xte, yte):.4f}")
    assert svm.error(xte, yte) < 0.1
    print("OK")


if __name__ == "__main__":
    main()
