"""Train a ~20M-param LM (reduced qwen3 family) for a few hundred steps —
the training-loop end-to-end driver over the framework's data pipeline,
optimizer, and sharded train step.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="qwen3-0.6b")
    args = ap.parse_args()
    losses = train(args.arch, reduced=True, steps=args.steps, batch=8,
                   seq=128, lr=1e-3, log_every=25)
    assert losses[-1] < losses[0] * 0.8, "loss must decrease"
    print("OK")


if __name__ == "__main__":
    main()
