"""Batched greedy decoding with KV caches / SSM states (serving example).

Runs three architecture families (dense GQA, attention-free RWKV6, hybrid
Jamba) through the same serve_step API.

    PYTHONPATH=src python examples/serve_decode.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import serve


def main():
    for arch in ("tinyllama-1.1b", "rwkv6-1.6b", "jamba-v0.1-52b"):
        serve(arch, reduced=True, batch=2, prompt_len=16, gen=16)
    print("OK")


if __name__ == "__main__":
    main()
