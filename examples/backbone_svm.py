"""End-to-end paper scenario: deep-backbone features -> LPD-SVM head.

The paper's ImageNet experiment extracts VGG-16 activations and trains a
1000-class one-vs-one SVM on them.  Here a reduced assigned architecture
(qwen3 family) embeds synthetic class-conditioned token sequences, and
LPD-SVM trains the multi-class large-margin head.

    PYTHONPATH=src python examples/backbone_svm.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train_svm import main

if __name__ == "__main__":
    sys.argv = [sys.argv[0], "--arch", "qwen3-0.6b", "--classes", "6",
                "--n", "1500", "--seq", "48", "--budget", "200"]
    err = main()
    assert err is None or err < 0.5
