"""Primal mini-batch SGD baseline (Pegasos / EigenPro-like).

The paper argues (sec. 2, citing LIBLINEAR) that "primal solvers find rough
approximate solutions quickly, while dual methods are the method of choice
when the large margin principle is taken serious".  This baseline lets the
benchmark reproduce that trade-off: SGD on the primal hinge objective over the
SAME whitened low-rank features (whitening = the EigenPro trick, which here
comes for free from stage 1).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kernel_fn import KernelParams
from repro.core.nystrom import compute_factor


@partial(jax.jit, static_argnames=("batch", "steps"))
def _sgd(G, y, lam, lr0, key, batch: int, steps: int):
    n, B = G.shape

    def step(carry, i):
        w, key = carry
        key, k = jax.random.split(key)
        idx = jax.random.randint(k, (batch,), 0, n)
        xb, yb = G[idx], y[idx]
        margins = yb * (xb @ w)
        active = (margins < 1.0).astype(jnp.float32)
        grad = lam * w - (active * yb) @ xb / batch
        lr = lr0 / (1.0 + 0.1 * i)                     # Pegasos-style decay
        return (w - lr * grad, key), None

    (w, _), _ = jax.lax.scan(step, (jnp.zeros((B,), jnp.float32), key),
                             jnp.arange(steps, dtype=jnp.float32))
    return w


class PrimalSGDSVM:
    def __init__(self, kernel: KernelParams, C: float = 1.0, budget: int = 500,
                 batch: int = 64, steps: int = 2000, lr0: float = 1.0, seed: int = 0):
        self.kernel, self.C = kernel, float(C)
        self.budget, self.batch, self.steps, self.lr0 = budget, batch, steps, lr0
        self.seed = seed

    def fit(self, x: np.ndarray, y: np.ndarray, factor=None):
        x = np.asarray(x, np.float32)
        self.classes_, labels = np.unique(np.asarray(y), return_inverse=True)
        if len(self.classes_) != 2:
            raise ValueError("binary only (benchmark baseline)")
        y_pm = jnp.asarray(np.where(labels == 0, 1.0, -1.0), jnp.float32)
        self.factor = factor or compute_factor(
            jnp.asarray(x), self.kernel, self.budget,
            key=jax.random.PRNGKey(self.seed))
        lam = 1.0 / (self.C * x.shape[0])
        self.w_ = _sgd(self.factor.G, y_pm, lam, self.lr0,
                       jax.random.PRNGKey(self.seed + 1), self.batch, self.steps)
        return self

    def decision_function(self, x: np.ndarray) -> np.ndarray:
        feats = self.factor.features(jnp.asarray(np.asarray(x, np.float32)))
        return np.asarray(feats @ self.w_)

    def predict(self, x: np.ndarray) -> np.ndarray:
        return self.classes_[(self.decision_function(x) <= 0).astype(int)]

    def error(self, x, y) -> float:
        return float(np.mean(self.predict(x) != np.asarray(y)))
