"""LLSVM-style baseline (Zhang et al., 2012) as characterized by the paper.

Low-rank linearization with the *design decisions the paper criticizes*:
  * training iterates over the data set ONLY ONCE, in chunks (default 50,000
    points per chunk);
  * within each chunk, a FIXED number of epochs (30) is performed "irrespective
    of the achieved solution accuracy" — no convergence check, no adaptive
    stopping ("It is of course easy to be fast if the job is not complete");
  * no shrinking, no warm starts.

Shares stage 1 (the Nyström factor) with LPD-SVM so the comparison isolates
the *solver* differences, exactly like Table 2's reading of the results.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kernel_fn import KernelParams
from repro.core.nystrom import compute_factor


@partial(jax.jit, static_argnames=("epochs",))
def _chunk_epochs(G_chunk, y_chunk, C, w, epochs: int):
    """`epochs` fixed coordinate-ascent passes over one chunk, no stopping."""
    q = jnp.maximum(jnp.sum(G_chunk ** 2, axis=-1), 1e-12)
    n = G_chunk.shape[0]
    alpha = jnp.zeros((n,), jnp.float32)

    def body(i, st):
        alpha, w = st
        row = G_chunk[i]
        g = 1.0 - y_chunk[i] * jnp.dot(w, row)
        a_new = jnp.clip(alpha[i] + g / q[i], 0.0, C)
        w = w + ((a_new - alpha[i]) * y_chunk[i]) * row
        return alpha.at[i].set(a_new), w

    def epoch(_, st):
        return jax.lax.fori_loop(0, n, body, st)

    alpha, w = jax.lax.fori_loop(0, epochs, epoch, (alpha, w))
    return alpha, w


class LLSVMStyle:
    def __init__(self, kernel: KernelParams, C: float = 1.0, budget: int = 100,
                 chunk_size: int = 50_000, epochs_per_chunk: int = 30, seed: int = 0):
        self.kernel, self.C = kernel, float(C)
        self.budget, self.chunk_size = budget, chunk_size
        self.epochs_per_chunk = epochs_per_chunk
        self.seed = seed

    def fit(self, x: np.ndarray, y: np.ndarray):
        x = np.asarray(x, np.float32)
        self.classes_, labels = np.unique(np.asarray(y), return_inverse=True)
        if len(self.classes_) != 2:
            raise ValueError("LLSVM is not applicable to data sets with more "
                             "than two classes (paper, Table 2 caption)")
        y_pm = np.where(labels == 0, 1.0, -1.0).astype(np.float32)
        self.factor = compute_factor(jnp.asarray(x), self.kernel, self.budget,
                                     key=jax.random.PRNGKey(self.seed))
        G = self.factor.G
        w = jnp.zeros((G.shape[1],), jnp.float32)
        for s in range(0, x.shape[0], self.chunk_size):   # single pass over data
            Gc = G[s:s + self.chunk_size]
            yc = jnp.asarray(y_pm[s:s + self.chunk_size])
            _, w = _chunk_epochs(Gc, yc, self.C, w, self.epochs_per_chunk)
        self.w_ = w
        return self

    def decision_function(self, x: np.ndarray) -> np.ndarray:
        feats = self.factor.features(jnp.asarray(np.asarray(x, np.float32)))
        return np.asarray(feats @ self.w_)

    def predict(self, x: np.ndarray) -> np.ndarray:
        return self.classes_[(self.decision_function(x) <= 0).astype(int)]

    def error(self, x, y) -> float:
        return float(np.mean(self.predict(x) != np.asarray(y)))
