"""Baselines the paper compares against (Table 2), reimplemented in JAX."""
from repro.baselines.exact_smo import ExactDualSVM
from repro.baselines.llsvm import LLSVMStyle
from repro.baselines.primal_sgd import PrimalSGDSVM

__all__ = ["ExactDualSVM", "LLSVMStyle", "PrimalSGDSVM"]
