"""Exact (non-approximate) dual SVM solver — the ThunderSVM/LIBSVM stand-in.

Dual coordinate ascent on the FULL precomputed kernel matrix Q (n x n).  This
is the "nearly exact" reference LPD-SVM is compared against in Table 2: same
optimization scheme, but iteration cost O(n) instead of O(B) and O(n^2) memory
instead of O(nB) — precisely the trade-off the paper's low-rank stage removes.
Only feasible for small/medium n (like ThunderSVM, it would OOM on ImageNet).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kernel_fn import KernelParams, gram


@partial(jax.jit, static_argnames=("tol", "max_epochs"))
def _solve_exact(K, y, C, alpha0, tol: float, max_epochs: int):
    """Coordinate ascent maintaining the full gradient vector (O(n)/step)."""
    n = y.shape[0]
    Q = (y[:, None] * y[None, :]) * K
    q_diag = jnp.maximum(jnp.diag(Q), 1e-12)
    grad0 = 1.0 - Q @ alpha0   # dD/dalpha

    def epoch(carry):
        alpha, grad, _, epoch_i = carry

        def body(i, st):
            alpha, grad, viol = st
            g = grad[i]
            at_lo = alpha[i] <= 0.0
            at_hi = alpha[i] >= C
            pg = jnp.where(at_lo, jnp.maximum(g, 0.0),
                           jnp.where(at_hi, jnp.minimum(g, 0.0), g))
            a_new = jnp.clip(alpha[i] + g / q_diag[i], 0.0, C)
            delta = a_new - alpha[i]
            grad = grad - delta * Q[i]
            alpha = alpha.at[i].set(a_new)
            return alpha, grad, jnp.maximum(viol, jnp.abs(pg))

        alpha, grad, viol = jax.lax.fori_loop(0, n, body, (alpha, grad, 0.0))
        return alpha, grad, viol, epoch_i + 1

    def cond(carry):
        _, _, viol, epoch_i = carry
        return jnp.logical_and(viol >= tol, epoch_i < max_epochs)

    alpha, grad, viol, epochs = jax.lax.while_loop(
        cond, epoch, (alpha0, grad0, jnp.float32(jnp.inf), jnp.int32(0)))
    return alpha, viol, epochs


class ExactDualSVM:
    """Binary or OVO-multiclass exact kernel SVM (full Q precomputation)."""

    def __init__(self, kernel: KernelParams, C: float = 1.0, tol: float = 1e-2,
                 max_epochs: int = 2000):
        self.kernel, self.C, self.tol, self.max_epochs = kernel, float(C), tol, max_epochs
        self.x_: Optional[np.ndarray] = None

    def fit(self, x: np.ndarray, y: np.ndarray):
        x = np.asarray(x, np.float32)
        self.classes_, labels = np.unique(np.asarray(y), return_inverse=True)
        self.x_ = x
        self.models_ = []  # (a, b, sel_idx, alpha, y_pm)
        import itertools
        for a, b in itertools.combinations(range(len(self.classes_)), 2):
            sel = np.where((labels == a) | (labels == b))[0]
            y_pm = jnp.asarray(np.where(labels[sel] == a, 1.0, -1.0), jnp.float32)
            K = gram(jnp.asarray(x[sel]), jnp.asarray(x[sel]), self.kernel)
            alpha0 = jnp.zeros((len(sel),), jnp.float32)
            alpha, viol, epochs = _solve_exact(K, y_pm, self.C, alpha0,
                                               self.tol, self.max_epochs)
            self.models_.append((a, b, sel, np.asarray(alpha), np.asarray(y_pm)))
        return self

    def decision_function(self, x: np.ndarray) -> np.ndarray:
        x = jnp.asarray(np.asarray(x, np.float32))
        cols = []
        for a, b, sel, alpha, y_pm in self.models_:
            K = gram(x, jnp.asarray(self.x_[sel]), self.kernel)
            cols.append(np.asarray(K @ jnp.asarray(alpha * y_pm)))
        return np.stack(cols, axis=1)

    def predict(self, x: np.ndarray) -> np.ndarray:
        d = self.decision_function(x)
        if len(self.classes_) == 2:
            return self.classes_[np.where(d[:, 0] > 0, 0, 1)]
        from repro.core.ovo import ovo_vote, class_pairs
        pred = ovo_vote(d, class_pairs(len(self.classes_)), len(self.classes_))
        return self.classes_[pred]

    def error(self, x, y) -> float:
        return float(np.mean(self.predict(x) != np.asarray(y)))
