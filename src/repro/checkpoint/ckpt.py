"""Flat-keyed msgpack checkpoints for arbitrary pytrees of jnp/np arrays.

Layout: <dir>/step_<n>.msgpack, each a map of "/"-joined key paths to
{dtype, shape, raw-bytes} triples.  Restores onto a template pytree so key
order / tree structure is validated on load.  Atomic via tmp-file rename.
"""
from __future__ import annotations

import os
import re
import tempfile
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _flatten(tree: Any):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = np.asarray(leaf)
    return out


def save_checkpoint(directory: str, step: int, tree: Any) -> str:
    os.makedirs(directory, exist_ok=True)
    payload = {
        k: {"dtype": str(v.dtype), "shape": list(v.shape), "data": v.tobytes()}
        for k, v in _flatten(tree).items()
    }
    path = os.path.join(directory, f"step_{step:08d}.msgpack")
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    with os.fdopen(fd, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
    os.replace(tmp, path)
    return path


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for fn in os.listdir(directory)
             if (m := re.match(r"step_(\d+)\.msgpack$", fn))]
    return max(steps) if steps else None


def load_checkpoint(directory: str, step: int, template: Any) -> Any:
    path = os.path.join(directory, f"step_{step:08d}.msgpack")
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    flat_t, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path_keys, leaf in flat_t:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path_keys)
        if key not in payload:
            raise KeyError(f"checkpoint missing {key!r}")
        rec = payload[key]
        arr = np.frombuffer(rec["data"], dtype=np.dtype(rec["dtype"])).reshape(rec["shape"])
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {np.shape(leaf)}")
        leaves.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(template), leaves)
