"""Compatibility shims over the jax API surface that moved between releases.

The repo targets the modern jax API (``jax.shard_map``, ``jax.set_mesh``,
``jax.sharding.AxisType``, ``check_vma=``); the container pins an older jax
where those live elsewhere (``jax.experimental.shard_map``, ``with mesh:``,
``check_rep=``) or do not exist at all.  Everything version-dependent funnels
through this module so call sites stay written against ONE surface:

    from repro.compat import AxisType, make_mesh, set_mesh, shard_map

On a new-enough jax these are straight re-exports; on the pinned jax they are
thin adapters with identical semantics for everything this repo uses.
"""
from __future__ import annotations

import contextlib
import enum
from typing import Any, Optional, Sequence

import jax

# --------------------------------------------------------------------- AxisType
try:  # jax >= 0.5-ish
    from jax.sharding import AxisType  # type: ignore[attr-defined]
    _HAS_AXIS_TYPE = True
except ImportError:  # pinned jax: meshes have no axis types; accept + ignore
    _HAS_AXIS_TYPE = False

    class AxisType(enum.Enum):  # type: ignore[no-redef]
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"


# --------------------------------------------------------------------- make_mesh
def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str],
              *, axis_types: Optional[Sequence[Any]] = None,
              devices=None) -> jax.sharding.Mesh:
    """``jax.make_mesh`` that tolerates ``axis_types`` on every jax version."""
    kwargs = {} if devices is None else {"devices": devices}
    if _HAS_AXIS_TYPE and axis_types is not None:
        try:
            return jax.make_mesh(axis_shapes, axis_names,
                                 axis_types=tuple(axis_types), **kwargs)
        except TypeError:
            pass  # AxisType exists but make_mesh predates the kwarg
    return jax.make_mesh(axis_shapes, axis_names, **kwargs)


# --------------------------------------------------------------------- set_mesh
if hasattr(jax, "set_mesh"):
    set_mesh = jax.set_mesh
else:
    @contextlib.contextmanager
    def set_mesh(mesh: jax.sharding.Mesh):  # type: ignore[no-redef]
        """Ambient-mesh scope: ``with mesh:`` plays ``jax.set_mesh`` on old jax.

        Entering the Mesh sets the resource env, which is what makes bare
        ``PartitionSpec`` in ``with_sharding_constraint`` resolve — the only
        ambient behaviour this repo relies on.
        """
        with mesh:
            yield mesh


# ------------------------------------------------- pallas TPU compiler params
def tpu_compiler_params(**kwargs):
    """``pltpu.CompilerParams`` across its ``TPUCompilerParams`` rename."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(**kwargs)


# ------------------------------------------------------------ get_abstract_mesh
def get_abstract_mesh():
    """Ambient mesh set by `set_mesh`, or None when no mesh scope is active.

    New jax returns an (possibly empty) AbstractMesh; old jax keeps the
    ambient mesh in the thread-local resource env that ``with mesh:`` fills.
    Callers must treat both None and an empty ``.shape`` as "no mesh".
    """
    if hasattr(jax.sharding, "get_abstract_mesh"):
        return jax.sharding.get_abstract_mesh()
    from jax._src import mesh as _mesh_lib
    m = _mesh_lib.thread_resources.env.physical_mesh
    return None if m.empty else m


# -------------------------------------------------------------------- shard_map
if hasattr(jax, "shard_map"):
    _shard_map_impl = jax.shard_map
    _REP_KWARG = "check_vma"
else:
    from jax.experimental.shard_map import shard_map as _shard_map_impl
    _REP_KWARG = "check_rep"


def shard_map(f=None, *, mesh, in_specs, out_specs, check_vma: bool = True,
              **kwargs):
    """``jax.shard_map`` signature, replication-check kwarg renamed as needed.

    Usable both as ``shard_map(f, mesh=..., ...)`` and as a decorator factory
    via ``functools.partial(shard_map, mesh=..., ...)``.
    """
    kwargs[_REP_KWARG] = check_vma
    if f is None:
        return lambda fn: _shard_map_impl(fn, mesh=mesh, in_specs=in_specs,
                                          out_specs=out_specs, **kwargs)
    return _shard_map_impl(f, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, **kwargs)
