"""Three-term roofline from the dry-run artifacts (TPU v5e targets).

    compute term    = HLO_FLOPs_per_device            / peak_FLOP/s  (197e12 bf16)
    memory term     = HLO_bytes_per_device            / HBM_bw       (819e9)
    collective term = weighted_collective_bytes/device / link_bw     (50e9)

The dry-run already reports *per-device* numbers (XLA compiles the SPMD
partition), loop-corrected via unrolled probes, so no further division by
chip count is needed.  MODEL_FLOPS uses the 6·N·D rule with N = active
params (MoE) and D = processed tokens; the ratio MODEL_FLOPS / HLO_FLOPS
shows how much of the compiled compute is "useful" (catches remat/recompute
and masked-attention waste).

Usage:
    PYTHONPATH=src python -m repro.analysis.roofline [--dir results/dryrun] \
        [--format md|csv]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List, Optional

from repro.configs import get_config
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.launch.specs import SHAPES


def model_flops_per_device(arch: str, shape_name: str, n_chips: int) -> float:
    """6·N_active·D (train: x3 for fwd+bwd via the standard 6ND; decode: 2ND)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        total = 6.0 * n_active * tokens
    elif shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        total = 2.0 * n_active * tokens
    else:  # decode: one token per sequence
        total = 2.0 * n_active * shape.global_batch
    return total / n_chips


def analyze_record(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok" or "flops" not in rec:
        return None
    n_chips = 512 if rec["mesh"] == "pod2x16x16" else 256
    flops = rec["flops"]
    bytes_hbm = rec["bytes_accessed"]
    bytes_coll = rec["collective_bytes"]
    t_compute = flops / PEAK_FLOPS_BF16
    t_memory = bytes_hbm / HBM_BW
    t_coll = bytes_coll / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops_per_device(rec["arch"], rec["shape"], n_chips)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "mode": rec["mode"],
        "compute_s": t_compute, "memory_s": t_memory, "collective_s": t_coll,
        "dominant": dominant,
        "model_flops_per_dev": mf,
        "useful_ratio": mf / flops if flops else 0.0,
        "hlo_flops": flops, "hlo_bytes": bytes_hbm,
        "collective_bytes": bytes_coll,
        "temp_gib": rec.get("memory", {}).get("temp_bytes", 0) / 2**30,
        "arg_gib": rec.get("memory", {}).get("argument_bytes", 0) / 2**30,
    }


def load_all(dirpath: str) -> List[Dict]:
    rows = []
    for fn in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        with open(fn) as f:
            rec = json.load(f)
        row = analyze_record(rec)
        if row:
            rows.append(row)
    return rows


def fmt_md(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s | "
           "dominant | useful | temp GiB |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']:.3e} | {r['memory_s']:.3e} "
            f"| {r['collective_s']:.3e} | **{r['dominant']}** "
            f"| {r['useful_ratio']:.2f} | {r['temp_gib']:.1f} |")
    return hdr + "\n".join(lines)


def fmt_csv(rows: List[Dict]) -> str:
    cols = ["arch", "shape", "mesh", "compute_s", "memory_s", "collective_s",
            "dominant", "useful_ratio", "hlo_flops", "hlo_bytes",
            "collective_bytes", "temp_gib", "arg_gib"]
    out = [",".join(cols)]
    for r in rows:
        out.append(",".join(str(r[c]) for c in cols))
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    default_dir = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                               "results", "dryrun")
    ap.add_argument("--dir", default=os.path.abspath(default_dir))
    ap.add_argument("--format", choices=["md", "csv"], default="md")
    args = ap.parse_args()
    rows = load_all(args.dir)
    print(fmt_md(rows) if args.format == "md" else fmt_csv(rows))


if __name__ == "__main__":
    main()
