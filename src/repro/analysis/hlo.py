"""Parse compiled (post-SPMD) HLO text for collective traffic.

`cost_analysis()` does not report collective bytes, so we sum the operand /
result sizes of every collective op in the HLO and weight them by the
per-device link-traffic factor of a ring implementation:

    op                   counted tensor      weight (bytes on the wire/device)
    all-reduce           result              2 (reduce-scatter + all-gather)
    all-gather           result              1 (receives (n-1)/n ~ 1 x result)
    reduce-scatter       largest operand     1
    all-to-all           result              1 ((n-1)/n of the buffer moves)
    collective-permute   result              1

Ops inside while-loop bodies appear once in the text; the dry-run avoids the
trip-count problem by measuring UNROLLED probe lowerings (see launch/dryrun).
"""
from __future__ import annotations

import re
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

# e.g.  %all-gather.3 = bf16[8,1024]{1,0} all-gather(...)
#       ROOT %tuple ... (f32[4], s32[2]) all-to-all(...)
_OP_RE = re.compile(
    r"= *((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\][^ ]*)) *"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> Dict:
    """Aggregate per-kind collective bytes (per device, shard shapes)."""
    by_kind = {k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        b = _shape_bytes(shape_str)
        by_kind[kind]["count"] += 1
        by_kind[kind]["bytes"] += b
    weighted = sum(_COLLECTIVES[k] * v["bytes"] for k, v in by_kind.items())
    return {
        "by_kind": by_kind,
        "raw_bytes": sum(v["bytes"] for v in by_kind.values()),
        "weighted_bytes": float(weighted),
        "total_count": sum(v["count"] for v in by_kind.values()),
    }
