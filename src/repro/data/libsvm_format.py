"""LIBSVM sparse text format reader/writer.

The paper's data sets ship in this format ("available from the LIBSVM
website"); sparse support matters because "also ThunderSVM converts data to a
dense format ... In our solver, we implemented all kernel operations based on
efficient sparse matrix products".  On TPU the MXU wants dense tiles, so we
ingest sparse and densify per block (DESIGN.md, changed assumption #1); a CSR
triple is kept so the densify-block-by-block path never materializes the full
dense matrix for wide data.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np


@dataclasses.dataclass
class CSRData:
    indptr: np.ndarray    # (n+1,) int64
    indices: np.ndarray   # (nnz,) int32
    values: np.ndarray    # (nnz,) float32
    n_features: int
    labels: np.ndarray    # (n,) float64 (raw labels as written)

    @property
    def n(self) -> int:
        return len(self.indptr) - 1

    def densify(self, start: int = 0, stop: Optional[int] = None) -> np.ndarray:
        stop = self.n if stop is None else min(stop, self.n)
        out = np.zeros((stop - start, self.n_features), dtype=np.float32)
        for r in range(start, stop):
            lo, hi = self.indptr[r], self.indptr[r + 1]
            out[r - start, self.indices[lo:hi]] = self.values[lo:hi]
        return out


def read_libsvm(path: str, n_features: Optional[int] = None) -> CSRData:
    """Parse `label idx:val idx:val ...` lines (1-based indices)."""
    labels, indptr, indices, values = [], [0], [], []
    max_idx = 0
    with open(path, "r") as f:
        for line in f:
            line = line.strip()
            if not line or line.startswith("#"):
                continue
            parts = line.split()
            labels.append(float(parts[0]))
            for tok in parts[1:]:
                i, v = tok.split(":")
                idx = int(i) - 1
                max_idx = max(max_idx, idx + 1)
                indices.append(idx)
                values.append(float(v))
            indptr.append(len(indices))
    nf = n_features if n_features is not None else max_idx
    return CSRData(
        indptr=np.asarray(indptr, np.int64),
        indices=np.asarray(indices, np.int32),
        values=np.asarray(values, np.float32),
        n_features=nf,
        labels=np.asarray(labels),
    )


def write_libsvm(path: str, x: np.ndarray, y: np.ndarray,
                 drop_zeros: bool = True) -> None:
    with open(path, "w") as f:
        for row, label in zip(np.asarray(x), np.asarray(y)):
            toks = [f"{label:g}"]
            for j, v in enumerate(row):
                if not drop_zeros or v != 0.0:
                    toks.append(f"{j + 1}:{v:g}")
            f.write(" ".join(toks) + "\n")
