"""LIBSVM sparse text format reader/writer.

The paper's data sets ship in this format ("available from the LIBSVM
website"); sparse support matters because "also ThunderSVM converts data to a
dense format ... In our solver, we implemented all kernel operations based on
efficient sparse matrix products".  On TPU the MXU wants dense tiles, so we
ingest sparse and densify per block (DESIGN.md, changed assumption #1); a CSR
triple is kept so the densify-block-by-block path never materializes the full
dense matrix for wide data.

Two out-of-core ingest paths feed `core.streaming.stream_factor_blocks`:

  * `CSRData.iter_dense_blocks(rows)` — the CSR triple fits host RAM and
    blocks are densified on their way to the device;
  * `read_libsvm_blocks(path, rows, n_features)` — even the CSR does not:
    the file is parsed chunkwise and each (dense rows, labels) block is
    yielded without any global structure being built.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Tuple

import numpy as np


def _scatter_dense(n_rows: int, n_features: int, indptr: np.ndarray,
                   indices: np.ndarray, values: np.ndarray) -> np.ndarray:
    """One flat scatter instead of a per-row Python loop (ingest hot path)."""
    out = np.zeros((n_rows, n_features), dtype=np.float32)
    if len(indices):
        if indices.max() >= n_features:
            raise ValueError(
                f"feature index {int(indices.max()) + 1} exceeds "
                f"n_features={n_features}")
        rows = np.repeat(np.arange(n_rows, dtype=np.int64),
                         np.diff(indptr).astype(np.int64))
        out.ravel()[rows * n_features + indices] = values
    return out


@dataclasses.dataclass
class CSRData:
    indptr: np.ndarray    # (n+1,) int64
    indices: np.ndarray   # (nnz,) int32
    values: np.ndarray    # (nnz,) float32
    n_features: int
    labels: np.ndarray    # (n,) float64 (raw labels as written)

    @property
    def n(self) -> int:
        return len(self.indptr) - 1

    def densify(self, start: int = 0, stop: Optional[int] = None) -> np.ndarray:
        stop = self.n if stop is None else min(stop, self.n)
        lo, hi = int(self.indptr[start]), int(self.indptr[stop])
        return _scatter_dense(stop - start, self.n_features,
                              self.indptr[start:stop + 1] - lo,
                              self.indices[lo:hi], self.values[lo:hi])

    def densify_rows(self, rows) -> np.ndarray:
        """Gather arbitrary rows (any order) to dense — landmark selection."""
        rows = np.asarray(rows)
        out = np.zeros((len(rows), self.n_features), dtype=np.float32)
        for i, r in enumerate(rows):
            lo, hi = int(self.indptr[r]), int(self.indptr[r + 1])
            out[i, self.indices[lo:hi]] = self.values[lo:hi]
        return out

    def iter_dense_blocks(self, rows: int) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        """Yield (dense rows, labels) blocks of at most ``rows`` rows; feeds
        `core.streaming.stream_factor_blocks` so stage 1 never materialises
        the full dense (n, p) matrix."""
        if rows < 1:
            raise ValueError("rows must be positive")
        for s in range(0, self.n, rows):
            e = min(s + rows, self.n)
            yield self.densify(s, e), self.labels[s:e]


class BadRowError(ValueError):
    """A malformed or non-finite LIBSVM line under ``on_bad_row="raise"``."""


@dataclasses.dataclass
class IngestStats:
    """Row accounting for validated ingest (filled in place when passed to a
    reader): streamed training jobs surface how much input was dropped instead
    of silently folding NaN rows into G."""

    rows_read: int = 0
    rows_skipped: int = 0


# _parse_line outcome codes
_BLANK, _DATA, _SKIPPED = 0, 1, 2


def _parse_line(line: str, lineno: int, labels, indices, values,
                on_bad_row: str = "raise") -> Tuple[int, int]:
    """Parse one `label idx:val ...` line into the accumulators; returns
    (outcome code, max feature index seen + 1).

    Validation guards the streamed ingest paths: malformed tokens, 0-based
    indices, and non-finite labels/values either raise `BadRowError`
    (``on_bad_row="raise"``, default) or drop the ROW atomically
    (``"skip"`` — partially-parsed values are rolled back so a bad tail
    never leaves a half-row in the CSR accumulators).
    """
    line = line.strip()
    if not line or line.startswith("#"):
        return _BLANK, 0
    n0 = len(indices)
    parts = line.split()
    try:
        lab = float(parts[0])
        if not np.isfinite(lab):
            raise ValueError(f"non-finite label {parts[0]!r}")
        hi = 0
        for tok in parts[1:]:
            i, sep, v = tok.partition(":")
            if not sep:
                raise ValueError(f"malformed token {tok!r} (expected idx:val)")
            idx = int(i) - 1
            if idx < 0:
                raise ValueError(f"feature index {i!r} is not 1-based")
            val = float(v)
            if not np.isfinite(val):
                raise ValueError(f"non-finite value in token {tok!r}")
            hi = max(hi, idx + 1)
            indices.append(idx)
            values.append(val)
    except ValueError as exc:
        del indices[n0:], values[n0:]   # atomic row rollback
        if on_bad_row == "skip":
            return _SKIPPED, 0
        raise BadRowError(f"line {lineno}: {exc}") from None
    labels.append(lab)
    return _DATA, hi


def _check_bad_row_mode(on_bad_row: str) -> None:
    if on_bad_row not in ("raise", "skip"):
        raise ValueError(f"on_bad_row must be 'raise' or 'skip', "
                         f"got {on_bad_row!r}")


def read_libsvm(path: str, n_features: Optional[int] = None,
                on_bad_row: str = "raise",
                stats: Optional[IngestStats] = None) -> CSRData:
    """Parse `label idx:val idx:val ...` lines (1-based indices).

    ``on_bad_row``: "raise" (default) raises `BadRowError` naming the line;
    "skip" drops bad rows and counts them in ``stats.rows_skipped`` (pass an
    `IngestStats` to read the counter back).
    """
    _check_bad_row_mode(on_bad_row)
    st = stats if stats is not None else IngestStats()
    labels, indptr, indices, values = [], [0], [], []
    max_idx = 0
    with open(path, "r") as f:
        for lineno, line in enumerate(f, 1):
            out, hi = _parse_line(line, lineno, labels, indices, values,
                                  on_bad_row)
            if out == _DATA:
                st.rows_read += 1
                max_idx = max(max_idx, hi)
                indptr.append(len(indices))
            elif out == _SKIPPED:
                st.rows_skipped += 1
    nf = n_features if n_features is not None else max_idx
    return CSRData(
        indptr=np.asarray(indptr, np.int64),
        indices=np.asarray(indices, np.int32),
        values=np.asarray(values, np.float32),
        n_features=nf,
        labels=np.asarray(labels),
    )


def read_libsvm_blocks(path: str, rows: int, n_features: int,
                       on_bad_row: str = "raise",
                       stats: Optional[IngestStats] = None,
                       ) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    """Stream a LIBSVM file as (dense rows, labels) blocks of ``rows`` rows.

    Nothing global is ever built — datasets larger than host RAM stream
    through stage 1 directly.  ``n_features`` must be given (the global
    maximum index is unknown until EOF in a single pass).  Validation is the
    same as `read_libsvm`: with ``on_bad_row="skip"`` a bad line shrinks the
    block instead of poisoning G with NaN rows, and ``stats.rows_skipped``
    keeps the count.
    """
    if rows < 1:
        raise ValueError("rows must be positive")
    _check_bad_row_mode(on_bad_row)
    st = stats if stats is not None else IngestStats()

    def emit(labels, indptr, indices, values):
        dense = _scatter_dense(len(labels), n_features,
                               np.asarray(indptr, np.int64),
                               np.asarray(indices, np.int32),
                               np.asarray(values, np.float32))
        return dense, np.asarray(labels)

    labels, indptr, indices, values = [], [0], [], []
    with open(path, "r") as f:
        for lineno, line in enumerate(f, 1):
            out, _ = _parse_line(line, lineno, labels, indices, values,
                                 on_bad_row)
            if out == _DATA:
                st.rows_read += 1
                indptr.append(len(indices))
            elif out == _SKIPPED:
                st.rows_skipped += 1
            if len(labels) == rows:
                yield emit(labels, indptr, indices, values)
                labels, indptr, indices, values = [], [0], [], []
    if labels:
        yield emit(labels, indptr, indices, values)


def read_libsvm_rows_range(path: str, lo: int, hi: int, n_features: int,
                           on_bad_row: str = "raise",
                           ) -> Tuple[np.ndarray, np.ndarray]:
    """Parse ONLY data rows [lo, hi) (post-skip row coordinates) to dense.

    The shard-store rebuild path (`core.shards.attach_source_rebuilder`):
    when one shard fails its checksum, just that shard's row range is
    re-parsed from the source text and re-encoded — not the whole file.
    Row numbering matches the streamed ingest exactly: blank/comment lines
    don't count, and with ``on_bad_row="skip"`` neither do dropped rows, so
    row i here is row i of `read_libsvm_blocks` output.  Returns
    (dense (hi-lo, n_features) f32, labels (hi-lo,) f64).
    """
    _check_bad_row_mode(on_bad_row)
    if lo < 0 or hi < lo:
        raise ValueError(f"bad row range [{lo}, {hi})")
    labels, indptr, indices, values = [], [0], [], []
    seen = 0
    with open(path, "r") as f:
        for lineno, line in enumerate(f, 1):
            if seen >= hi:
                break
            out, _ = _parse_line(line, lineno, labels, indices, values,
                                 on_bad_row)
            if out != _DATA:
                continue
            seen += 1
            if seen <= lo:
                # Before the window: drop the parsed row again (cheaper than
                # special-casing _parse_line for a skip-ahead mode).
                del labels[:], indices[:], values[:]
                continue
            indptr.append(len(indices))
    if seen < hi:
        raise ValueError(f"row range [{lo}, {hi}) exceeds the {seen} data "
                         f"rows in {path}")
    dense = _scatter_dense(len(labels), n_features,
                           np.asarray(indptr, np.int64),
                           np.asarray(indices, np.int32),
                           np.asarray(values, np.float32))
    return dense, np.asarray(labels)


def count_libsvm_rows(path: str) -> int:
    """Cheap first pass: number of data rows (landmark sampling needs n)."""
    n = 0
    with open(path, "r") as f:
        for line in f:
            s = line.strip()
            if s and not s.startswith("#"):
                n += 1
    return n


def write_libsvm(path: str, x: np.ndarray, y: np.ndarray,
                 drop_zeros: bool = True) -> None:
    with open(path, "w") as f:
        for row, label in zip(np.asarray(x), np.asarray(y)):
            toks = [f"{label:g}"]
            for j, v in enumerate(row):
                if not drop_zeros or v != 0.0:
                    toks.append(f"{j + 1}:{v:g}")
            f.write(" ".join(toks) + "\n")
