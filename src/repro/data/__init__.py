"""Data substrate: synthetic SVM datasets, LIBSVM sparse format, LM tokens."""
from repro.data.synthetic import (make_blobs, make_checker, make_two_spirals,
                                  make_multiclass, train_test_split)
from repro.data.libsvm_format import read_libsvm, write_libsvm
from repro.data.lm_data import TokenStream, synthetic_token_batches

__all__ = [
    "make_blobs", "make_checker", "make_two_spirals", "make_multiclass",
    "train_test_split", "read_libsvm", "write_libsvm",
    "TokenStream", "synthetic_token_batches",
]
