"""Data substrate: synthetic SVM datasets, LIBSVM sparse format, LM tokens."""
from repro.data.synthetic import (make_blobs, make_checker, make_two_spirals,
                                  make_multiclass, train_test_split)
from repro.data.libsvm_format import (BadRowError, CSRData, IngestStats,
                                      count_libsvm_rows, read_libsvm,
                                      read_libsvm_blocks, write_libsvm)
from repro.data.lm_data import TokenStream, synthetic_token_batches

__all__ = [
    "make_blobs", "make_checker", "make_two_spirals", "make_multiclass",
    "train_test_split", "BadRowError", "CSRData", "IngestStats",
    "count_libsvm_rows", "read_libsvm", "read_libsvm_blocks", "write_libsvm",
    "TokenStream", "synthetic_token_batches",
]
