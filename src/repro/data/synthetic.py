"""Synthetic classification datasets standing in for the paper's benchmarks.

The container has no network access, so Adult/Epsilon/SUSY/MNIST-8M/ImageNet
are replaced by scalable synthetic families with comparable *structure*:
non-linearly-separable binary problems (checker, spirals — exercise the RBF
kernel exactly like SUSY/Epsilon) and a c-class problem with tunable class
count (exercises OVO scaling like MNIST/ImageNet).  Sizes are parameters, so
benchmarks scale n the way the paper's tables scale data sets.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


def train_test_split(x, y, test_frac: float = 0.25, seed: int = 0):
    n = x.shape[0]
    perm = np.random.default_rng(seed).permutation(n)
    k = int(n * (1.0 - test_frac))
    tr, te = perm[:k], perm[k:]
    return x[tr], y[tr], x[te], y[te]


def make_blobs(n: int, p: int = 8, n_classes: int = 2, sep: float = 2.0,
               seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(n_classes, p)) * sep
    y = rng.integers(0, n_classes, size=n)
    x = centers[y] + rng.normal(size=(n, p))
    return x.astype(np.float32), y.astype(np.int64)


def make_checker(n: int, cells: int = 4, noise: float = 0.05,
                 seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """2-D checkerboard — classic RBF-SVM stress test (non-linear boundary)."""
    rng = np.random.default_rng(seed)
    x = rng.uniform(0, cells, size=(n, 2))
    y = ((np.floor(x[:, 0]) + np.floor(x[:, 1])) % 2).astype(np.int64)
    x = x + rng.normal(scale=noise, size=x.shape)
    return x.astype(np.float32), y


def make_two_spirals(n: int, noise: float = 0.1,
                     seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    rng = np.random.default_rng(seed)
    m = n // 2
    t = np.sqrt(rng.uniform(0.05, 1.0, size=m)) * 3.0 * np.pi
    s1 = np.stack([t * np.cos(t), t * np.sin(t)], axis=1)
    s2 = -s1
    x = np.concatenate([s1, s2]) / (3.0 * np.pi)
    x = x + rng.normal(scale=noise, size=x.shape)
    y = np.concatenate([np.zeros(m), np.ones(n - m)]).astype(np.int64)
    perm = rng.permutation(n)
    return x[perm].astype(np.float32), y[perm]


def make_multiclass(n: int, p: int = 16, n_classes: int = 10, sep: float = 1.6,
                    within: float = 0.9, seed: int = 0):
    """c-class gaussian mixture with overlapping clusters (OVO benchmark)."""
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(n_classes, p)) * sep
    y = rng.integers(0, n_classes, size=n)
    # two sub-clusters per class -> non-linear class regions
    sub = rng.integers(0, 2, size=n)
    offs = rng.normal(size=(n_classes, 2, p)) * within
    x = centers[y] + offs[y, sub] + rng.normal(scale=0.7, size=(n, p))
    return x.astype(np.float32), y.astype(np.int64)
