"""Token pipeline for the backbone training loop (deterministic, offline).

Synthetic but *structured* token streams: a mixture of Zipf-distributed
unigrams and short repeated motifs, so a language model has learnable signal
and the loss visibly decreases over a few hundred steps (examples/train_lm.py).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Tuple

import numpy as np


@dataclasses.dataclass
class TokenStream:
    vocab_size: int
    seed: int = 0
    zipf_a: float = 1.2
    motif_len: int = 8
    n_motifs: int = 64
    motif_prob: float = 0.35

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self._motifs = rng.integers(
            0, self.vocab_size, size=(self.n_motifs, self.motif_len))

    def sample(self, rng: np.random.Generator, length: int) -> np.ndarray:
        out = np.empty(length, dtype=np.int32)
        i = 0
        while i < length:
            if rng.random() < self.motif_prob:
                m = self._motifs[rng.integers(0, self.n_motifs)]
                k = min(self.motif_len, length - i)
                out[i:i + k] = m[:k]
                i += k
            else:
                # zipf over the vocab (clipped)
                v = min(int(rng.zipf(self.zipf_a)) - 1, self.vocab_size - 1)
                out[i] = v
                i += 1
        return out


def synthetic_token_batches(
    vocab_size: int, batch: int, seq_len: int, *, seed: int = 0,
) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    """Yield (tokens, targets) with targets = tokens shifted by one."""
    stream = TokenStream(vocab_size, seed=seed)
    rng = np.random.default_rng(seed + 1)
    while True:
        flat = stream.sample(rng, batch * (seq_len + 1))
        chunk = flat.reshape(batch, seq_len + 1)
        yield chunk[:, :-1].copy(), chunk[:, 1:].copy()
