"""jamba-v0.1-52b [hybrid] — Mamba + attention 1:7 interleave, MoE
[arXiv:2403.19887].

32L, d_model=4096, 32 heads (GQA kv=8), d_ff=14336, vocab=65536,
MoE 16 experts top-2 on every other layer; attention on layer i when
i % 8 == 4 (1 attention : 7 mamba); mamba d_state=16, conv=4, expand=2.
long_500k is native: mamba state is constant-size and the single attention
layer per block uses a sliding-window KV cache.
"""
import dataclasses

from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    arch_type="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=65536,
    attention="gqa", decode_window=8192,
    attn_layer_period=8, attn_layer_offset=4,
    ssm_kind="mamba", ssm_state_dim=16, ssm_conv_dim=4, ssm_expand=2,
    n_experts=16, n_shared_experts=0, top_k=2, moe_d_ff=14336,
    moe_layer_period=2, moe_layer_offset=1,
    act="silu", optimizer="adamw",
    citation="arXiv:2403.19887",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, d_ff=512,
        vocab_size=512, n_experts=4, top_k=2, moe_d_ff=512,
        attn_layer_period=2, attn_layer_offset=1, ssm_state_dim=8)


register(CONFIG, reduced)
