"""Architecture configuration schema + registry.

One file per assigned architecture lives next to this module; each exports
`CONFIG` (the exact assigned spec) and `reduced()` (the <=2-layer, d<=512
smoke-test variant of the same family).  `get_config(name)` /
`list_configs()` are the public lookup API used by --arch flags.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple

ARCH_IDS = (
    "phi-3-vision-4.2b",
    "seamless-m4t-large-v2",
    "tinyllama-1.1b",
    "codeqwen1.5-7b",
    "deepseek-v2-236b",
    "qwen3-0.6b",
    "kimi-k2-1t-a32b",
    "rwkv6-1.6b",
    "jamba-v0.1-52b",
    "minitron-4b",
)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int                    # 0 for attention-free
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    # --- attention ---
    attention: str = "gqa"          # gqa | mla | none
    head_dim: Optional[int] = None  # default d_model // n_heads
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 1e4
    sliding_window: int = 0         # 0 = full attention (training/prefill)
    decode_window: int = 0          # >0: windowed KV cache for long_500k
    # --- MLA (deepseek) ---
    kv_lora_rank: int = 0
    q_lora_rank: int = 0
    rope_head_dim: int = 64
    v_head_dim: Optional[int] = None
    # --- MoE ---
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0               # per-expert hidden dim
    moe_layer_period: int = 1       # layer i is MoE iff i % period == offset
    moe_layer_offset: int = 0
    first_dense_layers: int = 0     # leading dense layers (deepseek/kimi style)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01
    # --- hybrid / ssm ---
    attn_layer_period: int = 0      # jamba: attention 1-in-8
    attn_layer_offset: int = 0
    ssm_kind: str = ""              # rwkv6 | mamba
    ssm_state_dim: int = 16
    ssm_conv_dim: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64          # rwkv6 head size
    # --- encoder-decoder ---
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    # --- multimodal stub frontend ---
    modality: str = "text"          # text | vision | audio
    num_prefix_embeddings: int = 0  # patch/frame embeddings from the stub
    # --- misc ---
    act: str = "silu"               # silu (gated) | gelu (gated) | relu2 (mlp)
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    optimizer: str = "adamw"
    citation: str = ""

    # ------------------------------------------------------------- helpers
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def resolved_v_head_dim(self) -> int:
        return self.v_head_dim if self.v_head_dim is not None else self.resolved_head_dim

    def layer_kind(self, i: int) -> str:
        """'attn' | 'ssm' for the mixer of decoder layer i."""
        if self.arch_type == "ssm":
            return "ssm"
        if self.attn_layer_period > 0:
            return ("attn" if i % self.attn_layer_period == self.attn_layer_offset
                    else "ssm")
        return "attn"

    def layer_is_moe(self, i: int) -> bool:
        if self.n_experts == 0 or i < self.first_dense_layers:
            return False
        return i % self.moe_layer_period == self.moe_layer_offset

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND model-FLOPs in roofline)."""
        d, hd = self.d_model, self.resolved_head_dim
        vhd = self.resolved_v_head_dim
        total = self.vocab_size * d * (1 if self.tie_embeddings else 2)

        def attn_params() -> int:
            if self.attention == "mla":
                kv_in = self.q_lora_rank if self.q_lora_rank else d
                p = d * self.kv_lora_rank                      # kv down
                p += d * self.rope_head_dim                    # shared k_rope
                if self.q_lora_rank:
                    p += d * self.q_lora_rank
                p += kv_in * self.n_heads * (hd + self.rope_head_dim)  # q up
                p += self.kv_lora_rank * self.n_heads * (hd + vhd)     # kv up
                p += self.n_heads * vhd * d                    # out
                return p
            q = d * self.n_heads * hd
            kv = 2 * d * self.n_kv_heads * hd
            o = self.n_heads * hd * d
            return q + kv + o

        def mlp_params(ff: int) -> int:
            gate = 3 if self.act in ("silu", "gelu") else 2
            return gate * d * ff

        def ssm_params() -> int:
            if self.ssm_kind == "rwkv6":
                # r,k,v,g,w projections + output + decay lora (approx.)
                return 6 * d * d + 2 * d * 64
            inner = d * self.ssm_expand
            return (2 * d * inner + inner * self.ssm_conv_dim
                    + inner * (2 * self.ssm_state_dim + 2)  # B,C,dt
                    + inner * self.ssm_state_dim + inner * d)

        for i in range(self.n_layers):
            total += attn_params() if self.layer_kind(i) == "attn" else ssm_params()
            if self.layer_is_moe(i):
                total += self.n_experts * mlp_params(self.moe_d_ff)
                total += self.n_shared_experts * mlp_params(self.moe_d_ff)
                total += d * self.n_experts                    # router
            else:
                total += mlp_params(self.d_ff)
            total += 2 * d                                     # norms
        if self.is_encoder_decoder:
            for _ in range(self.n_encoder_layers):
                total += attn_params() + mlp_params(self.d_ff) + 2 * d
            total += self.n_layers * attn_params()             # cross-attn
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k + shared experts only)."""
        if self.n_experts == 0:
            return self.param_count()
        full = self.param_count()
        d = self.d_model
        gate = 3 if self.act in ("silu", "gelu") else 2
        per_expert = gate * d * self.moe_d_ff
        n_moe_layers = sum(self.layer_is_moe(i) for i in range(self.n_layers))
        inactive = n_moe_layers * (self.n_experts - self.top_k) * per_expert
        return full - inactive


_REGISTRY = {}


def register(cfg: ModelConfig, reduced_fn) -> ModelConfig:
    _REGISTRY[cfg.name] = (cfg, reduced_fn)
    return cfg


def get_config(name: str, reduced: bool = False) -> ModelConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    cfg, reduced_fn = _REGISTRY[name]
    return reduced_fn() if reduced else cfg


def list_configs() -> Tuple[str, ...]:
    _ensure_loaded()
    return tuple(sorted(_REGISTRY))


_LOADED = False


def _ensure_loaded():
    global _LOADED
    if _LOADED:
        return
    for arch in ARCH_IDS:
        importlib.import_module("repro.configs." + arch.replace("-", "_").replace(".", "_"))
    _LOADED = True
