"""minitron-4b [dense] — pruned Nemotron [arXiv:2407.14679].

32L, d_model=3072, 24 heads (GQA kv=8), d_ff=9216, vocab=256000.
Nemotron family: squared-ReLU MLP (non-gated), no qkv bias.
"""
import dataclasses

from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="minitron-4b",
    arch_type="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8,
    d_ff=9216, vocab_size=256000,
    attention="gqa", rope_theta=1e4, decode_window=8192,
    act="relu2", optimizer="adamw",
    citation="arXiv:2407.14679",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=256, n_heads=4, n_kv_heads=2, d_ff=512,
        vocab_size=512)


register(CONFIG, reduced)
