"""tinyllama-1.1b [dense] — llama2-architecture small model [arXiv:2401.02385].

22L, d_model=2048, 32 heads, GQA kv=4, d_ff=5632, vocab=32000.
"""
import dataclasses

from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="tinyllama-1.1b",
    arch_type="dense",
    n_layers=22, d_model=2048, n_heads=32, n_kv_heads=4,
    d_ff=5632, vocab_size=32000,
    attention="gqa", rope_theta=1e4, decode_window=8192,
    act="silu", optimizer="adamw",
    citation="arXiv:2401.02385",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=256, n_heads=8, n_kv_heads=2, d_ff=512,
        vocab_size=512)


register(CONFIG, reduced)
