"""seamless-m4t-large-v2 [audio] — encoder-decoder, multimodal [arXiv:2308.11596].

Assigned spec: 24L, d_model=1024, 16 heads (kv=16), d_ff=8192, vocab=256206.
Interpreted as the model card's 24 encoder + 24 decoder layers (text decoder
with cross-attention).  The speech frontend (mel-spectrogram + conformer
feature extractor) is a STUB: input_specs() supplies frame embeddings
(B, n_frames, d_model) as encoder input; decode shapes lower the decoder with
the encoder memory precomputed.
"""
import dataclasses

from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    arch_type="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab_size=256206,
    attention="gqa", rope_theta=1e4, decode_window=8192,
    is_encoder_decoder=True, n_encoder_layers=24,
    modality="audio", num_prefix_embeddings=1024,   # encoder frames (default)
    act="gelu", optimizer="adamw",
    citation="arXiv:2308.11596",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, n_encoder_layers=2, d_model=256, n_heads=4,
        n_kv_heads=4, d_ff=512, vocab_size=512, num_prefix_embeddings=32)


register(CONFIG, reduced)
