"""codeqwen1.5-7b [dense] — qwen1.5 architecture [hf:Qwen/CodeQwen1.5-7B].

32L, d_model=4096, 32 heads (MHA kv=32), d_ff=13440, vocab=92416.
Qwen1.5 uses qkv biases.
"""
import dataclasses

from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="codeqwen1.5-7b",
    arch_type="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32,
    d_ff=13440, vocab_size=92416,
    attention="gqa", qkv_bias=True, rope_theta=1e6, decode_window=8192,
    act="silu", optimizer="adamw",
    citation="hf:Qwen/CodeQwen1.5-7B",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=256, n_heads=4, n_kv_heads=4, d_ff=512,
        vocab_size=512)


register(CONFIG, reduced)
