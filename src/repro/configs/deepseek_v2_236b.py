"""deepseek-v2-236b [moe] — MLA + fine-grained MoE [arXiv:2405.04434].

60L, d_model=5120, 128 heads, MLA kv_lora=512 (+64-dim decoupled rope),
per-expert d_ff=1536, vocab=102400, 160 routed experts top-6 + 2 shared,
first layer dense (d_ff=12288), q_lora=1536, v_head_dim=128.
"""
import dataclasses

from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    arch_type="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128,
    d_ff=12288,                      # the dense (first) layer's FFN
    vocab_size=102400,
    attention="mla", head_dim=128, v_head_dim=128,
    kv_lora_rank=512, q_lora_rank=1536, rope_head_dim=64,
    decode_window=8192,
    n_experts=160, n_shared_experts=2, top_k=6, moe_d_ff=1536,
    moe_layer_period=1, first_dense_layers=1,
    act="silu", optimizer="adamw",
    citation="arXiv:2405.04434",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=256, n_heads=4, n_kv_heads=4,
        head_dim=64, v_head_dim=64, kv_lora_rank=64, q_lora_rank=96,
        rope_head_dim=32, d_ff=512, vocab_size=512,
        n_experts=4, n_shared_experts=1, top_k=2, moe_d_ff=128,
        first_dense_layers=1)


register(CONFIG, reduced)
