"""rwkv6-1.6b [ssm] — Finch, data-dependent decay [arXiv:2404.05892].

24L, d_model=2048, attention-free (RWKV6 time-mix, head size 64 -> 32 heads),
channel-mix d_ff=7168, vocab=65536.  Constant-size recurrent state makes
long_500k decode native (no KV cache at all).
"""
import dataclasses

from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    arch_type="ssm",
    n_layers=24, d_model=2048, n_heads=0, n_kv_heads=0,
    d_ff=7168, vocab_size=65536,
    attention="none", ssm_kind="rwkv6", ssm_head_dim=64,
    act="relu2",                     # RWKV channel-mix uses squared ReLU
    optimizer="adamw",
    citation="arXiv:2404.05892",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=256, d_ff=512, vocab_size=512,
        ssm_head_dim=32)


register(CONFIG, reduced)
