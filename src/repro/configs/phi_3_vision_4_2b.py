"""phi-3-vision-4.2b [vlm] — phi3-mini backbone + CLIP-style patch prefix.

[hf:microsoft/Phi-3-vision-128k-instruct]: 32L, d_model=3072, 32 heads
(MHA, kv=32), d_ff=8192, vocab=32064.  The vision frontend (CLIP ViT-L/14 +
projector) is a STUB per instructions: input_specs() supplies projected patch
embeddings (B, num_prefix, d_model); the language transformer consumes them
as a prefix ahead of the text tokens.
"""
import dataclasses

from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    arch_type="vlm",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab_size=32064,
    attention="gqa", rope_theta=1e4, decode_window=8192,
    modality="vision", num_prefix_embeddings=576,   # 24x24 CLIP patch grid
    act="silu", optimizer="adamw",
    citation="hf:microsoft/Phi-3-vision-128k-instruct",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=256, n_heads=4, n_kv_heads=4, d_ff=512,
        vocab_size=512, num_prefix_embeddings=16)


register(CONFIG, reduced)
