"""kimi-k2-1t-a32b [moe] — trillion-parameter MoE [arXiv:2501.kimi2].

61L, d_model=7168, 64 heads (GQA kv=8 per the assignment table), per-expert
d_ff=2048, vocab=163840, 384 routed experts top-8 + 1 shared, first layer
dense.  Trains with Adafactor: fp32 Adam moments for 1T params would need
~16 GB/chip on the 512-chip mesh (DESIGN.md §Distribution).
"""
import dataclasses

from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    arch_type="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
    d_ff=18432,                     # dense (first) layer FFN
    vocab_size=163840,
    attention="gqa", head_dim=112, rope_theta=5e4, decode_window=8192,
    n_experts=384, n_shared_experts=1, top_k=8, moe_d_ff=2048,
    moe_layer_period=1, first_dense_layers=1,
    act="silu", optimizer="adafactor",
    citation="arXiv:2501.kimi2",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=256, n_heads=4, n_kv_heads=2,
        head_dim=64, d_ff=512, vocab_size=512,
        n_experts=4, n_shared_experts=1, top_k=2, moe_d_ff=128,
        first_dense_layers=1)


register(CONFIG, reduced)
