"""qwen3-0.6b [dense] — qk_norm + GQA [hf:Qwen/Qwen3-8B family].

28L, d_model=1024, 16 heads (GQA kv=8), d_ff=3072, vocab=151936,
head_dim=128 (decoupled from d_model/n_heads), per-head RMS qk-norm.
"""
import dataclasses

from repro.configs.base import ModelConfig, register

CONFIG = ModelConfig(
    name="qwen3-0.6b",
    arch_type="dense",
    n_layers=28, d_model=1024, n_heads=16, n_kv_heads=8,
    d_ff=3072, vocab_size=151936,
    attention="gqa", head_dim=128, qk_norm=True, rope_theta=1e6,
    decode_window=8192, tie_embeddings=True,
    act="silu", optimizer="adamw",
    citation="hf:Qwen/Qwen3-8B",
)


def reduced() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=256, n_heads=4, n_kv_heads=2,
        head_dim=64, d_ff=512, vocab_size=512)


register(CONFIG, reduced)
