"""Assigned-architecture configs (+ the paper's own SVM workloads)."""
from repro.configs.base import ModelConfig, get_config, list_configs, ARCH_IDS

__all__ = ["ModelConfig", "get_config", "list_configs", "ARCH_IDS"]
