"""Shared model-building utilities: params-with-sharding-specs, norms, acts.

Parameters are plain dict pytrees.  Every init function returns BOTH the
parameter tree and a parallel tree of *logical* sharding specs — tuples of
logical axis names resolved against the physical mesh at launch time
(launch/mesh.py):

    logical axis    16x16 mesh            2x16x16 mesh
    "fsdp"      ->  "data"                "data"
    "tp"        ->  "model"               "model"
    "ep"        ->  "model"               "model"
    "batch"     ->  ("data",)             ("pod", "data")
    "seq"       ->  "model" (MoE blocks)  "model"

Models never mention physical axis names, so the same definition lowers on a
single CPU device (smoke tests), one pod, or two pods.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import get_abstract_mesh

# ---------------------------------------------------------------------------
# logical -> physical axis resolution
# ---------------------------------------------------------------------------

def logical_to_physical(mesh_axis_names: Sequence[str]):
    """Return a resolver mapping logical spec tuples -> PartitionSpec."""
    has_pod = "pod" in mesh_axis_names
    table = {
        None: None,
        "fsdp": "data",
        "tp": "model",
        "ep": "model",
        "seq": "model",
        "batch": ("pod", "data") if has_pod else ("data",),
    }

    def resolve(logical: Optional[Tuple]) -> P:
        if logical is None:
            return P()
        return P(*[table[a] for a in logical])

    return resolve


def spec_tree_to_shardings(spec_tree, mesh, shape_tree=None):
    """Resolve logical specs to NamedShardings.

    When `shape_tree` is given, axes whose sizes do not divide the mesh axis
    product are dropped (replicated) — e.g. seamless's vocab 256,206 cannot be
    16-way sharded.
    """
    from jax.sharding import NamedSharding
    resolve = logical_to_physical(mesh.axis_names)
    is_leaf = lambda x: x is None or isinstance(x, tuple)

    if shape_tree is None:
        return jax.tree.map(lambda spec: NamedSharding(mesh, resolve(spec)),
                            spec_tree, is_leaf=is_leaf)

    def one(spec, arr):
        pspec = resolve(spec)
        entries = []
        for dim, entry in enumerate(pspec):
            if entry is None:
                entries.append(None)
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            n = math.prod(mesh.shape[a] for a in axes)
            entries.append(entry if arr.shape[dim] % n == 0 else None)
        return NamedSharding(mesh, P(*entries))

    return jax.tree.map(one, spec_tree, shape_tree, is_leaf=is_leaf)


# ---------------------------------------------------------------------------
# initializers (params + logical specs built together)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ParamAndSpec:
    params: Any
    specs: Any


def dense_init(key, shape, spec, dtype=jnp.bfloat16, scale: Optional[float] = None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    w = (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)
    return w, spec


def zeros_init(shape, spec, dtype=jnp.bfloat16):
    return jnp.zeros(shape, dtype), spec


def embed_init(key, vocab, d, spec=("tp", "fsdp"), dtype=jnp.bfloat16):
    w = (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)
    return w, spec


# ---------------------------------------------------------------------------
# numerics
# ---------------------------------------------------------------------------

def rms_norm(x, gamma, eps: float = 1e-5):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * gamma


def activation(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "relu2":   # Nemotron/Minitron squared ReLU
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(name)


def rotary_cos_sin(positions, head_dim: int, theta: float = 1e4):
    """positions (...,) int32 -> (cos, sin) of shape (..., head_dim // 2)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(angles), jnp.sin(angles)


def apply_rotary(x, cos, sin):
    """x (..., head_dim); cos/sin broadcastable (..., head_dim//2)."""
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def shard(x, *logical):
    """with_sharding_constraint by logical axes.

    Requires an active `jax.set_mesh(mesh)` scope to take effect; outside one
    (unit tests on a single device) it is a no-op.  Dimensions that do not
    divide the mesh axis product are left unconstrained — forcing e.g. a
    16-way split onto 8 KV heads makes GSPMD fall back to full
    rematerialization (replicate + reshard), which is both a memory and a
    collective disaster.
    """
    mesh = get_abstract_mesh()
    if mesh is None or not mesh.shape:
        return x
    resolve = logical_to_physical(mesh.axis_names)
    spec = resolve(tuple(logical))
    entries = []
    for dim, entry in enumerate(spec):
        if entry is None:
            entries.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        n = math.prod(mesh.shape[a] for a in axes)
        entries.append(entry if x.shape[dim] % n == 0 else None)
    return jax.lax.with_sharding_constraint(x, P(*entries))
