"""Full model assembly: decoder-only LM, encoder-decoder, VLM/audio prefixes.

Layer stacking uses `jax.lax.scan` over *layer groups* so 60-layer models
produce compact HLO: a group is lcm(attn_layer_period, moe_layer_period)
layers (jamba: 8, everything else: 1); `first_dense_layers` (deepseek/kimi)
run unscanned as a prologue.  Every layer is wrapped in `jax.checkpoint`
(full remat) during training.

Public entry points (all pure functions over a params pytree):

    init_model(key, cfg)                  -> (params, logical spec tree)
    forward(params, cfg, batch, mode)     -> (logits, aux_loss)   # train/prefill
    init_decode_state(params, cfg, ...)   -> cache pytree
    decode(params, cfg, tokens, cache, pos) -> (logits, cache)    # one token
"""
from __future__ import annotations

import math
from functools import partial
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import blocks
from repro.models.common import embed_init, rms_norm, shard


def _group_size(cfg: ModelConfig) -> int:
    g = 1
    if cfg.attn_layer_period:
        g = cfg.attn_layer_period
    if cfg.n_experts and cfg.moe_layer_period > 1:
        g = math.lcm(g, cfg.moe_layer_period)
    return g


def _layout(cfg: ModelConfig) -> Tuple[int, int, int]:
    """(n_prologue, group, n_groups); prologue absorbs non-periodic leftovers."""
    g = _group_size(cfg)
    pro = cfg.first_dense_layers
    rem = cfg.n_layers - pro
    n_groups = rem // g
    pro += rem - n_groups * g          # leftovers join the prologue
    return pro, g, n_groups


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def padded_vocab(cfg: ModelConfig) -> int:
    """Pad the vocab to a shardable size (Megatron-style).

    seamless's 256,206 does not divide the 16-way "model" axis, which forces
    the (B, S, V) logits (and every CE temporary) to replicate — 67 GiB/device
    at prefill_32k.  Padding to a multiple of 512 costs <0.2% embed rows; the
    padded logits are masked to -inf in forward/decode.
    """
    V = cfg.vocab_size
    return V if V % 512 == 0 or V % 16 == 0 else -(-V // 512) * 512


def _mask_padded_logits(cfg: ModelConfig, logits):
    V = cfg.vocab_size
    if logits.shape[-1] == V:
        return logits
    keep = jnp.arange(logits.shape[-1]) < V
    return jnp.where(keep, logits, jnp.asarray(-1e30, logits.dtype))


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_model(key, cfg: ModelConfig, dtype=jnp.bfloat16):
    keys = jax.random.split(key, cfg.n_layers + cfg.n_encoder_layers + 4)
    params: Dict = {}
    specs: Dict = {}
    Vp = padded_vocab(cfg)
    params["embed"], specs["embed"] = embed_init(keys[0], Vp,
                                                 cfg.d_model, dtype=dtype)
    params["final_ln"] = jnp.ones((cfg.d_model,), dtype)
    specs["final_ln"] = (None,)
    if not cfg.tie_embeddings:
        params["unembed"], specs["unembed"] = embed_init(
            keys[1], Vp, cfg.d_model, spec=("tp", "fsdp"), dtype=dtype)

    with_cross = cfg.is_encoder_decoder
    pro, g, n_groups = _layout(cfg)

    params["prologue"], specs["prologue"] = [], []
    for i in range(pro):
        p, s = blocks.init_layer(keys[2 + i], cfg, i, dtype, with_cross=with_cross)
        params["prologue"].append(p)
        specs["prologue"].append(s)

    group_p, group_s = [], []
    for gi in range(n_groups):
        ps, ss = [], []
        for j in range(g):
            i = pro + gi * g + j
            p, s = blocks.init_layer(keys[2 + i], cfg, i, dtype,
                                     with_cross=with_cross)
            ps.append(p)
            ss.append(s)
        group_p.append(ps)
        group_s.append(ss)
    if n_groups:
        # stack over groups: list[groups] of list[g] of dict -> list[g] of
        # stacked dicts with leading (n_groups,) axis
        params["groups"] = [_stack([group_p[gi][j] for gi in range(n_groups)])
                            for j in range(g)]
        specs["groups"] = [jax.tree.map(
            lambda spec: (None,) + tuple(spec),
            group_s[0][j],
            is_leaf=lambda x: x is None or isinstance(x, tuple))
            for j in range(g)]
    else:
        params["groups"], specs["groups"] = [], []

    if cfg.is_encoder_decoder:
        enc_cfg = cfg
        ep, es = [], []
        base = 2 + cfg.n_layers
        for i in range(cfg.n_encoder_layers):
            p, s = blocks.init_layer(keys[base + i], enc_cfg, i, dtype)
            ep.append(p)
            es.append(s)
        params["encoder"] = {"layers": _stack(ep),
                             "final_ln": jnp.ones((cfg.d_model,), dtype)}
        specs["encoder"] = {
            "layers": jax.tree.map(
                lambda spec: (None,) + tuple(spec), es[0],
                is_leaf=lambda x: x is None or isinstance(x, tuple)),
            "final_ln": (None,),
        }
    return params, specs


# ---------------------------------------------------------------------------
# full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------

def _run_encoder(params, cfg: ModelConfig, frames, *, unroll: bool = False):
    """Bidirectional encoder over stub frame embeddings (B, S_enc, d)."""
    x = frames
    S = x.shape[1]
    positions = jnp.arange(S)

    def body(x, layer_params):
        x, _ = blocks.apply_layer_full(layer_params, cfg, 0, x, positions,
                                       causal=False)
        return x, None

    if unroll:
        for li in range(cfg.n_encoder_layers):
            lp = jax.tree.map(lambda a: a[li], params["encoder"]["layers"])
            x, _ = body(x, lp)
    else:
        x, _ = jax.lax.scan(
            lambda c, p: body(c, p), x, params["encoder"]["layers"])
    return rms_norm(x, params["encoder"]["final_ln"], cfg.norm_eps)


def forward(params, cfg: ModelConfig, batch: Dict, *,
            moe_strategy: str = "local", remat: bool = True,
            token_spec=None, unroll: bool = False):
    """batch: {"tokens" (B,S), optional "prefix" (B,P,d), "frames" (B,F,d)}.

    Returns (logits (B, S_total, V), aux_loss).  For prefix models the
    prefix positions are included in logits (caller slices).
    """
    tokens = batch["tokens"]
    B, S = tokens.shape
    x = params["embed"][tokens]                         # (B, S, d) gather
    memory = None
    if cfg.modality == "vision" and "prefix" in batch:
        x = jnp.concatenate([batch["prefix"].astype(x.dtype), x], axis=1)
    if cfg.is_encoder_decoder:
        memory = _run_encoder(params, cfg, batch["frames"].astype(x.dtype),
                              unroll=unroll)
    S_tot = x.shape[1]
    positions = jnp.arange(S_tot)
    x = shard(x, "batch", None, None)
    aux_total = jnp.float32(0.0)
    pro, g, n_groups = _layout(cfg)

    def one_layer(i, lp, x):
        # sequence-parallel residual stream: the layer input is what remat
        # saves per scanned layer — sharding S over "model" divides that
        # footprint by the TP degree (norms/residual adds are elementwise)
        x = shard(x, "batch", "seq", None)
        x, aux = blocks.apply_layer_full(lp, cfg, i, x, positions,
                                         causal=True, memory=memory,
                                         moe_strategy=moe_strategy,
                                         token_spec=token_spec)
        return shard(x, "batch", "seq", None), aux

    for i, lp in enumerate(params["prologue"]):
        f = jax.checkpoint(partial(one_layer, i)) if remat else partial(one_layer, i)
        x, aux = f(lp, x)
        aux_total += aux

    if n_groups:
        def group_body(carry, group_params):
            x, aux_acc = carry
            for j in range(g):
                i = pro + j      # layer kind depends on i mod periods only
                f = (jax.checkpoint(partial(one_layer, i)) if remat
                     else partial(one_layer, i))
                x, aux = f(group_params[j], x)
                aux_acc = aux_acc + aux
            return (x, aux_acc), None

        if unroll:  # dry-run cost probes: scan bodies are cost-counted once
            for gi in range(n_groups):
                gp = jax.tree.map(lambda a: a[gi], params["groups"])
                (x, aux_total), _ = group_body((x, aux_total), gp)
        else:
            (x, aux_total), _ = jax.lax.scan(
                group_body, (x, aux_total), params["groups"])

    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    unembed = (params["embed"].T if cfg.tie_embeddings
               else params["unembed"].T)
    logits = x @ unembed                                # (B, S_tot, Vp)
    logits = shard(logits, "batch", None, "tp")
    return _mask_padded_logits(cfg, logits), aux_total


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_decode_state(cfg: ModelConfig, batch: int, kv_len: int,
                      dtype=jnp.bfloat16, *, enc_len: int = 0):
    """Cache pytree: prologue list + per-group-position stacked caches."""
    pro, g, n_groups = _layout(cfg)
    state = {"prologue": [blocks.init_layer_cache(cfg, i, batch, kv_len, dtype,
                                                  enc_len=enc_len)
                          for i in range(pro)]}
    groups = []
    for j in range(g):
        i = pro + j
        one = blocks.init_layer_cache(cfg, i, batch, kv_len, dtype,
                                      enc_len=enc_len)
        groups.append(jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n_groups,) + a.shape).copy(), one))
    state["groups"] = groups
    return state


def decode(params, cfg: ModelConfig, tokens, state, pos, *,
           moe_strategy: str = "local", token_spec=None, unroll: bool = False):
    """One decode step.  tokens (B, 1) int32; pos scalar int32 position."""
    x = params["embed"][tokens]
    pro, g, n_groups = _layout(cfg)
    new_pro = []
    for i, lp in enumerate(params["prologue"]):
        x, c = blocks.apply_layer_decode(lp, cfg, i, x, state["prologue"][i],
                                         pos, moe_strategy=moe_strategy,
                                         token_spec=token_spec)
        new_pro.append(c)

    new_groups = state["groups"]
    if n_groups:
        def group_body(x, scanned):
            group_params, caches = scanned
            new_caches = []
            for j in range(g):
                i = pro + j
                x, c = blocks.apply_layer_decode(
                    group_params[j], cfg, i, x, caches[j], pos,
                    moe_strategy=moe_strategy, token_spec=token_spec)
                new_caches.append(c)
            return x, new_caches

        if unroll:
            ng_list = []
            for gi in range(n_groups):
                gp = jax.tree.map(lambda a: a[gi], params["groups"])
                gc = jax.tree.map(lambda a: a[gi], state["groups"])
                x, nc = group_body(x, (gp, gc))
                ng_list.append(nc)
            new_groups = jax.tree.map(lambda *xs: jnp.stack(xs), *ng_list)
        else:
            x, new_groups = jax.lax.scan(
                group_body, x, (params["groups"], state["groups"]))

    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    unembed = (params["embed"].T if cfg.tie_embeddings else params["unembed"].T)
    logits = _mask_padded_logits(cfg, x @ unembed)
    return logits, {"prologue": new_pro, "groups": new_groups}


def prefill_cross_attention(params, cfg: ModelConfig, state, memory):
    """Populate the decode state's cross-attention k/v from encoder memory."""
    B = memory.shape[0]
    hd = cfg.resolved_head_dim

    def kv(wk, wv):
        if wk.ndim == 3:   # stacked group weights (n_groups, d, Hkv*hd)
            xk = jnp.einsum("bsd,gdh->gbsh", memory, wk)
            xv = jnp.einsum("bsd,gdh->gbsh", memory, wv)
            G = wk.shape[0]
            return (xk.reshape(G, B, -1, cfg.n_kv_heads, hd),
                    xv.reshape(G, B, -1, cfg.n_kv_heads, hd))
        xk = (memory @ wk).reshape(B, -1, cfg.n_kv_heads, hd)
        xv = (memory @ wv).reshape(B, -1, cfg.n_kv_heads, hd)
        return xk, xv

    for i, c in enumerate(state["prologue"]):
        c["xk"], c["xv"] = kv(params["prologue"][i]["cross"]["wk"],
                              params["prologue"][i]["cross"]["wv"])
    for j, c in enumerate(state["groups"]):
        c["xk"], c["xv"] = kv(params["groups"][j]["cross"]["wk"],
                              params["groups"][j]["cross"]["wv"])
    return state


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def lm_loss(logits, targets, *, prefix_len: int = 0):
    """Mean cross-entropy over the text positions.  targets (B, S_text).

    Written without take_along_axis: a gather over the vocab axis would make
    GSPMD all-gather the (B, S, V) logits when the vocab is tensor-sharded;
    the masked-sum form keeps everything vocab-sharded (the reductions become
    cheap all-reduces of (B, S) partials).
    """
    if prefix_len:
        logits = logits[:, prefix_len:]
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    V = logits.shape[-1]
    onehot = targets[..., None] == jnp.arange(V, dtype=targets.dtype)
    tgt_logit = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    return jnp.mean(lse - tgt_logit)
