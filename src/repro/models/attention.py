"""Attention mixers: GQA (with qk-norm / sliding window / biases) and MLA.

Three execution paths, all sharing weights:

  * `attend_full`  — training / prefill over a whole sequence.  Flash-style
    online-softmax accumulation over KV chunks (lax.scan) so the S x S logits
    matrix never materializes (peak transient is (B, H, S_q, kv_chunk));
  * `decode_step`  — one token against a (possibly rolling sliding-window) KV
    cache.  Plain attention: S_q = 1 logits are tiny, and keeping the cache
    un-chunked lets GSPMD shard the cache sequence axis over "model" and turn
    the softmax reductions into all-reduces (distributed flash-decode);
  * MLA decode uses the *absorbed* form: w_uk is folded into the query and
    w_uv into the output so only the latent c_kv (kv_lora + rope dims) is
    cached and attended — the whole point of MLA's small cache.

Sharding (logical): batch -> "batch", heads -> "tp".  KV caches shard the kv
head axis over "tp" when divisible, else the sequence axis (launch/steps.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import (apply_rotary, dense_init, rms_norm,
                                 rotary_cos_sin, shard, zeros_init)

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# parameter construction
# ---------------------------------------------------------------------------

def init_gqa(key, cfg: ModelConfig, dtype) -> Tuple[Dict, Dict]:
    d, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    p, s = {}, {}
    p["wq"], s["wq"] = dense_init(ks[0], (d, H * hd), ("fsdp", "tp"), dtype)
    p["wk"], s["wk"] = dense_init(ks[1], (d, Hkv * hd), ("fsdp", "tp"), dtype)
    p["wv"], s["wv"] = dense_init(ks[2], (d, Hkv * hd), ("fsdp", "tp"), dtype)
    p["wo"], s["wo"] = dense_init(ks[3], (H * hd, d), ("tp", "fsdp"), dtype)
    if cfg.qkv_bias:
        for nm, width in (("bq", H * hd), ("bk", Hkv * hd), ("bv", Hkv * hd)):
            p[nm], s[nm] = zeros_init((width,), ("tp",), dtype)
    if cfg.qk_norm:
        p["q_norm"], s["q_norm"] = jnp.ones((hd,), dtype), (None,)
        p["k_norm"], s["k_norm"] = jnp.ones((hd,), dtype), (None,)
    return p, s


def init_mla(key, cfg: ModelConfig, dtype) -> Tuple[Dict, Dict]:
    d, H = cfg.d_model, cfg.n_heads
    hd, vhd, r = cfg.resolved_head_dim, cfg.resolved_v_head_dim, cfg.rope_head_dim
    ks = jax.random.split(key, 6)
    p, s = {}, {}
    q_in = cfg.q_lora_rank if cfg.q_lora_rank else d
    if cfg.q_lora_rank:
        p["w_dq"], s["w_dq"] = dense_init(ks[0], (d, cfg.q_lora_rank), ("fsdp", None), dtype)
        p["q_ln"], s["q_ln"] = jnp.ones((cfg.q_lora_rank,), dtype), (None,)
    p["w_uq"], s["w_uq"] = dense_init(ks[1], (q_in, H * (hd + r)), ("fsdp", "tp"), dtype)
    p["w_dkv"], s["w_dkv"] = dense_init(ks[2], (d, cfg.kv_lora_rank), ("fsdp", None), dtype)
    p["kv_ln"], s["kv_ln"] = jnp.ones((cfg.kv_lora_rank,), dtype), (None,)
    p["w_kr"], s["w_kr"] = dense_init(ks[3], (d, r), ("fsdp", None), dtype)
    p["w_ukv"], s["w_ukv"] = dense_init(
        ks[4], (cfg.kv_lora_rank, H * (hd + vhd)), ("fsdp", "tp"), dtype)
    p["wo"], s["wo"] = dense_init(ks[5], (H * vhd, d), ("tp", "fsdp"), dtype)
    return p, s


def init_attention(key, cfg: ModelConfig, dtype):
    if cfg.attention == "mla":
        return init_mla(key, cfg, dtype)
    return init_gqa(key, cfg, dtype)


# ---------------------------------------------------------------------------
# flash-style chunked attention core (full-sequence paths)
# ---------------------------------------------------------------------------

# Dry-run cost probes set these to huge values so the online-softmax scans
# have a single iteration (XLA cost analysis counts scan bodies once).
FLASH_KV_CHUNK = 1024
FLASH_Q_CHUNK = 512


def _flash(q, k, v, q_pos, kv_pos, *, causal: bool, window: int,
           kv_chunk: Optional[int] = None, q_chunk: Optional[int] = None):
    """Online-softmax attention, chunked over BOTH query and kv axes.

    q: (B, Sq, Hkv, G, hd)   grouped queries (G = H / Hkv)
    k: (B, Skv, Hkv, hd)     v: (B, Skv, Hkv, vhd)
    q_pos: (Sq,), kv_pos: (Skv,) int32 (-1 marks invalid kv slots)

    Peak temp per device is one (B, H, q_chunk, kv_chunk) float32 logits
    block; both scan bodies are remat'd, so the backward recomputes logits
    per block instead of saving them — the flash-attention trade in jnp.
    (The Pallas flash kernel is the TPU-native version of exactly this
    blocking; the jnp form is what the dry-run lowers.)
    """
    B, Sq, Hkv, G, hd = q.shape
    vhd = v.shape[-1]
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    Skv = k.shape[1]
    def _divisor_chunk(S, target):
        """Largest divisor of S that is <= target (handles VLM's 4672 etc.)."""
        c = min(target, S)
        while S % c:
            c -= 1
        return c

    kv_chunk = _divisor_chunk(Skv, kv_chunk if kv_chunk is not None
                              else FLASH_KV_CHUNK)
    q_chunk = _divisor_chunk(Sq, q_chunk if q_chunk is not None
                             else FLASH_Q_CHUNK)
    n_kv = Skv // kv_chunk
    n_q = Sq // q_chunk

    kc = k.reshape(B, n_kv, kv_chunk, Hkv, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_kv, kv_chunk, Hkv, vhd).transpose(1, 0, 2, 3, 4)
    pc = kv_pos.reshape(n_kv, kv_chunk)
    qc = q.reshape(B, n_q, q_chunk, Hkv, G, hd).transpose(1, 0, 2, 3, 4, 5)
    qpc = q_pos.reshape(n_q, q_chunk)

    def q_step(_, q_inp):
        q_blk, qp_blk = q_inp                        # (B, qc, Hkv, G, hd)

        def kv_step(carry, inp):
            m, l, acc = carry
            k_blk, v_blk, p_blk = inp
            s = jnp.einsum("bqhgd,bkhd->bhgqk", q_blk.astype(jnp.float32),
                           k_blk.astype(jnp.float32)) * scale
            mask = p_blk[None, :] >= 0                               # valid
            if causal:
                mask = jnp.logical_and(mask, qp_blk[:, None] >= p_blk[None, :])
            if window > 0:
                mask = jnp.logical_and(mask,
                                       qp_blk[:, None] - p_blk[None, :] < window)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new)
            l = l * alpha + jnp.sum(p, axis=-1, keepdims=True)
            acc = acc * alpha + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, v_blk.astype(jnp.float32))
            return (m_new, l, acc), None

        m0 = jnp.full((B, Hkv, G, q_chunk, 1), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, q_chunk, 1), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, q_chunk, vhd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(jax.checkpoint(kv_step), (m0, l0, a0),
                                      (kc, vc, pc))
        out = acc / jnp.maximum(l, 1e-30)            # (B, Hkv, G, qc, vhd)
        return None, out.astype(v.dtype)

    if n_q == 1:
        _, outs = q_step(None, (qc[0], qpc[0]))
        out = outs[None]
    else:
        _, outs = jax.lax.scan(jax.checkpoint(q_step), None, (qc, qpc))
        out = outs                                   # (n_q, B, Hkv, G, qc, vhd)
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq, Hkv, G, vhd)
    return out


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------

def _qkv(params, cfg: ModelConfig, x):
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    B, S, _ = x.shape
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(B, S, Hkv, H // Hkv, hd)
    k = k.reshape(B, S, Hkv, hd)
    v = v.reshape(B, S, Hkv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    return q, k, v


def gqa_full(params, cfg: ModelConfig, x, positions, *, causal=True,
             window: int = 0):
    """Training / prefill.  x (B, S, d); positions (S,)."""
    B, S, _ = x.shape
    H, hd = cfg.n_heads, cfg.resolved_head_dim
    q, k, v = _qkv(params, cfg, x)
    cos, sin = rotary_cos_sin(positions, hd, cfg.rope_theta)
    q = apply_rotary(q, cos[None, :, None, None], sin[None, :, None, None])
    k = apply_rotary(k, cos[None, :, None], sin[None, :, None])
    q = shard(q, "batch", None, "tp", None, None)
    k = shard(k, "batch", None, "tp", None)
    out = _flash(q, k, v, positions, positions, causal=causal, window=window)
    out = out.reshape(B, S, H * hd).astype(x.dtype)
    return out @ params["wo"]


def gqa_decode(params, cfg: ModelConfig, x, cache, pos):
    """One token.  x (B, 1, d); cache {k, v: (B, W, Hkv, hd), pos: (W,)}."""
    B = x.shape[0]
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    W = cache["k"].shape[1]
    q, k_new, v_new = _qkv(params, cfg, x)
    cos, sin = rotary_cos_sin(pos[None], hd, cfg.rope_theta)
    q = apply_rotary(q, cos[None, :, None, None], sin[None, :, None, None])
    k_new = apply_rotary(k_new, cos[None, :, None], sin[None, :, None])

    slot = pos % W
    k = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype),
                                     (0, slot, 0, 0))
    v = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype),
                                     (0, slot, 0, 0))
    kv_pos = jax.lax.dynamic_update_slice(cache["pos"], pos[None], (slot,))

    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    mask = jnp.logical_and(kv_pos >= 0, kv_pos <= pos)
    s = jnp.where(mask[None, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    out = out.reshape(B, 1, H * hd).astype(x.dtype)
    return out @ params["wo"], {"k": k, "v": v, "pos": kv_pos}


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2)
# ---------------------------------------------------------------------------

def _mla_q(params, cfg: ModelConfig, x):
    H, hd, r = cfg.n_heads, cfg.resolved_head_dim, cfg.rope_head_dim
    B, S, _ = x.shape
    h = x
    if cfg.q_lora_rank:
        h = rms_norm(x @ params["w_dq"], params["q_ln"], cfg.norm_eps)
    q = (h @ params["w_uq"]).reshape(B, S, H, hd + r)
    return q[..., :hd], q[..., hd:]          # q_nope, q_rope


def _mla_latent(params, cfg: ModelConfig, x, positions):
    c_kv = rms_norm(x @ params["w_dkv"], params["kv_ln"], cfg.norm_eps)
    k_r = x @ params["w_kr"]                                    # (B, S, r)
    cos, sin = rotary_cos_sin(positions, cfg.rope_head_dim, cfg.rope_theta)
    k_r = apply_rotary(k_r, cos[None], sin[None])
    return c_kv, k_r


def mla_full(params, cfg: ModelConfig, x, positions, *, causal=True,
             window: int = 0):
    """Training / prefill: materialize per-head k/v from the latent."""
    B, S, _ = x.shape
    H, hd, vhd, r = (cfg.n_heads, cfg.resolved_head_dim,
                     cfg.resolved_v_head_dim, cfg.rope_head_dim)
    q_nope, q_rope = _mla_q(params, cfg, x)
    cos, sin = rotary_cos_sin(positions, r, cfg.rope_theta)
    q_rope = apply_rotary(q_rope, cos[None, :, None], sin[None, :, None])
    c_kv, k_r = _mla_latent(params, cfg, x, positions)
    kv = (c_kv @ params["w_ukv"]).reshape(B, S, H, hd + vhd)
    k_nope, v = kv[..., :hd], kv[..., hd:]
    # fold the shared rope key into per-head keys: concat along feature dim
    q = jnp.concatenate([q_nope, q_rope], axis=-1)[:, :, :, None, :]  # Hkv=H,G=1
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_r[:, :, None, :],
                                                  (B, S, H, r))], axis=-1)
    q = shard(q, "batch", None, "tp", None, None)
    k = shard(k, "batch", None, "tp", None)
    out = _flash(q, k, v, positions, positions, causal=causal, window=window)
    out = out.reshape(B, S, H * vhd).astype(x.dtype)
    return out @ params["wo"]


def mla_decode(params, cfg: ModelConfig, x, cache, pos):
    """Absorbed-matrix decode over the latent cache.

    cache: {ckv: (B, W, kv_lora), kr: (B, W, r), pos: (W,)}
    q_eff[h] = q_nope[h] @ w_uk[h]^T  -> attends c_kv directly;
    out[h]   = (attn @ c_kv) @ w_uv[h].
    """
    B = x.shape[0]
    H, hd, vhd, r = (cfg.n_heads, cfg.resolved_head_dim,
                     cfg.resolved_v_head_dim, cfg.rope_head_dim)
    L = cfg.kv_lora_rank
    W = cache["ckv"].shape[1]
    q_nope, q_rope = _mla_q(params, cfg, x)                     # (B,1,H,·)
    cos, sin = rotary_cos_sin(pos[None], r, cfg.rope_theta)
    q_rope = apply_rotary(q_rope, cos[None, :, None], sin[None, :, None])
    c_new, kr_new = _mla_latent(params, cfg, x, pos[None])

    slot = pos % W
    ckv = jax.lax.dynamic_update_slice(cache["ckv"],
                                       c_new.astype(cache["ckv"].dtype),
                                       (0, slot, 0))
    kr = jax.lax.dynamic_update_slice(cache["kr"],
                                      kr_new.astype(cache["kr"].dtype),
                                      (0, slot, 0))
    kv_pos = jax.lax.dynamic_update_slice(cache["pos"], pos[None], (slot,))

    w_ukv = params["w_ukv"].reshape(L, H, hd + vhd)
    w_uk, w_uv = w_ukv[..., :hd], w_ukv[..., hd:]
    # absorb: (B,1,H,hd) x (L,H,hd) -> (B,1,H,L)
    q_eff = jnp.einsum("bqhd,lhd->bqhl", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))
    scale = 1.0 / jnp.sqrt(hd + r).astype(jnp.float32)
    s = (jnp.einsum("bqhl,bkl->bhqk", q_eff, ckv.astype(jnp.float32))
         + jnp.einsum("bqhr,bkr->bhqk", q_rope.astype(jnp.float32),
                      kr.astype(jnp.float32))) * scale
    mask = jnp.logical_and(kv_pos >= 0, kv_pos <= pos)
    s = jnp.where(mask[None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    lat = jnp.einsum("bhqk,bkl->bqhl", p, ckv.astype(jnp.float32))
    out = jnp.einsum("bqhl,lhv->bqhv", lat, w_uv.astype(jnp.float32))
    out = out.reshape(B, 1, H * vhd).astype(x.dtype)
    return out @ params["wo"], {"ckv": ckv, "kr": kr, "pos": kv_pos}


# ---------------------------------------------------------------------------
# dispatch + cache builders
# ---------------------------------------------------------------------------

def attend_full(params, cfg: ModelConfig, x, positions, *, causal=True,
                window: int = 0):
    if cfg.attention == "mla":
        return mla_full(params, cfg, x, positions, causal=causal, window=window)
    return gqa_full(params, cfg, x, positions, causal=causal, window=window)


def decode_step(params, cfg: ModelConfig, x, cache, pos):
    if cfg.attention == "mla":
        return mla_decode(params, cfg, x, cache, pos)
    return gqa_decode(params, cfg, x, cache, pos)


def init_cache(cfg: ModelConfig, batch: int, length: int, dtype=jnp.bfloat16):
    """Empty KV cache for one attention layer (length = S or decode_window)."""
    if cfg.attention == "mla":
        return {
            "ckv": jnp.zeros((batch, length, cfg.kv_lora_rank), dtype),
            "kr": jnp.zeros((batch, length, cfg.rope_head_dim), dtype),
            "pos": jnp.full((length,), -1, jnp.int32),
        }
    hd = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, length, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, length, cfg.n_kv_heads, hd), dtype),
        "pos": jnp.full((length,), -1, jnp.int32),
    }
