"""Assigned-architecture model zoo (pure-JAX, dict-pytree parameters)."""
from repro.models.model import (init_model, forward, decode,
                                init_decode_state, prefill_cross_attention,
                                lm_loss)
from repro.models.common import spec_tree_to_shardings, logical_to_physical

__all__ = [
    "init_model", "forward", "decode", "init_decode_state",
    "prefill_cross_attention", "lm_loss",
    "spec_tree_to_shardings", "logical_to_physical",
]
