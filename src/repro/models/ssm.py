"""Attention-free mixers: RWKV6 ("Finch") time-mix and Mamba-1 SSM.

TPU adaptation (DESIGN.md): the reference CUDA kernels for both models are
sequential per-token loops.  We restructure them as *chunked* recurrences —
an outer `lax.scan` over chunks carrying the constant-size recurrent state,
with the inner chunk computed either in parallel matmul form (RWKV6: the
chunked linear-attention identity feeds the MXU) or as a remat'd inner scan
(Mamba: the (d_inner, N) state makes the full (T, d_inner, N) unrolled scan
prohibitively large).  Decode is the plain one-token recurrence.

RWKV6 recurrence per head (head dim D):
    S_t = diag(w_t) S_{t-1} + k_t^T v_t          (S: D x D, w_t data-dependent)
    o_t = r_t (S_{t-1} + diag(u) k_t^T v_t)      (u: per-head "bonus")
Chunked form with A_t = cumprod_{j<=t} w_t (within chunk):
    o_t = (r_t * A_t) S_0 + sum_{j<t} (r_t * A_t / A_j) k_j v_j^T + bonus term
    S_L = diag(A_L) S_0 + sum_j diag(A_L / A_j) k_j v_j^T
float32 state; decays are clamped so A never underflows within a chunk.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init, rms_norm, zeros_init

# ---------------------------------------------------------------------------
# RWKV6
# ---------------------------------------------------------------------------

DECAY_LORA = 64


def init_rwkv6(key, cfg: ModelConfig, dtype) -> Tuple[Dict, Dict]:
    d = cfg.d_model
    ks = jax.random.split(key, 10)
    p, s = {}, {}
    for i, nm in enumerate(("wr", "wk", "wv", "wg")):
        p[nm], s[nm] = dense_init(ks[i], (d, d), ("fsdp", "tp"), dtype)
    p["wo"], s["wo"] = dense_init(ks[4], (d, d), ("tp", "fsdp"), dtype)
    # data-dependent decay: low-rank lora  w_t = exp(-exp(base + tanh(x A) B))
    p["decay_a"], s["decay_a"] = dense_init(ks[5], (d, DECAY_LORA), ("fsdp", None), dtype)
    p["decay_b"], s["decay_b"] = dense_init(ks[6], (DECAY_LORA, d), (None, "tp"), dtype)
    p["decay_base"], s["decay_base"] = zeros_init((d,), ("tp",), jnp.float32)
    p["bonus"], s["bonus"] = zeros_init((d,), ("tp",), jnp.float32)
    # token-shift mixing coefficients (simplified static shift)
    p["mix_rkvg"], s["mix_rkvg"] = (0.5 * jnp.ones((4, d), jnp.float32),
                                    (None, None))
    p["ln_x"], s["ln_x"] = jnp.ones((d,), dtype), (None,)
    return p, s


def _rwkv6_rkvgw(params, cfg: ModelConfig, x, x_prev):
    """Project shifted inputs to r, k, v, g, and per-token decay w.

    x (B, T, d); x_prev (B, 1, d) is the last token of the previous chunk.
    """
    shifted = jnp.concatenate([x_prev, x[:, :-1]], axis=1)
    mix = params["mix_rkvg"]                      # (4, d)

    def mixi(i):
        return x * mix[i] + shifted * (1.0 - mix[i])

    r = mixi(0) @ params["wr"]
    k = mixi(1) @ params["wk"]
    v = mixi(2) @ params["wv"]
    g = jax.nn.silu((mixi(3) @ params["wg"]).astype(jnp.float32))
    dx = jnp.tanh((x.astype(jnp.float32) @ params["decay_a"].astype(jnp.float32)))
    dlog = params["decay_base"] + dx @ params["decay_b"].astype(jnp.float32)
    # clip so that cumprod over a chunk AND its gradient (~1/A^2) stay well
    # inside float32 range: min decay exp(-e^0) ~ 0.368; 0.368^16 ~ 1.2e-7,
    # so 1/A^2 <= ~7e13 << f32 max.  (Decay floor 0.368/token still forgets
    # the state within ~10 tokens — documented approximation, DESIGN.md.)
    w = jnp.exp(-jnp.exp(jnp.clip(dlog, -8.0, 0.0)))      # (B, T, d) in (0,1)
    return r, k, v, g, w


def rwkv6_chunk(r, k, v, w, u, S0, *, head_dim: int):
    """One chunk of the chunked linear-attention recurrence.

    r/k/v/w: (B, L, H, D) float32; u: (H, D); S0: (B, H, D, D).
    Returns (out (B, L, H, D), S_L).
    """
    B, L, H, D = r.shape
    A = jnp.cumprod(w, axis=1)                             # inclusive: prod_{i<=t}
    A_exc = A / w                                          # exclusive: prod_{i<t}
    r_ = r * A_exc     # queries see S_{t-1}: decay prod_{i<t} relative to S0
    k_ = k / A         # keys compensated by their own inclusive decay
    # inter-chunk: o_inter[t] = (r_t * A_{t-1}) @ S0
    o_inter = jnp.einsum("blhd,bhde->blhe", r_, S0)
    # intra-chunk (strictly causal j < t): coeff A_{t-1}/A_j
    att = jnp.einsum("blhd,bmhd->bhlm", r_, k_)
    mask = jnp.tril(jnp.ones((L, L), bool), k=-1)
    att = jnp.where(mask[None, None], att, 0.0)
    o_intra = jnp.einsum("bhlm,bmhe->blhe", att, v)
    # bonus: current token contributes via diag(u)
    o_bonus = jnp.einsum("blhd,blhd,blhe->blhe", r, u[None, None] * k, v)
    out = o_inter + o_intra + o_bonus
    # state: S_L = diag(A_L)(S0 + sum_j diag(1/A_j) k_j v_j^T)
    S_L = A[:, -1][..., None] * (S0 + jnp.einsum("blhd,blhe->bhde", k_, v))
    return out, S_L


def rwkv6_mix(params, cfg: ModelConfig, x, *, chunk: int = 16):
    """Full-sequence RWKV6 time-mix.  x (B, T, d)."""
    B, T, d = x.shape
    D = cfg.ssm_head_dim
    H = d // D
    x_prev = jnp.zeros((B, 1, d), x.dtype)
    r, k, v, g, w = _rwkv6_rkvgw(params, cfg, x, x_prev)
    f32 = lambda a: a.astype(jnp.float32).reshape(B, T, H, D)
    r, k, v, w = f32(r), f32(k), f32(v), f32(w)
    u = params["bonus"].reshape(H, D)

    chunk = min(chunk, T)
    assert T % chunk == 0, (T, chunk)
    n_chunks = T // chunk
    rc = r.reshape(B, n_chunks, chunk, H, D).transpose(1, 0, 2, 3, 4)
    kc = k.reshape(B, n_chunks, chunk, H, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, H, D).transpose(1, 0, 2, 3, 4)
    wc = w.reshape(B, n_chunks, chunk, H, D).transpose(1, 0, 2, 3, 4)

    def step(S, inp):
        rr, kk, vv, ww = inp
        out, S = rwkv6_chunk(rr, kk, vv, ww, u, S, head_dim=D)
        return S, out

    S0 = jnp.zeros((B, H, D, D), jnp.float32)
    _, outs = jax.lax.scan(step, S0, (rc, kc, vc, wc))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, T, d)
    out = rms_norm(out.astype(x.dtype), params["ln_x"], cfg.norm_eps)
    out = (out.astype(jnp.float32) * g).astype(x.dtype)
    return out @ params["wo"]


def rwkv6_decode(params, cfg: ModelConfig, x, state):
    """One token.  state: {"S": (B,H,D,D) f32, "x_prev": (B,1,d)}."""
    B, _, d = x.shape
    D = cfg.ssm_head_dim
    H = d // D
    r, k, v, g, w = _rwkv6_rkvgw(params, cfg, x, state["x_prev"])
    f32 = lambda a: a.astype(jnp.float32).reshape(B, H, D)
    r, k, v, w = f32(r), f32(k), f32(v), f32(w)
    u = params["bonus"].reshape(H, D)
    S = state["S"]
    kv = jnp.einsum("bhd,bhe->bhde", k, v)
    out = jnp.einsum("bhd,bhde->bhe", r, S + u[None, :, :, None] * kv)
    S = w[..., None] * S + kv
    out = out.reshape(B, 1, d)
    out = rms_norm(out.astype(x.dtype), params["ln_x"], cfg.norm_eps)
    out = (out.astype(jnp.float32) * g.reshape(B, 1, d)).astype(x.dtype)
    return out @ params["wo"], {"S": S, "x_prev": x}


def init_rwkv6_state(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    d, D = cfg.d_model, cfg.ssm_head_dim
    H = d // D
    return {"S": jnp.zeros((batch, H, D, D), jnp.float32),
            "x_prev": jnp.zeros((batch, 1, d), dtype)}


# ---------------------------------------------------------------------------
# Mamba-1 (Jamba's SSM mixer)
# ---------------------------------------------------------------------------

def init_mamba(key, cfg: ModelConfig, dtype) -> Tuple[Dict, Dict]:
    d = cfg.d_model
    inner = d * cfg.ssm_expand
    N = cfg.ssm_state_dim
    ks = jax.random.split(key, 7)
    p, s = {}, {}
    p["w_in"], s["w_in"] = dense_init(ks[0], (d, 2 * inner), ("fsdp", "tp"), dtype)
    p["conv_w"], s["conv_w"] = dense_init(ks[1], (cfg.ssm_conv_dim, inner), (None, "tp"), dtype)
    p["conv_b"], s["conv_b"] = zeros_init((inner,), ("tp",), dtype)
    dt_rank = max(1, d // 16)
    p["w_bcdt"], s["w_bcdt"] = dense_init(ks[2], (inner, 2 * N + dt_rank),
                                          ("tp", None), dtype)
    p["dt_bias"], s["dt_bias"] = zeros_init((inner,), ("tp",), jnp.float32)
    p["w_dt"], s["w_dt"] = dense_init(ks[3], (dt_rank, inner), (None, "tp"), dtype)
    # A: (inner, N) negative diagonal, stored as log
    a = jnp.log(jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None], (inner, 1)))
    p["a_log"], s["a_log"] = a, ("tp", None)
    p["d_skip"], s["d_skip"] = jnp.ones((inner,), jnp.float32), ("tp",)
    p["w_out"], s["w_out"] = dense_init(ks[4], (inner, d), ("tp", "fsdp"), dtype)
    return p, s


def _mamba_scan_inputs(params, cfg: ModelConfig, x, conv_state=None):
    """Shared projections.  x (B, T, d) -> (xz gate, u, B_, C_, dt)."""
    inner = cfg.d_model * cfg.ssm_expand
    N = cfg.ssm_state_dim
    xz = x @ params["w_in"]
    u, z = jnp.split(xz, 2, axis=-1)                      # (B, T, inner)
    # depthwise causal conv over time
    K = cfg.ssm_conv_dim
    if conv_state is None:
        pad = jnp.zeros((x.shape[0], K - 1, inner), u.dtype)
    else:
        pad = conv_state
    u_pad = jnp.concatenate([pad, u], axis=1)
    new_conv_state = u_pad[:, -(K - 1):] if K > 1 else None
    conv = sum(u_pad[:, i:i + u.shape[1]] * params["conv_w"][i]
               for i in range(K))
    u = jax.nn.silu((conv + params["conv_b"]).astype(jnp.float32))
    bcdt = u.astype(x.dtype) @ params["w_bcdt"]
    B_, C_, dt_in = bcdt[..., :N], bcdt[..., N:2 * N], bcdt[..., 2 * N:]
    del inner
    dt = jax.nn.softplus(dt_in.astype(jnp.float32) @ params["w_dt"]
                         + params["dt_bias"])             # (B, T, inner)
    return u, z, B_.astype(jnp.float32), C_.astype(jnp.float32), dt, new_conv_state


def mamba_mix(params, cfg: ModelConfig, x, *, chunk: int = 256):
    """Full-sequence Mamba.  Outer scan over chunks, remat'd inner scan."""
    B, T, d = x.shape
    inner = d * cfg.ssm_expand
    N = cfg.ssm_state_dim
    u, z, B_, C_, dt, _ = _mamba_scan_inputs(params, cfg, x)
    A = -jnp.exp(params["a_log"])                          # (inner, N)

    chunk = min(chunk, T)
    assert T % chunk == 0
    n_chunks = T // chunk

    def to_chunks(a):
        return a.reshape(B, n_chunks, chunk, -1).transpose(1, 0, 2, 3)

    uc, bc, cc, dtc = map(to_chunks, (u, B_, C_, dt))

    @jax.checkpoint
    def chunk_body(h, inp):
        uu, bb, ccx, ddt = inp                              # (B, L, ·)

        def step(h, t_inp):
            u_t, b_t, c_t, dt_t = t_inp                     # (B, inner/N)
            da = jnp.exp(dt_t[..., None] * A[None])         # (B, inner, N)
            h = da * h + (dt_t * u_t)[..., None] * b_t[:, None, :]
            y = jnp.einsum("bin,bn->bi", h, c_t)
            return h, y

        h, ys = jax.lax.scan(step, h, (uu.transpose(1, 0, 2), bb.transpose(1, 0, 2),
                                       ccx.transpose(1, 0, 2), ddt.transpose(1, 0, 2)))
        return h, ys.transpose(1, 0, 2)                     # (B, L, inner)

    h0 = jnp.zeros((B, inner, N), jnp.float32)
    _, ys = jax.lax.scan(chunk_body, h0, (uc, bc, cc, dtc))
    y = ys.transpose(1, 0, 2, 3).reshape(B, T, inner)
    y = y + u * params["d_skip"]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return y @ params["w_out"]


def mamba_decode(params, cfg: ModelConfig, x, state):
    """One token.  state: {"h": (B, inner, N) f32, "conv": (B, K-1, inner)}."""
    B = x.shape[0]
    A = -jnp.exp(params["a_log"])
    u, z, B_, C_, dt, new_conv = _mamba_scan_inputs(
        params, cfg, x, conv_state=state["conv"])
    u1, b1, c1, dt1 = u[:, 0], B_[:, 0], C_[:, 0], dt[:, 0]
    da = jnp.exp(dt1[..., None] * A[None])
    h = da * state["h"] + (dt1 * u1)[..., None] * b1[:, None, :]
    y = jnp.einsum("bin,bn->bi", h, c1) + u1 * params["d_skip"]
    y = (y * jax.nn.silu(z[:, 0].astype(jnp.float32))).astype(x.dtype)
    return (y @ params["w_out"])[:, None], {"h": h, "conv": new_conv}


def init_mamba_state(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    inner = cfg.d_model * cfg.ssm_expand
    return {"h": jnp.zeros((batch, inner, cfg.ssm_state_dim), jnp.float32),
            "conv": jnp.zeros((batch, cfg.ssm_conv_dim - 1, inner), dtype)}
