"""Mixture-of-Experts FFN with real expert parallelism.

Three execution strategies over the same weights:

  * "local"      — single device (smoke tests / reduced configs): tokens are
    packed into per-expert capacity buckets and computed with one batched
    einsum per projection (activated-FLOPs only, up to capacity padding — no
    dense all-experts compute);
  * "a2a"        — training / prefill on a mesh: tokens sharded over
    (data x model), experts sharded over "model" (contiguous blocks of
    E_loc = E / M experts per shard).  Top-k pairs are packed into fixed
    capacity-C send buffers, exchanged with `jax.lax.all_to_all` over
    "model", bucket-packed and computed with batched einsums at the owning
    shard, and returned by the inverse all_to_all.  Over-capacity pairs are
    dropped (capacity_factor);
  * "replicated" — decode: a handful of tokens is replicated over "model",
    each shard computes only its local experts' contributions and a psum over
    "model" combines them (weights stay put — the right trade at tiny T).

Expert weights are stored (E, d, f) sharded ("ep", "fsdp", None): expert axis
over "model", d over "data" (FSDP); the a2a path all-gathers the local
experts' d axis per layer, and shard_map's transpose turns that into a
reduce-scatter of the gradients.

Router runs in float32; load-balance aux loss is the switch-style
E * sum_e(frac_e * prob_e).
"""
from __future__ import annotations

import functools
import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import get_abstract_mesh, shard_map
from repro.configs.base import ModelConfig
from repro.models.common import dense_init, logical_to_physical


def init_moe(key, cfg: ModelConfig, dtype) -> Tuple[Dict, Dict]:
    d, E, f = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 7)
    p, s = {}, {}
    p["router"], s["router"] = dense_init(ks[0], (d, E), (None, None), jnp.float32)
    p["w_gate"], s["w_gate"] = dense_init(ks[1], (E, d, f), ("ep", "fsdp", None), dtype)
    p["w_up"], s["w_up"] = dense_init(ks[2], (E, d, f), ("ep", "fsdp", None), dtype)
    p["w_down"], s["w_down"] = dense_init(ks[3], (E, f, d), ("ep", None, "fsdp"), dtype)
    if cfg.n_shared_experts:
        fs = cfg.moe_d_ff * cfg.n_shared_experts
        p["ws_gate"], s["ws_gate"] = dense_init(ks[4], (d, fs), ("fsdp", "tp"), dtype)
        p["ws_up"], s["ws_up"] = dense_init(ks[5], (d, fs), ("fsdp", "tp"), dtype)
        p["ws_down"], s["ws_down"] = dense_init(ks[6], (fs, d), ("tp", "fsdp"), dtype)
    return p, s


def _route(router_w, cfg: ModelConfig, x):
    """x (T, d) -> (ids (T, k), weights (T, k) f32, aux_loss scalar)."""
    logits = x.astype(jnp.float32) @ router_w
    probs = jax.nn.softmax(logits, axis=-1)
    weights, ids = jax.lax.top_k(probs, cfg.top_k)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    # switch-style load balance: E * sum_e frac_tokens_e * mean_prob_e
    E = cfg.n_experts
    frac = jnp.mean(jax.nn.one_hot(ids, E, dtype=jnp.float32), axis=(0, 1))
    prob = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac * prob)
    return ids, weights, aux


def _bucketize(rows, eids, n_buckets: int, cap: int):
    """Pack rows into per-expert capacity buckets (GShard/Switch style).

    rows (P, d); eids (P,) in [0, n_buckets).  Returns
    (buf (n_buckets, cap, d), src (n_buckets, cap) int32, -1 = empty slot).
    Rows beyond an expert's capacity are dropped.

    NOTE: jax.lax.ragged_dot would express this without padding, but its XLA
    lowering on non-TPU backends expands to a DENSE (E, P, d) masked compute —
    catastrophic for both memory and counted FLOPs.  Fixed-capacity buckets
    feed a plain batched einsum, which is also what the MXU prefers.
    """
    P, d = rows.shape
    oh = (eids[:, None] == jnp.arange(n_buckets)[None, :]).astype(jnp.int32)
    pos = jnp.sum((jnp.cumsum(oh, axis=0) - 1) * oh, axis=1)
    slot = jnp.where(pos < cap, pos, cap)                  # cap = trash slot
    src = jnp.arange(P, dtype=jnp.int32)
    buf = jnp.zeros((n_buckets, cap + 1, d), rows.dtype).at[eids, slot].set(rows)
    srcb = jnp.full((n_buckets, cap + 1), -1, jnp.int32).at[eids, slot].set(src)
    return buf[:, :cap], srcb[:, :cap]


def _unbucketize(ybuf, src, P: int):
    """Inverse of _bucketize: scatter (E, cap, d) back to (P, d) rows."""
    d = ybuf.shape[-1]
    src_flat = src.reshape(-1)
    vals = jnp.where((src_flat >= 0)[:, None], ybuf.reshape(-1, d), 0.0)
    return jnp.zeros((P, d), ybuf.dtype).at[jnp.maximum(src_flat, 0)].add(vals)


def _expert_mlp_bucketed(buf, w_gate, w_up, w_down, act):
    """buf (E, cap, d) x (E, d, f) -> (E, cap, d): batched expert MLP."""
    g = jnp.einsum("ecd,edf->ecf", buf, w_gate)
    u = jnp.einsum("ecd,edf->ecf", buf, w_up)
    h = (act(g.astype(jnp.float32)) * u.astype(jnp.float32)).astype(buf.dtype)
    return jnp.einsum("ecf,efd->ecd", h, w_down)


def _capacity(expected: float, cf: float, floor: int = 8) -> int:
    return max(floor, -(-int(expected * cf)) // 8 * 8 + 8)


def moe_ffn_local(params, cfg: ModelConfig, x, act) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Single-device routed FFN.  x (T, d) -> (out (T, d), aux)."""
    T, d = x.shape
    k, E = cfg.top_k, cfg.n_experts
    ids, weights, aux = _route(params["router"], cfg, x)
    flat_ids = ids.reshape(-1)                              # (T*k,)
    cap = _capacity(T * k / E, cfg.capacity_factor)
    buf, src = _bucketize(x[jnp.arange(T * k) // k], flat_ids, E, cap)
    ybuf = _expert_mlp_bucketed(buf, params["w_gate"], params["w_up"],
                                params["w_down"], act)
    ys = _unbucketize(ybuf, src, T * k)                    # (T*k, d)
    w_flat = weights.reshape(-1).astype(ys.dtype)
    out = jnp.zeros((T, d), ys.dtype).at[jnp.arange(T * k) // k].add(
        ys * w_flat[:, None])
    return out.astype(x.dtype), aux


def _pack_send(x, ids, cfg: ModelConfig, M: int, C: int):
    """Pack top-k pairs into per-destination-shard capacity buffers.

    Returns send_x (M, C, d), send_eloc (M, C) i32, send_src (M, C) i32
    (-1 = empty slot), with over-capacity pairs dropped into a trash slot.
    """
    T, d = x.shape
    k = cfg.top_k
    E_loc = cfg.n_experts // M
    flat_ids = ids.reshape(-1)                              # (P,) P = T*k
    dst = flat_ids // E_loc
    eloc = flat_ids - dst * E_loc
    oh = (dst[:, None] == jnp.arange(M)[None, :]).astype(jnp.int32)
    pos = jnp.sum((jnp.cumsum(oh, axis=0) - 1) * oh, axis=1)
    slot = jnp.where(pos < C, pos, C)                       # C = trash slot
    src = jnp.arange(T * k, dtype=jnp.int32)
    send_x = jnp.zeros((M, C + 1, d), x.dtype).at[dst, slot].set(x[src // k])
    send_eloc = jnp.zeros((M, C + 1), jnp.int32).at[dst, slot].set(eloc)
    send_src = jnp.full((M, C + 1), -1, jnp.int32).at[dst, slot].set(src)
    return send_x[:, :C], send_eloc[:, :C], send_src[:, :C]


def _moe_a2a_block(x, router_w, w_gate, w_up, w_down, *, cfg: ModelConfig,
                   M: int, C: int, act, fsdp_axis: str, all_axes: tuple):
    """Per-device body of the a2a strategy (runs inside shard_map)."""
    T, d = x.shape
    k = cfg.top_k
    E_loc = cfg.n_experts // M
    # FSDP all-gather of this shard's expert weights (transposes to
    # reduce-scatter of the gradient)
    w_gate = jax.lax.all_gather(w_gate, fsdp_axis, axis=1, tiled=True)
    w_up = jax.lax.all_gather(w_up, fsdp_axis, axis=1, tiled=True)
    w_down = jax.lax.all_gather(w_down, fsdp_axis, axis=2, tiled=True)

    ids, weights, aux = _route(router_w, cfg, x)
    send_x, send_eloc, send_src = _pack_send(x, ids, cfg, M, C)

    recv_x = jax.lax.all_to_all(send_x, "model", 0, 0, tiled=True)
    recv_eloc = jax.lax.all_to_all(send_eloc, "model", 0, 0, tiled=True)
    recv_valid = jax.lax.all_to_all(send_src >= 0, "model", 0, 0, tiled=True)

    flat_x = recv_x.reshape(M * C, d)
    # invalid slots go to a trash bucket (index E_loc), never computed
    flat_e = jnp.where(recv_valid.reshape(-1), recv_eloc.reshape(-1), E_loc)
    cap = _capacity(M * C / E_loc, cfg.capacity_factor)
    buf, src = _bucketize(flat_x, flat_e, E_loc + 1, cap)
    ybuf = _expert_mlp_bucketed(buf[:E_loc], w_gate, w_up, w_down, act)
    y_flat = _unbucketize(ybuf, src[:E_loc], M * C)
    y_back = jax.lax.all_to_all(y_flat.reshape(M, C, d), "model", 0, 0, tiled=True)

    # combine at source: gate-weight each returned pair into its token
    w_pair = weights.reshape(-1).astype(y_back.dtype)       # (T*k,)
    src = send_src.reshape(-1)                              # send-slot -> pair
    valid = src >= 0
    contrib = y_back.reshape(M * C, d) * jnp.where(
        valid, w_pair[jnp.maximum(src, 0)], 0.0)[:, None]
    out = jnp.zeros((T, d), y_back.dtype).at[
        jnp.maximum(src, 0) // k].add(contrib)
    n_dev = jax.lax.psum(1, all_axes)
    aux = jax.lax.psum(aux, all_axes) / n_dev
    return out.astype(x.dtype), aux


def _moe_replicated_block(x, router_w, w_gate, w_up, w_down, *,
                          cfg: ModelConfig, M: int, act, fsdp_axis: str,
                          all_axes: tuple, reduce_axes: tuple):
    """Decode-time body: tokens replicated over "model", experts local."""
    T, d = x.shape
    k = cfg.top_k
    E_loc = cfg.n_experts // M
    w_gate = jax.lax.all_gather(w_gate, fsdp_axis, axis=1, tiled=True)
    w_up = jax.lax.all_gather(w_up, fsdp_axis, axis=1, tiled=True)
    w_down = jax.lax.all_gather(w_down, fsdp_axis, axis=2, tiled=True)

    ids, weights, aux = _route(router_w, cfg, x)
    me = jax.lax.axis_index("model")
    flat_ids = ids.reshape(-1)
    mine = (flat_ids // E_loc) == me
    eloc = jnp.where(mine, flat_ids - me * E_loc, E_loc)   # E_loc = trash
    cap = _capacity(T * k / (E_loc * M) * E_loc, cfg.capacity_factor)
    buf, src = _bucketize(x[jnp.arange(T * k) // k], eloc, E_loc + 1, cap)
    ybuf = _expert_mlp_bucketed(buf[:E_loc], w_gate, w_up, w_down, act)
    ys = _unbucketize(ybuf, src[:E_loc], T * k)
    w_pair = (weights.reshape(-1) * mine).astype(ys.dtype)
    out = jnp.zeros((T, d), ys.dtype).at[jnp.arange(T * k) // k].add(
        ys * w_pair[:, None])
    out = jax.lax.psum(out, "model")
    # aux only varies over the axes the tokens are sharded on (possibly none)
    if reduce_axes:
        aux = jax.lax.psum(aux, reduce_axes) / jax.lax.psum(1, reduce_axes)
    return out.astype(x.dtype), aux


def _moe_replicated_psum_block(x, router_w, w_gate, w_up, w_down, *,
                               cfg: ModelConfig, M: int, act,
                               reduce_axes: tuple, data_size: int):
    """Decode-time MoE WITHOUT the expert-weight all-gather (beyond-paper).

    The baseline replicated strategy all-gathers (E_loc, d, f) expert weights
    over "data" every layer — ~2 GiB/layer for kimi-k2 to serve a handful of
    tokens.  Decode token batches are tiny, so invert the trade: all-gather
    the TOKENS over the token-sharded axes (~MBs), contract against the LOCAL
    d-shard of the weights, and psum the partial products over "data".  The
    wire now carries activations, never weights.
    """
    T_loc, d = x.shape
    k = cfg.top_k
    E_loc = cfg.n_experts // M
    d_loc = d // data_size
    # tokens are cheap at decode: replicate them across the data axis
    x_full = (jax.lax.all_gather(x, reduce_axes, axis=0, tiled=True)
              if reduce_axes else x)
    T = x_full.shape[0]
    ids, weights, aux = _route(router_w, cfg, x_full)   # identical on shards
    me = jax.lax.axis_index("model")
    me_d = jax.lax.axis_index("data")
    x_d = jax.lax.dynamic_slice_in_dim(x_full, me_d * d_loc, d_loc, axis=1)

    flat_ids = ids.reshape(-1)
    mine = (flat_ids // E_loc) == me
    eloc = jnp.where(mine, flat_ids - me * E_loc, E_loc)
    cap = _capacity(T * k / (E_loc * M) * E_loc, cfg.capacity_factor)
    buf, src = _bucketize(x_d[jnp.arange(T * k) // k], eloc, E_loc + 1, cap)
    buf = buf[:E_loc]
    # partial contraction over my d-shard, psum'd over "data"
    g = jax.lax.psum(jnp.einsum("ecd,edf->ecf", buf, w_gate), "data")
    u = jax.lax.psum(jnp.einsum("ecd,edf->ecf", buf, w_up), "data")
    h = (act(g.astype(jnp.float32)) * u.astype(jnp.float32)).astype(buf.dtype)
    y_loc = jnp.einsum("ecf,efd->ecd", h, w_down)        # (E_loc, cap, d_loc)
    y = jax.lax.all_gather(y_loc, "data", axis=2, tiled=True)
    ys = _unbucketize(y, src[:E_loc], T * k)
    w_pair = (weights.reshape(-1) * mine).astype(ys.dtype)
    out = jnp.zeros((T, d), ys.dtype).at[jnp.arange(T * k) // k].add(
        ys * w_pair[:, None])
    out = jax.lax.psum(out, "model")                     # (T, d) full tokens
    if reduce_axes:   # return to token-sharded layout
        me_lin = jax.lax.axis_index(reduce_axes)
        out = jax.lax.dynamic_slice_in_dim(out, me_lin * T_loc, T_loc, axis=0)
    return out.astype(x.dtype), aux


def moe_ffn(params, cfg: ModelConfig, x, act, *, strategy: str = "local",
            token_spec: P = None):
    """Routed-experts FFN dispatch.  x (T, d) -> (out, aux_loss)."""
    if strategy == "local":
        return moe_ffn_local(params, cfg, x, act)

    mesh = get_abstract_mesh()
    assert mesh is not None and "model" in mesh.axis_names, "needs a mesh"
    M = mesh.shape["model"]
    if cfg.n_experts % M != 0:
        return moe_ffn_local(params, cfg, x, act)
    data_axes = tuple(a for a in mesh.axis_names if a != "model")
    all_axes = tuple(mesh.axis_names)
    # expert weights arrive sharded ("ep","fsdp",·): keep "data" sharding in
    # the block spec and all-gather inside
    wg_spec = P("model", "data", None)
    wd_spec = P("model", None, "data")

    if strategy == "a2a":
        if token_spec is None:
            token_spec = P(tuple(list(data_axes) + ["model"]), None)
        T_glob = x.shape[0]
        n_blocks = math.prod(mesh.shape.values())
        T_loc = T_glob // n_blocks
        C = max(8, -(-int(T_loc * cfg.top_k / M * cfg.capacity_factor)) // 8 * 8)
        body = functools.partial(_moe_a2a_block, cfg=cfg, M=M, C=C, act=act,
                                 fsdp_axis="data", all_axes=all_axes)
        fn = shard_map(
            body, mesh=mesh,
            in_specs=(token_spec, P(None, None), wg_spec, wg_spec, wd_spec),
            out_specs=(token_spec, P()), check_vma=False)
        return fn(x, params["router"], params["w_gate"], params["w_up"],
                  params["w_down"])

    if strategy in ("replicated", "replicated_psum"):
        if token_spec is None:
            token_spec = P(data_axes, None)
        entry = token_spec[0]
        reduce_axes = (() if entry is None
                       else (entry if isinstance(entry, tuple) else (entry,)))
        data_size = mesh.shape["data"]
        if strategy == "replicated_psum" and cfg.d_model % data_size == 0:
            body = functools.partial(
                _moe_replicated_psum_block, cfg=cfg, M=M, act=act,
                reduce_axes=tuple(reduce_axes), data_size=data_size)
        else:
            body = functools.partial(
                _moe_replicated_block, cfg=cfg, M=M, act=act,
                fsdp_axis="data", all_axes=all_axes,
                reduce_axes=tuple(reduce_axes))
        fn = shard_map(
            body, mesh=mesh,
            in_specs=(token_spec, P(None, None), wg_spec, wg_spec, wd_spec),
            out_specs=(token_spec, P()), check_vma=False)
        return fn(x, params["router"], params["w_gate"], params["w_up"],
                  params["w_down"])

    raise ValueError(strategy)


def shared_expert_ffn(params, cfg: ModelConfig, x, act):
    """Dense always-on shared experts (DeepSeek/Kimi style), tp-sharded."""
    g = x @ params["ws_gate"]
    u = x @ params["ws_up"]
    return (act(g.astype(jnp.float32)) * u.astype(jnp.float32)).astype(x.dtype) @ params["ws_down"]
