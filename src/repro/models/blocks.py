"""Decoder/encoder layer assembly: mixer (attn | ssm) + FFN (dense | MoE).

Pre-norm residual blocks.  Layer kinds are fully determined by the config
(`cfg.layer_kind(i)`, `cfg.layer_is_moe(i)`), so periodic stacks (jamba's
1-attention-in-8, MoE-every-other-layer) scan over layer groups of
lcm(attn_period, moe_period) layers (model.py).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.common import activation, dense_init, rms_norm


# ---------------------------------------------------------------------------
# dense FFN
# ---------------------------------------------------------------------------

def init_ffn(key, cfg: ModelConfig, d_ff: int, dtype) -> Tuple[Dict, Dict]:
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    p, s = {}, {}
    if cfg.act in ("silu", "gelu"):
        p["w_gate"], s["w_gate"] = dense_init(ks[0], (d, d_ff), ("fsdp", "tp"), dtype)
        p["w_up"], s["w_up"] = dense_init(ks[1], (d, d_ff), ("fsdp", "tp"), dtype)
        p["w_down"], s["w_down"] = dense_init(ks[2], (d_ff, d), ("tp", "fsdp"), dtype)
    else:  # relu2: non-gated
        p["w_in"], s["w_in"] = dense_init(ks[0], (d, d_ff), ("fsdp", "tp"), dtype)
        p["w_down"], s["w_down"] = dense_init(ks[2], (d_ff, d), ("tp", "fsdp"), dtype)
    return p, s


def apply_ffn(params, cfg: ModelConfig, x):
    act = activation(cfg.act)
    if cfg.act in ("silu", "gelu"):
        g = x @ params["w_gate"]
        u = x @ params["w_up"]
        h = (act(g.astype(jnp.float32)) * u.astype(jnp.float32)).astype(x.dtype)
    else:
        h = act((x @ params["w_in"]).astype(jnp.float32)).astype(x.dtype)
    return h @ params["w_down"]


# ---------------------------------------------------------------------------
# one decoder layer
# ---------------------------------------------------------------------------

def init_layer(key, cfg: ModelConfig, i: int, dtype, *,
               with_cross: bool = False) -> Tuple[Dict, Dict]:
    kind = cfg.layer_kind(i)
    is_moe = cfg.layer_is_moe(i)
    ks = jax.random.split(key, 4)
    p, s = {}, {}
    p["ln1"], s["ln1"] = jnp.ones((cfg.d_model,), dtype), (None,)
    if kind == "attn":
        p["mixer"], s["mixer"] = attn.init_attention(ks[0], cfg, dtype)
    elif cfg.ssm_kind == "rwkv6":
        p["mixer"], s["mixer"] = ssm_mod.init_rwkv6(ks[0], cfg, dtype)
    else:
        p["mixer"], s["mixer"] = ssm_mod.init_mamba(ks[0], cfg, dtype)
    if with_cross:
        p["ln_x"], s["ln_x"] = jnp.ones((cfg.d_model,), dtype), (None,)
        p["cross"], s["cross"] = attn.init_gqa(ks[2], cfg, dtype)
    p["ln2"], s["ln2"] = jnp.ones((cfg.d_model,), dtype), (None,)
    if is_moe:
        p["ffn"], s["ffn"] = moe_mod.init_moe(ks[1], cfg, dtype)
    else:
        p["ffn"], s["ffn"] = init_ffn(ks[1], cfg, cfg.d_ff, dtype)
    return p, s


def _cross_attend_full(params, cfg: ModelConfig, x, memory):
    """Cross-attention (no rope, not causal).  memory (B, S_enc, d)."""
    B, S, _ = x.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = (x @ params["wq"]).reshape(B, S, Hkv, H // Hkv, hd)
    k = (memory @ params["wk"]).reshape(B, -1, Hkv, hd)
    v = (memory @ params["wv"]).reshape(B, -1, Hkv, hd)
    S_enc = k.shape[1]
    q_pos = jnp.arange(S)
    kv_pos = jnp.arange(S_enc)
    out = attn._flash(q, k, v, q_pos, kv_pos, causal=False, window=0)
    return out.reshape(B, S, H * hd).astype(x.dtype) @ params["wo"]


def _cross_attend_cached(params, cfg: ModelConfig, x, xk, xv):
    """Decode-time cross-attention against precomputed memory k/v."""
    B = x.shape[0]
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    q = (x @ params["wq"]).reshape(B, 1, Hkv, H // Hkv, hd)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q.astype(jnp.float32),
                   xk.astype(jnp.float32)) / jnp.sqrt(hd)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, xv.astype(jnp.float32))
    return out.reshape(B, 1, H * hd).astype(x.dtype) @ params["wo"]


def apply_layer_full(params, cfg: ModelConfig, i: int, x, positions, *,
                     causal: bool = True, memory=None,
                     moe_strategy: str = "local", token_spec=None):
    """Training / prefill path.  Returns (x, aux_loss)."""
    kind = cfg.layer_kind(i)
    h = rms_norm(x, params["ln1"], cfg.norm_eps)
    if kind == "attn":
        mix = attn.attend_full(params["mixer"], cfg, h, positions,
                               causal=causal, window=cfg.sliding_window)
    elif cfg.ssm_kind == "rwkv6":
        mix = ssm_mod.rwkv6_mix(params["mixer"], cfg, h)
    else:
        mix = ssm_mod.mamba_mix(params["mixer"], cfg, h)
    x = x + mix
    if memory is not None and "cross" in params:
        hx = rms_norm(x, params["ln_x"], cfg.norm_eps)
        x = x + _cross_attend_full(params["cross"], cfg, hx, memory)
    h2 = rms_norm(x, params["ln2"], cfg.norm_eps)
    aux = jnp.float32(0.0)
    if cfg.layer_is_moe(i):
        B, S, d = h2.shape
        flat = h2.reshape(B * S, d)
        out, aux = moe_mod.moe_ffn(params["ffn"], cfg, flat,
                                   activation(cfg.act),
                                   strategy=moe_strategy,
                                   token_spec=token_spec)
        out = out.reshape(B, S, d)
        if cfg.n_shared_experts:
            out = out + moe_mod.shared_expert_ffn(params["ffn"], cfg, h2,
                                                  activation(cfg.act))
    else:
        out = apply_ffn(params["ffn"], cfg, h2)
    return x + out, aux


def apply_layer_decode(params, cfg: ModelConfig, i: int, x, cache, pos, *,
                       moe_strategy: str = "local", token_spec=None):
    """One-token decode.  cache is this layer's state dict."""
    kind = cfg.layer_kind(i)
    h = rms_norm(x, params["ln1"], cfg.norm_eps)
    new_cache = dict(cache)
    if kind == "attn":
        mix, upd = attn.decode_step(params["mixer"], cfg, h, cache["kv"], pos)
        new_cache["kv"] = upd
    elif cfg.ssm_kind == "rwkv6":
        mix, upd = ssm_mod.rwkv6_decode(params["mixer"], cfg, h, cache["ssm"])
        new_cache["ssm"] = upd
    else:
        mix, upd = ssm_mod.mamba_decode(params["mixer"], cfg, h, cache["ssm"])
        new_cache["ssm"] = upd
    x = x + mix
    if "cross" in params and "xk" in cache:
        hx = rms_norm(x, params["ln_x"], cfg.norm_eps)
        x = x + _cross_attend_cached(params["cross"], cfg, hx,
                                     cache["xk"], cache["xv"])
    h2 = rms_norm(x, params["ln2"], cfg.norm_eps)
    if cfg.layer_is_moe(i):
        B, S, d = h2.shape
        out, _ = moe_mod.moe_ffn(params["ffn"], cfg, h2.reshape(B * S, d),
                                 activation(cfg.act), strategy=moe_strategy,
                                 token_spec=token_spec)
        out = out.reshape(B, S, d)
        if cfg.n_shared_experts:
            out = out + moe_mod.shared_expert_ffn(params["ffn"], cfg, h2,
                                                  activation(cfg.act))
    else:
        out = apply_ffn(params["ffn"], cfg, h2)
    return x + out, new_cache


def init_layer_cache(cfg: ModelConfig, i: int, batch: int, kv_len: int,
                     dtype=jnp.bfloat16, *, enc_len: int = 0):
    """Decode cache for layer i: KV cache / ssm state (+ cross-attn kv)."""
    cache = {}
    if cfg.layer_kind(i) == "attn":
        cache["kv"] = attn.init_cache(cfg, batch, kv_len, dtype)
    elif cfg.ssm_kind == "rwkv6":
        cache["ssm"] = ssm_mod.init_rwkv6_state(cfg, batch, dtype)
    else:
        cache["ssm"] = ssm_mod.init_mamba_state(cfg, batch, dtype)
    if enc_len and cfg.is_encoder_decoder:
        hd = cfg.resolved_head_dim
        cache["xk"] = jnp.zeros((batch, enc_len, cfg.n_kv_heads, hd), dtype)
        cache["xv"] = jnp.zeros((batch, enc_len, cfg.n_kv_heads, hd), dtype)
    return cache
