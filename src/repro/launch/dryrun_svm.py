import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Dry-run of the PAPER'S OWN workload on the production mesh.

Lowers + compiles the two LPD-SVM stages at server scale (the paper's
largest settings: n = 10^7, B = 10^4, p = 256 dense features):

  stage1-gram     K(x, landmarks): rows sharded ("pod","data"), landmark
                  axis sharded "model" — the cuBLAS batch-kernel step.
  stage1-project  G = K_nm @ projector, contraction over the "model"-sharded
                  budget axis (reduce-scatter visible in the schedule).
  stage2-farm     shard_map task farm: 512 OVO/CV binary problems solved
                  concurrently, one per device (the paper's multi-GPU grid
                  search, 11,250 SVMs at a time).

    PYTHONPATH=src python -m repro.launch.dryrun_svm [--multi-pod]
"""
import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import set_mesh
from repro.analysis.hlo import collective_stats
from repro.core.distributed import stage1_gram_sharded, stage1_project_sharded
from repro.core.dual_solver import SolverConfig, TaskBatch, solve_batch
from repro.core.kernel_fn import KernelParams
from repro.launch.mesh import make_production_mesh

RESULTS_DIR = os.path.abspath(os.path.join(
    os.path.dirname(__file__), "..", "..", "..", "results", "dryrun"))


def run(multi_pod: bool, n: int, budget: int, p: int, task_rows: int,
        out_dir: str):
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rows = ("pod", "data") if multi_pod else ("data",)
    n_dev = int(np.prod(list(mesh.shape.values())))
    kp = KernelParams("rbf", gamma=2 ** -7)
    recs = {}

    def record(name, lowered):
        c = lowered.compile()
        ma = c.memory_analysis()
        recs[name] = {
            "temp_bytes": ma.temp_size_in_bytes,
            "argument_bytes": ma.argument_size_in_bytes,
            "cost": {k: v for k, v in c.cost_analysis().items()
                     if k in ("flops", "bytes accessed")},
            "collectives": collective_stats(c.as_text()),
        }
        print(f"[ok] svm-{name} ({mesh_name})  "
              f"temp={ma.temp_size_in_bytes/2**30:.2f}GiB "
              f"flops={recs[name]['cost'].get('flops', 0):.3e}", flush=True)

    with set_mesh(mesh):
        x_sds = jax.ShapeDtypeStruct((n, p), jnp.float32,
                                     sharding=NamedSharding(mesh, P(rows, None)))
        lm_sds = jax.ShapeDtypeStruct((budget, p), jnp.float32,
                                      sharding=NamedSharding(mesh, P("model", None)))
        gram = stage1_gram_sharded(mesh, kp, row_axes=rows)
        record("stage1-gram", gram.lower(x_sds, lm_sds))

        knm_sds = jax.ShapeDtypeStruct((n, budget), jnp.float32,
                                       sharding=NamedSharding(mesh, P(rows, "model")))
        proj_sds = jax.ShapeDtypeStruct((budget, budget), jnp.float32,
                                        sharding=NamedSharding(mesh, P(None, None)))
        project = stage1_project_sharded(mesh, row_axes=rows)
        record("stage1-project", project.lower(knm_sds, proj_sds))

        from repro.core.distributed import stage1_project_sharded_v2
        project_v2 = stage1_project_sharded_v2(mesh, row_axes=rows)
        record("stage1-project-v2", project_v2.lower(knm_sds, proj_sds))

        # stage 2: one binary task per device over a replicated G
        T = n_dev
        n_pad = task_rows
        g_sds = jax.ShapeDtypeStruct((n_pad * 4, budget), jnp.float32,
                                     sharding=NamedSharding(mesh, P(None, None)))
        tspec = NamedSharding(mesh, P(tuple(mesh.axis_names)))
        tb = TaskBatch(
            idx=jax.ShapeDtypeStruct((T, n_pad), jnp.int32, sharding=tspec),
            y=jax.ShapeDtypeStruct((T, n_pad), jnp.float32, sharding=tspec),
            c=jax.ShapeDtypeStruct((T, n_pad), jnp.float32, sharding=tspec),
            alpha0=jax.ShapeDtypeStruct((T, n_pad), jnp.float32, sharding=tspec),
        )
        cfgs = SolverConfig(tol=1e-2, max_epochs=100)

        def farm(G, idx, y, c, a0):
            from repro.core.distributed import solve_tasks_sharded
            return solve_tasks_sharded(G, TaskBatch(idx, y, c, a0), cfgs, mesh)

        record("stage2-farm", jax.jit(farm).lower(g_sds, tb.idx, tb.y, tb.c,
                                                  tb.alpha0))

    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, f"svm-workload__{mesh_name}.json"), "w") as f:
        json.dump({"mesh": mesh_name, "n": n, "budget": budget, "p": p,
                   "stages": recs}, f, indent=1)
    return recs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both", action="store_true")
    ap.add_argument("--n", type=int, default=10_002_432)  # divisible by 512 devices
    ap.add_argument("--budget", type=int, default=10_000)
    ap.add_argument("--p", type=int, default=256)
    ap.add_argument("--task-rows", type=int, default=65536)
    ap.add_argument("--out", default=RESULTS_DIR)
    args = ap.parse_args()
    modes = [False, True] if args.both else [args.multi_pod]
    for mp in modes:
        run(mp, args.n, args.budget, args.p, args.task_rows, args.out)


if __name__ == "__main__":
    main()
