"""Batched greedy serving driver (prefill-by-decode + generation loop).

    PYTHONPATH=src python -m repro.launch.serve --arch tinyllama-1.1b \
        --reduced --batch 4 --prompt-len 32 --gen 64
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.steps import make_serve_step
from repro.models import init_model, init_decode_state, prefill_cross_attention
from repro.models import model as M


def serve(arch: str, *, reduced: bool = True, batch: int = 4,
          prompt_len: int = 32, gen: int = 32, seed: int = 0):
    cfg = get_config(arch, reduced=reduced)
    params, _ = init_model(jax.random.PRNGKey(seed), cfg)
    rng = np.random.default_rng(seed)
    prompts = rng.integers(0, cfg.vocab_size, (batch, prompt_len))
    kv_len = prompt_len + gen
    enc_len = 16 if cfg.is_encoder_decoder else 0
    state = init_decode_state(cfg, batch, kv_len, enc_len=enc_len)
    if cfg.is_encoder_decoder:
        frames = jnp.asarray(rng.normal(size=(batch, enc_len, cfg.d_model)),
                             jnp.bfloat16)
        memory = M._run_encoder(params, cfg, frames)
        state = prefill_cross_attention(params, cfg, state, memory)

    step = jax.jit(make_serve_step(cfg, global_batch=batch))
    # prefill by sequential decode (cache building), then generate
    t0 = time.time()
    tok = None
    for t in range(prompt_len):
        tok, state = step(params, jnp.asarray(prompts[:, t:t + 1], jnp.int32),
                          state, jnp.int32(t))
    generated = []
    for t in range(prompt_len, prompt_len + gen):
        generated.append(np.asarray(tok)[:, 0])
        tok, state = step(params, tok, state, jnp.int32(t))
    dt = time.time() - t0
    gen_arr = np.stack(generated, axis=1)
    print(f"{arch}: generated {gen_arr.shape} in {dt:.2f}s "
          f"({batch * (prompt_len + gen) / dt:.1f} tok/s incl. prefill)")
    print("sample:", gen_arr[0][:16])
    return gen_arr


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()
    serve(args.arch, reduced=args.reduced, batch=args.batch,
          prompt_len=args.prompt_len, gen=args.gen)


if __name__ == "__main__":
    main()
