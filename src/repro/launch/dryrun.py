import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combination.

For each combination this driver produces:
  * the full scanned-layers lowering, compiled on the production mesh —
    memory_analysis() proves the per-device footprint fits, and the HLO is
    kept for the collective schedule;
  * two small UNROLLED "probe" lowerings (1 and 2 layer groups) whose
    cost_analysis() and collective bytes are exact (no scan bodies, single
    flash chunk), extrapolated linearly to the full depth:
        total = probe1 + (n_groups - 1) * (probe2 - probe1)
    (XLA's HloCostAnalysis counts while-loop bodies ONCE, so the full
    lowering's FLOP numbers would undercount scanned layers.)

Results land in results/dryrun/<arch>__<shape>__<mesh>.json, consumed by
`repro.analysis.roofline` and EXPERIMENTS.md.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b \
        --shape train_4k --mesh single           # one combo
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax

from repro.compat import set_mesh
from repro.analysis.hlo import collective_stats
from repro.configs import ARCH_IDS, get_config
from repro.launch import specs as S
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_prefill_step, make_serve_step, make_train_step
from repro.models import attention as attn_mod
from repro.optim import get_optimizer

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def probe_config(cfg, n_groups: int):
    """Reduce depth to `first_dense_layers + n_groups * group` layers."""
    from repro.models.model import _group_size
    g = _group_size(cfg)
    changes = {"n_layers": cfg.first_dense_layers + n_groups * g}
    if cfg.is_encoder_decoder:
        changes["n_encoder_layers"] = n_groups
    return dataclasses.replace(cfg, **changes)


def lower_combo(cfg, shape, mesh, *, unroll: bool):
    """Lower the right step for `shape.mode`; returns (lowered, n_groups)."""
    from repro.models.model import _layout
    B = shape.global_batch
    with set_mesh(mesh):
        params_sds, _ = S.param_specs(cfg, mesh)
        if shape.mode == "train":
            opt = get_optimizer(cfg.optimizer)
            opt_sds = S.opt_state_specs(opt, params_sds)
            step = make_train_step(cfg, opt, mesh, global_batch=B,
                                   unroll=unroll)
            lowered = jax.jit(step, donate_argnums=(0, 1)).lower(
                params_sds, opt_sds, S.batch_specs(cfg, shape, mesh))
        elif shape.mode == "prefill":
            step = make_prefill_step(cfg, mesh, global_batch=B, unroll=unroll)
            lowered = jax.jit(step).lower(params_sds,
                                          S.batch_specs(cfg, shape, mesh))
        else:
            step = make_serve_step(cfg, mesh, global_batch=B, unroll=unroll)
            ins = S.serve_input_specs(cfg, shape, mesh)
            lowered = jax.jit(step, donate_argnums=(2,)).lower(
                params_sds, ins["tokens"], ins["state"], ins["pos"])
    return lowered, _layout(cfg)[2]


def run_one(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
            *, skip_probes: bool = False) -> dict:
    cfg = get_config(arch)
    shape = S.SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    tag = f"{arch}__{shape_name}__{mesh_name}"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "mode": shape.mode, "status": "ok"}
    t0 = time.time()
    try:
        # ---- full lowering: compile proof + memory + collective schedule
        lowered, n_groups = lower_combo(cfg, shape, mesh, unroll=False)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        ma = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
        }
        rec["timings"] = {"lower_s": round(t_lower, 1),
                          "compile_s": round(t_compile, 1)}
        rec["full_cost"] = {k: v for k, v in compiled.cost_analysis().items()
                            if k in ("flops", "bytes accessed")}
        rec["full_collectives"] = collective_stats(compiled.as_text())
        rec["n_groups"] = n_groups

        if not skip_probes:
            # ---- probe extrapolation (exact per-group costs)
            attn_mod.FLASH_KV_CHUNK = 1 << 30
            try:
                probes = []
                for k in (1, 2):
                    pl, _ = lower_combo(probe_config(cfg, k), shape, mesh,
                                        unroll=True)
                    pc = pl.compile()
                    probes.append({
                        "cost": pc.cost_analysis(),
                        "coll": collective_stats(pc.as_text()),
                    })
            finally:
                attn_mod.FLASH_KV_CHUNK = 1024

            def extra(sel):
                # per-group delta clamped >= 0: probe fusion noise can make
                # p2 marginally smaller than p1 for near-zero terms
                p1, p2 = sel(probes[0]), sel(probes[1])
                return p1 + (n_groups - 1) * max(0.0, p2 - p1)

            rec["flops"] = extra(lambda p: p["cost"].get("flops", 0.0))
            rec["bytes_accessed"] = extra(
                lambda p: p["cost"].get("bytes accessed", 0.0))
            rec["collective_bytes"] = extra(
                lambda p: p["coll"]["weighted_bytes"])
            rec["collective_detail"] = {
                "probe1": probes[0]["coll"], "probe2": probes[1]["coll"]}
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec["status"] = "FAIL"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["wall_s"] = round(time.time() - t0, 1)

    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, tag + ".json"), "w") as f:
        json.dump(rec, f, indent=1)
    print(f"[{rec['status']}] {tag}  wall={rec['wall_s']}s "
          f"temp={rec.get('memory', {}).get('temp_bytes', 0)/2**30:.2f}GiB "
          f"flops={rec.get('flops', 0):.3e}", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-probes", action="store_true")
    ap.add_argument("--out", default=os.path.abspath(RESULTS_DIR))
    args = ap.parse_args()

    archs = list(ARCH_IDS) if (args.all or args.arch is None) else [args.arch]
    shapes = list(S.SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    fails = 0
    for multi in meshes:
        for arch in archs:
            for shape in shapes:
                rec = run_one(arch, shape, multi, args.out,
                              skip_probes=args.skip_probes)
                fails += rec["status"] != "ok"
    print(f"done; {fails} failures")
    raise SystemExit(1 if fails else 0)


if __name__ == "__main__":
    main()
