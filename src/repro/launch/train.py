"""LM training driver (runs for real on the host; e2e example substrate).

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-0.6b --reduced \
        --steps 200 --batch 8 --seq 256
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs import get_config
from repro.data import synthetic_token_batches
from repro.launch.steps import make_train_step
from repro.models import init_model
from repro.optim import cosine_schedule, get_optimizer


def train(arch: str, *, reduced: bool = True, steps: int = 100, batch: int = 8,
          seq: int = 256, lr: float = 3e-4, seed: int = 0,
          ckpt_dir: str = None, log_every: int = 10):
    cfg = get_config(arch, reduced=reduced)
    params, _ = init_model(jax.random.PRNGKey(seed), cfg)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    opt = get_optimizer(cfg.optimizer, lr=lr,
                        schedule=cosine_schedule(lr, steps // 10, steps))
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(cfg, opt, global_batch=batch))

    it = synthetic_token_batches(cfg.vocab_size, batch, seq, seed=seed)
    rng = np.random.default_rng(seed)
    losses = []
    t0 = time.time()
    for step in range(steps):
        tokens, targets = next(it)
        b = {"tokens": jnp.asarray(tokens), "targets": jnp.asarray(targets)}
        if cfg.modality == "vision":
            b["prefix"] = jnp.asarray(
                rng.normal(size=(batch, cfg.num_prefix_embeddings,
                                 cfg.d_model)), jnp.bfloat16)
        if cfg.is_encoder_decoder:
            b["frames"] = jnp.asarray(
                rng.normal(size=(batch, 32, cfg.d_model)), jnp.bfloat16)
        params, opt_state, metrics = step_fn(params, opt_state, b)
        losses.append(float(metrics["loss"]))
        if step % log_every == 0 or step == steps - 1:
            dt = time.time() - t0
            tps = (step + 1) * batch * seq / dt
            print(f"step {step:5d}  loss {losses[-1]:.4f}  "
                  f"tok/s {tps:,.0f}", flush=True)
    if ckpt_dir:
        save_checkpoint(ckpt_dir, steps, {"params": params})
        print(f"checkpoint -> {ckpt_dir}")
    print(f"params: {n_params/1e6:.1f}M  first loss {losses[0]:.4f}  "
          f"final loss {losses[-1]:.4f}")
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()
    train(args.arch, reduced=args.reduced, steps=args.steps, batch=args.batch,
          seq=args.seq, lr=args.lr, ckpt_dir=args.ckpt_dir)


if __name__ == "__main__":
    main()
