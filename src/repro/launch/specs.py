"""ShapeDtypeStruct input specs per (architecture x input shape x mesh).

The dry-run never allocates: params, optimizer state, batches and KV caches
are all `jax.ShapeDtypeStruct`s with `NamedSharding`s attached (weak-type
correct, shardable, no device memory).

Input shapes (assigned):
    train_4k       seq  4,096   global_batch 256   train_step
    prefill_32k    seq 32,768   global_batch  32   prefill_step
    decode_32k     seq 32,768   global_batch 128   serve_step (full KV cache)
    long_500k      seq 524,288  global_batch   1   serve_step (windowed cache /
                                                   constant-size SSM state)
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.models.common import logical_to_physical, spec_tree_to_shardings


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str             # train | prefill | decode


SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


def _batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _sds(shape, dtype, mesh, spec: P):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=NamedSharding(mesh, spec))


def batch_entry(mesh: Mesh, batch: int):
    """PartitionSpec ENTRY for the batch dim: axis tuple, or None (replicate)
    when the batch does not divide the batch-axes product (e.g. B=1)."""
    import math
    axes = _batch_axes(mesh)
    n = math.prod(mesh.shape[a] for a in axes)
    return axes if batch % n == 0 else None


# ---------------------------------------------------------------------------
# batches
# ---------------------------------------------------------------------------

def batch_specs(cfg: ModelConfig, shape: InputShape, mesh: Mesh) -> Dict:
    """Training / prefill batch ShapeDtypeStructs."""
    B, S = shape.global_batch, shape.seq_len
    b = batch_entry(mesh, B)
    out = {"tokens": _sds((B, S), jnp.int32, mesh, P(b, None))}
    if shape.mode == "train":
        out["targets"] = _sds((B, S), jnp.int32, mesh, P(b, None))
    if cfg.modality == "vision":
        out["prefix"] = _sds((B, cfg.num_prefix_embeddings, cfg.d_model),
                             jnp.bfloat16, mesh, P(b, None, None))
    if cfg.is_encoder_decoder:
        out["frames"] = _sds((B, cfg.num_prefix_embeddings, cfg.d_model),
                             jnp.bfloat16, mesh, P(b, None, None))
    return out


# ---------------------------------------------------------------------------
# params + optimizer state
# ---------------------------------------------------------------------------

def param_specs(cfg: ModelConfig, mesh: Mesh):
    """(param SDS tree with shardings, logical spec tree)."""
    box = {}

    def build(k):
        p, s = M.init_model(k, cfg)
        box["specs"] = s          # spec tree is static (strings) — side-channel
        return p

    params_shape = jax.eval_shape(build, jax.random.PRNGKey(0))
    specs = box["specs"]
    shardings = spec_tree_to_shardings(specs, mesh, shape_tree=params_shape)
    sds = jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=s),
        params_shape, shardings)
    return sds, specs


def opt_state_specs(optimizer, params_sds):
    """Optimizer-state SDS tree; states inherit parameter shardings where
    shapes match, replicated otherwise (adafactor's factored vectors)."""
    state_shape = jax.eval_shape(optimizer.init, params_sds)

    param_leaves = jax.tree.leaves(params_sds)
    shard_by_shape = {}
    for leaf in param_leaves:
        shard_by_shape.setdefault((leaf.shape, ()), leaf.sharding)
        shard_by_shape[leaf.shape] = leaf.sharding

    mesh = param_leaves[0].sharding.mesh

    def assign(a):
        sh = shard_by_shape.get(a.shape)
        if sh is None:
            sh = NamedSharding(mesh, P())
        return jax.ShapeDtypeStruct(a.shape, a.dtype, sharding=sh)

    return jax.tree.map(assign, state_shape)


# ---------------------------------------------------------------------------
# decode caches
# ---------------------------------------------------------------------------

def decode_kv_len(cfg: ModelConfig, shape: InputShape) -> int:
    if shape.seq_len <= 32768:
        return shape.seq_len
    # long_500k: sub-quadratic only — windowed cache (or SSM state)
    if cfg.decode_window:
        return cfg.decode_window
    if cfg.arch_type == "ssm":
        return 8      # unused dummy (no attention layers)
    raise ValueError(f"{cfg.name}: long_500k needs decode_window or SSM")


def decode_state_specs(cfg: ModelConfig, shape: InputShape, mesh: Mesh):
    """SDS tree for the decode cache, sharded per DESIGN.md rules."""
    B = shape.global_batch
    kv_len = decode_kv_len(cfg, shape)
    enc_len = cfg.num_prefix_embeddings if cfg.is_encoder_decoder else 0
    state_shape = jax.eval_shape(
        lambda: M.init_decode_state(cfg, B, kv_len, enc_len=enc_len))

    m_size = mesh.shape["model"]
    bspec = batch_entry(mesh, B)
    heads_ok = cfg.n_kv_heads > 0 and cfg.n_kv_heads % m_size == 0
    seq_ok = kv_len % m_size == 0
    rwkv_heads = (cfg.d_model // cfg.ssm_head_dim) if cfg.ssm_kind == "rwkv6" else 0
    inner_ok = (cfg.d_model * cfg.ssm_expand) % m_size == 0

    def spec_for(path, a) -> P:
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        stacked = any(getattr(p, "key", None) == "groups" for p in path)
        lead = (None,) if stacked else ()

        def mk(*rest):
            return P(*(lead + rest))

        if name in ("k", "v"):
            if cfg.attention != "mla" and heads_ok:
                return mk(bspec, None, "model", None)
            return mk(bspec, "model" if seq_ok else None, None, None)
        if name in ("xk", "xv"):
            ok = cfg.n_kv_heads % m_size == 0
            return mk(bspec, None, "model" if ok else None, None)
        if name in ("ckv", "kr"):
            return mk(bspec, "model" if seq_ok else None, None)
        if name == "pos":
            return mk(None)
        if name == "S":
            ok = rwkv_heads and rwkv_heads % m_size == 0
            return mk(bspec, "model" if ok else None, None, None)
        if name == "x_prev":
            return mk(bspec, None, None)
        if name == "h":
            return mk(bspec, "model" if inner_ok else None, None)
        if name == "conv":
            return mk(bspec, None, "model" if inner_ok else None)
        return P()

    return jax.tree_util.tree_map_with_path(
        lambda path, a: jax.ShapeDtypeStruct(
            a.shape, a.dtype, sharding=NamedSharding(mesh, spec_for(path, a))),
        state_shape)


def serve_input_specs(cfg: ModelConfig, shape: InputShape, mesh: Mesh) -> Dict:
    B = shape.global_batch
    b = batch_entry(mesh, B)
    return {
        "tokens": _sds((B, 1), jnp.int32, mesh, P(b, None)),
        "state": decode_state_specs(cfg, shape, mesh),
        "pos": jax.ShapeDtypeStruct((), jnp.int32,
                                    sharding=NamedSharding(mesh, P())),
    }


def input_specs(cfg: ModelConfig, shape: InputShape, mesh: Mesh) -> Dict:
    """All model inputs for the given shape (excluding params/opt state)."""
    if shape.mode == "decode":
        return serve_input_specs(cfg, shape, mesh)
    return batch_specs(cfg, shape, mesh)
