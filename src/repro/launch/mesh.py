"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  The single-pod production mesh is 16 x 16 = 256
chips ("data", "model"); the multi-pod mesh is 2 x 16 x 16 = 512 chips
("pod", "data", "model").  TPU v5e numbers are used for the roofline.
"""
from __future__ import annotations

import jax

from repro.compat import AxisType, make_mesh

# TPU v5e hardware constants (per chip) — roofline denominators
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW = 50e9                   # bytes/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh():
    """Small mesh over the real local device(s) — tests and examples."""
    n = len(jax.devices())
    shape = (2, n // 2) if n >= 2 and n % 2 == 0 else (1, n)
    return make_mesh(shape, ("data", "model"),
                     axis_types=(AxisType.Auto,) * 2)
