"""Sharded train / prefill / serve step factories.

Each factory closes over (cfg, mesh) and returns a function suitable both for
real execution (examples, tests on the host mesh) and for `.lower(...SDS...)`
in the dry-run.  MoE strategy selection:

    train / prefill on a >1 "model" mesh  -> "a2a"  (expert-parallel all_to_all,
                                             tokens resharded over data x model)
    decode on a mesh                      -> "replicated" (tokens tiny: keep
                                             experts put, psum over "model")
    no mesh / 1-device mesh               -> "local" ragged_dot
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.launch.specs import InputShape, batch_entry
from repro.models import model as M


def _moe_plan(cfg: ModelConfig, mesh: Optional[Mesh], mode: str, batch: int,
              decode_strategy: str = "replicated_psum"):
    """(strategy, token_spec) for the MoE layers.

    decode_strategy: "replicated_psum" (default — tokens gathered, weights
    stay put; §Perf hillclimb #2) or "replicated" (paper-of-record baseline
    that all-gathers expert weights over the FSDP axis).
    """
    if (mesh is None or cfg.n_experts == 0 or "model" not in mesh.axis_names
            or mesh.shape["model"] == 1
            or cfg.n_experts % mesh.shape["model"] != 0):
        return "local", None
    if mode in ("train", "prefill"):
        axes = tuple(a for a in mesh.axis_names)
        return "a2a", P(axes, None)
    b = batch_entry(mesh, batch)
    return decode_strategy, P(b, None)


def make_train_step(cfg: ModelConfig, optimizer, mesh: Optional[Mesh] = None,
                    *, global_batch: int = 0, remat: bool = True,
                    unroll: bool = False):
    prefix = cfg.num_prefix_embeddings if cfg.modality == "vision" else 0
    strategy, token_spec = _moe_plan(cfg, mesh, "train", global_batch)

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            logits, aux = M.forward(p, cfg, batch, moe_strategy=strategy,
                                    token_spec=token_spec, remat=remat,
                                    unroll=unroll)
            loss = M.lm_loss(logits, batch["targets"], prefix_len=prefix)
            return loss + cfg.router_aux_coef * aux, (loss, aux)

        (total, (loss, aux)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        new_params, new_state = optimizer.update(grads, opt_state, params)
        metrics = {"loss": loss, "aux": aux, "total": total}
        return new_params, new_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, mesh: Optional[Mesh] = None,
                      *, global_batch: int = 0, unroll: bool = False):
    strategy, token_spec = _moe_plan(cfg, mesh, "prefill", global_batch)

    def prefill_step(params, batch):
        logits, _ = M.forward(params, cfg, batch, moe_strategy=strategy,
                              token_spec=token_spec, remat=False,
                              unroll=unroll)
        # serving prefill: next-token logits for the last position
        return logits[:, -1, :]

    return prefill_step


def make_serve_step(cfg: ModelConfig, mesh: Optional[Mesh] = None,
                    *, global_batch: int = 0, greedy: bool = True,
                    unroll: bool = False,
                    moe_decode: str = "replicated_psum"):
    strategy, token_spec = _moe_plan(cfg, mesh, "decode", global_batch,
                                     decode_strategy=moe_decode)

    def serve_step(params, tokens, state, pos):
        logits, state = M.decode(params, cfg, tokens, state, pos,
                                 moe_strategy=strategy, token_spec=token_spec,
                                 unroll=unroll)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, state

    return serve_step
