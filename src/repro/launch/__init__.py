"""Launch layer: production mesh, sharded train/serve steps, dry-run."""
