"""End-to-end paper driver: backbone features -> LPD-SVM classifier head.

This is the paper's ImageNet experiment in miniature: a (reduced) assigned
architecture plays VGG-16, its pooled hidden states are the feature vectors,
and LPD-SVM trains the one-vs-one large-margin classifier on top.

    PYTHONPATH=src python -m repro.launch.train_svm --arch qwen3-0.6b \
        --classes 10 --n 4000 --budget 256
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import KernelParams, LPDSVM, median_gamma
from repro.models import init_model
from repro.models import model as M


def extract_features(cfg, params, tokens: np.ndarray, batch: int = 32):
    """Mean-pooled final hidden states as feature vectors."""
    outs = []

    @jax.jit
    def embed(toks):
        # forward up to final norm; logits path skipped via tiny trick:
        # reuse forward but take pre-unembed activations by computing
        # logits @ nothing — instead rerun the trunk here.
        x = params["embed"][toks]
        positions = jnp.arange(x.shape[1])
        from repro.models.model import _layout
        from repro.models import blocks
        pro, g, n_groups = _layout(cfg)
        for i, lp in enumerate(params["prologue"]):
            x, _ = blocks.apply_layer_full(lp, cfg, i, x, positions)

        def body(c, gp):
            x = c
            for j in range(g):
                x, _ = blocks.apply_layer_full(gp[j], cfg, pro + j, x, positions)
            return x, None

        if n_groups:
            x, _ = jax.lax.scan(body, x, params["groups"])
        from repro.models.common import rms_norm
        x = rms_norm(x, params["final_ln"], cfg.norm_eps)
        return jnp.mean(x.astype(jnp.float32), axis=1)

    for s in range(0, tokens.shape[0], batch):
        outs.append(np.asarray(embed(jnp.asarray(tokens[s:s + batch]))))
    return np.concatenate(outs, axis=0)


def class_conditioned_tokens(n: int, n_classes: int, seq: int, vocab: int,
                             seed: int = 0, mix: float = 0.5):
    """Synthetic 'documents' whose token statistics depend on the class."""
    rng = np.random.default_rng(seed)
    y = rng.integers(0, n_classes, size=n)
    # each class owns a band of preferred tokens
    band = vocab // (n_classes + 1)
    toks = rng.integers(0, vocab, size=(n, seq))
    for c in range(n_classes):
        mask = rng.random((n, seq)) < mix
        mask &= (y == c)[:, None]
        toks = np.where(mask, rng.integers(c * band, (c + 1) * band,
                                           size=(n, seq)), toks)
    return toks.astype(np.int32), y


def train_from_libsvm(args, stream_config):
    """Out-of-core end-to-end path: LIBSVM file -> CSR -> streamed stage 1
    (`compute_factor_streamed_csr`) -> streamed stage 2.  The dense (n, p)
    matrix is never materialised; training rows are scored from G.

    With ``--shard-dir`` the text is parsed ONCE into the checksummed shard
    store (`core/shards.py`) and this — and every later — run streams the
    verified binary shards instead (`compute_factor_streamed_shards`): a
    reused store performs zero text parses."""
    from repro.core import KernelParams, LPDSVM, StreamConfig
    from repro.core.streaming import (compute_factor_streamed_csr,
                                      compute_factor_streamed_shards)

    cfg = stream_config or StreamConfig()
    kp_gamma = args.gamma
    t0 = time.time()
    if args.shard_dir:
        import os
        from repro.core.shards import ShardStoreStats, open_or_ingest
        sstats = ShardStoreStats()
        store, ingested = open_or_ingest(
            args.libsvm, os.path.join(args.shard_dir, "data"),
            n_features=args.n_features or None,
            shard_rows=cfg.shard_rows,
            dtype="int8" if args.stage1_dtype == "int8" else "f32",
            on_bad_row=args.on_bad_row, verify=cfg.verify_shards,
            retries=0 if cfg.fail_fast else cfg.max_retries,
            retry_backoff=cfg.retry_backoff, stats=sstats, trace=cfg.trace)
        t_read = time.time() - t0
        n, p = store.n, store.cols
        labels = store.labels()
        skipped = int(store.manifest.get("rows_skipped", 0))
        if skipped:
            print(f"libsvm: skipped {skipped} bad row(s) (--on-bad-row skip)")
        if kp_gamma is None:
            rows = np.random.default_rng(0).choice(n, min(256, n),
                                                   replace=False)
            kp_gamma = median_gamma(store.gather_rows(np.sort(rows)))
        kp = KernelParams("rbf", gamma=kp_gamma)
        t0 = time.time()
        factor = compute_factor_streamed_shards(
            store, kp, args.budget, key=jax.random.PRNGKey(0), config=cfg)
        src = "ingested (parsed once)" if ingested else "reused (no parse)"
        shard_line = (f"shards: {store.n_shards} x {store.shard_rows} rows "
                      f"({store.dtype}) under {args.shard_dir} — {src}")
    else:
        from repro.data import IngestStats, read_libsvm
        ingest = IngestStats()
        data = read_libsvm(args.libsvm, n_features=args.n_features or None,
                           on_bad_row=args.on_bad_row, stats=ingest)
        t_read = time.time() - t0
        n, p = data.n, data.n_features
        labels = data.labels
        if ingest.rows_skipped:
            print(f"libsvm: skipped {ingest.rows_skipped} bad row(s) "
                  f"(--on-bad-row skip)")
        if kp_gamma is None:
            # densify only a row subsample for the heuristic (median_gamma's
            # own sampler never sees the CSR rows it was not handed)
            rows = np.random.default_rng(0).choice(n, min(256, n),
                                                   replace=False)
            kp_gamma = median_gamma(data.densify_rows(np.sort(rows)))
        kp = KernelParams("rbf", gamma=kp_gamma)
        t0 = time.time()
        factor = compute_factor_streamed_csr(data, kp, args.budget,
                                             key=jax.random.PRNGKey(0),
                                             config=cfg)
        shard_line = None
    args.gamma = kp_gamma
    t_factor = time.time() - t0
    svm = LPDSVM(kp, C=args.C, budget=args.budget, tol=1e-2,
                 stream=True, stream_config=stream_config,
                 polish=args.polish, polish_levels=args.polish_levels)
    svm.fit(None, labels, factor=factor)
    svm.stats.stage1_seconds = t_factor   # factor was computed out here
    err = float(np.mean(svm.predict_from_factor() != labels))
    print(f"libsvm: {n} rows x {p} features in {t_read:.1f}s")
    if shard_line:
        print(shard_line)
        st = sstats
        line = (f"shard io: {st.shards_read} reads "
                f"{st.bytes_read / 2**20:.1f} MiB "
                f"({st.read_gbps:.2f} GB/s), {st.verifications} verified")
        if st.checksum_failures:
            line += (f", {st.checksum_failures} corrupt -> "
                     f"{st.quarantined} quarantined / {st.rebuilt} rebuilt")
        if st.retries:
            line += f", {st.retries} retried"
        print(line)
    _report(svm)
    print(f"train error: {err:.4f}")
    return err


def _report(svm):
    s1 = svm.stats.stage1_stats
    s2 = svm.stats.stage2_stats
    print(f"stage1 {svm.stats.stage1_seconds:.2f}s (rank "
          f"{svm.stats.effective_rank}"
          f"{', streamed' if svm.stats.stage1_streamed else ''})  "
          f"stage2 {svm.stats.stage2_seconds:.2f}s "
          f"({svm.stats.n_tasks} binary SVMs"
          f"{', streamed' if svm.stats.stage2_streamed else ''})")
    if s1 is not None:
        scales = (f" ({s1.bytes_scales / 2**10:.1f} KiB scales)"
                  if s1.bytes_scales else "")
        print(f"stage1 stream: {s1.chunks} x {s1.wire_dtype} chunks, "
              f"prefetch {s1.prefetch_final}, "
              f"{s1.bytes_h2d / 2**20:.1f} MiB H2D{scales}")
    if s2 is not None:
        print(f"stage2 stream: tile {s2.tile_rows} rows x {s2.block_dtype} "
              f"blocks, {s2.n_devices} device(s), prefetch "
              f"{s2.prefetch_final}, {s2.epochs} epochs, "
              f"{s2.bytes_h2d / 2**20:.1f} MiB H2D"
              + (f" ({s2.bytes_scales / 2**10:.1f} KiB scales)"
                 if s2.bytes_scales else "")
              + f" / {s2.bytes_d2h / 2**20:.1f} MiB D2H, "
              f"active {s2.active_history}")
        # bytes_miss accrues even with the cache off (the cross-run
        # identity needs it); only report when the cache actually ran
        if s2.bytes_hit or s2.cache_resident_bytes:
            total = s2.bytes_hit + s2.bytes_miss
            print(f"stage2 cache: {s2.bytes_hit / 2**20:.1f} MiB hit / "
                  f"{s2.bytes_miss / 2**20:.1f} MiB miss "
                  f"({100 * s2.bytes_hit / total:.0f}% of compacted G bytes "
                  f"served from HBM), peak resident "
                  f"{s2.cache_resident_bytes / 2**20:.1f} MiB, "
                  f"{s2.cache_evictions} evictions")
    tr = svm.stats.polish_trace
    if tr is not None:
        for lv in tr.levels:
            finite = np.isfinite(lv.duality_gap)
            gap = float(np.max(lv.duality_gap[finite])) if finite.any() \
                else float("nan")
            print(f"polish level {lv.fraction:.4g}: {lv.n_rows} rows, "
                  f"tol {lv.tol:.3g}, {int(lv.epochs.max())} epochs max, "
                  f"gap {gap:.3g}, {lv.row_visits} row-visits"
                  f"{', streamed' if lv.streamed else ''}")
        print(f"polish total: {tr.total_row_visits} row-visits over "
              f"{len(tr.levels)} levels")


def _report_grid(res, gammas, Cs):
    """Per-grid summary for --grid-*: selection, errors, and — when the grid
    task farm ran — the one-stream stats each gamma's whole (C x folds) grid
    trained under."""
    print(f"grid: {len(gammas)} gammas x {len(Cs)} Cs, "
          f"{res.n_binary_solved} binary SVMs, "
          f"stage1 {res.stage1_seconds:.2f}s stage2 {res.stage2_seconds:.2f}s")
    for gi, gamma in enumerate(gammas):
        errs = " ".join(f"{e:.4f}" for e in res.errors[gi])
        line = f"  gamma {gamma:.4g}: err [{errs}]"
        if res.stream_stats is not None and res.stream_stats[gi] is not None:
            st = res.stream_stats[gi]
            line += (f"  farm: {st.epochs} epochs, "
                     f"{st.bytes_h2d / 2**20:.1f} MiB H2D "
                     f"({st.bytes_g / 2**20:.1f} MiB G blocks), "
                     f"{st.bytes_d2h / 2**20:.1f} MiB D2H, "
                     f"tile {st.tile_rows} x {st.block_dtype}")
        print(line)
    print(f"grid best: gamma={res.best_gamma:.4g} C={res.best_C:.4g} "
          f"err={res.best_error:.4f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--classes", type=int, default=10)
    ap.add_argument("--n", type=int, default=2000)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--budget", type=int, default=256)
    ap.add_argument("--C", type=float, default=8.0)
    ap.add_argument("--gamma", type=float, default=None)
    ap.add_argument("--device-budget-mb", type=float, default=0.0,
                    help="device working-set budget for BOTH stages; >0 "
                         "auto-routes onto the out-of-core pipelines when "
                         "the monolithic working set exceeds it")
    ap.add_argument("--chunk-rows", type=int, default=0,
                    help="fixed stage-1 streaming chunk size (0 = derive from "
                         "budget; without --device-budget-mb this forces "
                         "streaming)")
    ap.add_argument("--tile-rows", type=int, default=0,
                    help="fixed stage-2 G block rows (0 = derive from budget)")
    ap.add_argument("--stream", action="store_true",
                    help="force the out-of-core pipelines (both stages) "
                         "regardless of budget")
    ap.add_argument("--block-dtype", choices=("f32", "bf16", "int8"),
                    default="f32",
                    help="wire dtype of streamed stage-2 G blocks; bf16 "
                         "halves the H2D bytes (upcast on device), int8 "
                         "quarters them (per-row-group scale/zero codec, "
                         "fused device dequant); like --tile-rows, a non-f32 "
                         "dtype forces streaming without a budget")
    ap.add_argument("--stage1-dtype", choices=("f32", "int8"), default="f32",
                    help="wire dtype of streamed stage-1 x chunks; int8 "
                         "quarters the chunk H2D bytes with dequantisation "
                         "fused into the gram kernel (forces streaming "
                         "without a budget)")
    ap.add_argument("--quant-group-rows", type=int, default=0,
                    help="rows per int8 scale group (0 = default 32; both "
                         "stages)")
    ap.add_argument("--no-overlap", action="store_true",
                    help="disable the overlapped multi-device stage-2 task "
                         "farm (serial per-device streams; single-device "
                         "hosts are unaffected)")
    ap.add_argument("--cache-budget-mb", type=float, default=-1.0,
                    help="HBM allowance for the stage-2 hot-row block cache "
                         "per device (<0 = the unused remainder of the "
                         "device budget, the default; 0 disables caching)")
    ap.add_argument("--no-cache", action="store_true",
                    help="disable the stage-2 HBM block cache (every "
                         "compacted cheap epoch re-ships the active-row "
                         "union over H2D)")
    ap.add_argument("--polish", action="store_true",
                    help="coarse-to-fine warm-started stage 2: solve a "
                         "nested subsample ladder (n/16 -> n/4 -> n by "
                         "default) with tolerance annealing so the full-data "
                         "pass is a short polish (core/polish.py)")
    ap.add_argument("--polish-levels", type=int, default=3,
                    help="depth of the polish ladder (default 3)")
    ap.add_argument("--grid-cs", default=None,
                    help="comma-separated C grid (e.g. '1,4,16'); with "
                         "--grid-gammas runs the k-fold CV grid search "
                         "instead of a single fit — when the cells stream, "
                         "each gamma's whole (C x folds) grid trains as ONE "
                         "task farm over a single G stream")
    ap.add_argument("--grid-gammas", default=None,
                    help="comma-separated gamma grid for --grid-cs "
                         "(default: the median heuristic's single gamma)")
    ap.add_argument("--grid-folds", type=int, default=3,
                    help="CV folds for the grid search (default 3)")
    ap.add_argument("--libsvm", default=None,
                    help="train from a LIBSVM-format file instead of backbone "
                         "features (end-to-end out-of-core path)")
    ap.add_argument("--n-features", type=int, default=0,
                    help="feature count for --libsvm (0 = infer from file)")
    ap.add_argument("--on-bad-row", choices=("raise", "skip"),
                    default="raise",
                    help="--libsvm ingest policy for malformed / non-finite "
                         "rows: 'raise' (default) aborts naming the line, "
                         "'skip' drops them and reports the count")
    ap.add_argument("--shard-dir", default=None, metavar="DIR",
                    help="durable disk tier (core/shards.py): with --libsvm, "
                         "parse the text ONCE into checksummed binary shards "
                         "under DIR/data and stream every run from them "
                         "(re-runs skip the parse entirely); also the home "
                         "of --spill-g stores; forces the streamed pipelines")
    ap.add_argument("--shard-rows", type=int, default=4096,
                    help="rows per shard file (default 4096; multiple of the "
                         "int8 group size so stored scale groups stay "
                         "global-row-aligned)")
    ap.add_argument("--spill-g", action="store_true",
                    help="stream the stage-1 factor G into f32 shards under "
                         "--shard-dir and run stage 2 straight off the disk "
                         "tier (the (n, B') host buffer never materialises)")
    ap.add_argument("--verify-shards", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="recompute each shard's checksum on every read "
                         "(default on; corrupt shards are quarantined and "
                         "rebuilt from source — --no-verify-shards trusts "
                         "the bytes)")
    ap.add_argument("--checkpoint-dir", default=None, metavar="DIR",
                    help="fault-tolerance state directory (core/resilience.py)"
                         ": stage 1 streams G into a resumable memmap there, "
                         "stage 2 snapshots full solver state at epoch "
                         "boundaries; forces the streamed pipelines")
    ap.add_argument("--checkpoint-every", type=int, default=1,
                    help="snapshot stage 2 every N full passes (default 1; "
                         "needs --checkpoint-dir)")
    ap.add_argument("--resume", action="store_true",
                    help="resume from the newest snapshot in "
                         "--checkpoint-dir; bit-equal to the uninterrupted "
                         "run when killed at an epoch boundary")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="record the run's pipeline timeline (core/trace.py) "
                         "and export it as Chrome-trace JSON loadable in "
                         "Perfetto / chrome://tracing")
    ap.add_argument("--trace-summary", action="store_true",
                    help="print the aggregated trace summary (seconds per "
                         "category, effective H2D GB/s, rows/s, overlap "
                         "efficiency) after the run; implies tracing")
    ap.add_argument("--verbose", action="store_true",
                    help="print one progress line per stage-2 epoch (active "
                         "rows, bytes, cache hit rate, rows/s, max KKT "
                         "violation); implies tracing")
    args = ap.parse_args()
    if args.chunk_rows < 0:
        ap.error(f"--chunk-rows must be >= 0, got {args.chunk_rows}")
    if args.tile_rows < 0:
        ap.error(f"--tile-rows must be >= 0, got {args.tile_rows}")
    if args.polish_levels < 1:
        ap.error(f"--polish-levels must be >= 1, got {args.polish_levels}")
    if args.grid_folds < 2:
        ap.error(f"--grid-folds must be >= 2, got {args.grid_folds}")
    if args.grid_gammas is not None and args.grid_cs is None:
        ap.error("--grid-gammas requires --grid-cs")
    if args.checkpoint_every < 0:
        ap.error(f"--checkpoint-every must be >= 0, got {args.checkpoint_every}")
    if args.resume and not args.checkpoint_dir:
        ap.error("--resume requires --checkpoint-dir")
    if args.shard_rows < 1:
        ap.error(f"--shard-rows must be >= 1, got {args.shard_rows}")
    if args.spill_g and not args.shard_dir:
        ap.error("--spill-g requires --shard-dir")

    stream_config = None
    # An explicit chunk/tile size or wire dtype with no budget is a request
    # to stream, not a hint to the (roomy) default budget; --stream forces.
    from repro.core.quant import GROUP_ROWS
    if args.quant_group_rows < 0:
        ap.error(f"--quant-group-rows must be >= 0, got {args.quant_group_rows}")
    quant = args.block_dtype != "f32" or args.stage1_dtype != "f32"
    # Checkpoints only exist on the streamed paths, so --checkpoint-dir is a
    # request to stream (like an explicit chunk/tile size with no budget).
    force = args.stream or bool(args.checkpoint_dir) or bool(args.shard_dir) \
        or ((args.chunk_rows > 0 or args.tile_rows > 0
             or quant) and args.device_budget_mb <= 0)
    cache_off = args.no_cache or args.cache_budget_mb == 0
    if (args.device_budget_mb > 0 or args.chunk_rows > 0
            or args.tile_rows > 0 or args.stream or quant or args.no_overlap
            or cache_off or args.cache_budget_mb > 0 or args.checkpoint_dir
            or args.shard_dir):
        from repro.core import StreamConfig
        stream_config = StreamConfig(
            device_budget_bytes=int(args.device_budget_mb * 2**20) or 2 << 30,
            chunk_rows=args.chunk_rows or None,
            tile_rows=args.tile_rows or None,
            block_dtype=args.block_dtype,
            stage1_dtype=args.stage1_dtype,
            quant_group_rows=args.quant_group_rows or GROUP_ROWS,
            overlap_devices=not args.no_overlap,
            cache_blocks=not cache_off,
            cache_budget_bytes=(int(args.cache_budget_mb * 2**20)
                                if args.cache_budget_mb > 0 else None),
            checkpoint_dir=args.checkpoint_dir,
            checkpoint_every=(args.checkpoint_every
                              if args.checkpoint_dir else 0),
            resume=args.resume,
            shard_dir=args.shard_dir,
            shard_rows=args.shard_rows,
            spill_g=args.spill_g,
            verify_shards=args.verify_shards)
        if args.checkpoint_dir:
            print(f"checkpoint: {args.checkpoint_dir} (every "
                  f"{args.checkpoint_every} full passes"
                  f"{', resuming' if args.resume else ''})")

    # Observability (core/trace.py): any of the three flags arms a tracer.
    # It is installed process-wide — every instrumented hot path resolves it
    # even when no StreamConfig exists — and additionally threaded through
    # `StreamConfig.trace` when one does.  Export/summary run in `finally`
    # so a failed run still leaves a timeline to look at.
    tracer = None
    if args.trace or args.trace_summary or args.verbose:
        from repro.core.trace import ProgressPrinter, Tracer, install
        tracer = Tracer()
        if args.verbose:
            tracer.add_listener(ProgressPrinter())
        if stream_config is not None:
            stream_config = dataclasses.replace(stream_config, trace=tracer)
        install(tracer)
    try:
        return _run(args, ap, stream_config, force)
    finally:
        if tracer is not None:
            from repro.core.trace import uninstall
            uninstall()
            if args.trace:
                tracer.export(args.trace)
                print(f"trace: {tracer.n_events} events -> {args.trace}")
            if args.trace_summary:
                print(tracer.summary())


def _run(args, ap, stream_config, force):
    if args.libsvm:
        if args.grid_cs is not None:
            ap.error("--grid-cs is not supported with --libsvm")
        return train_from_libsvm(args, stream_config)

    cfg = get_config(args.arch, reduced=True)
    params, _ = init_model(jax.random.PRNGKey(0), cfg)

    t0 = time.time()
    toks, y = class_conditioned_tokens(args.n, args.classes, args.seq,
                                       cfg.vocab_size)
    feats = extract_features(cfg, params, toks)
    t_feat = time.time() - t0
    if args.gamma is None:
        args.gamma = median_gamma(feats)
    n_tr = int(args.n * 0.8)

    if args.grid_cs is not None:
        from repro.core import grid_search
        Cs = [float(v) for v in args.grid_cs.split(",")]
        gammas = ([float(v) for v in args.grid_gammas.split(",")]
                  if args.grid_gammas else [args.gamma])
        t0 = time.time()
        res = grid_search(feats[:n_tr], y[:n_tr], gammas, Cs,
                          budget=args.budget, folds=args.grid_folds,
                          stream=True if force else None,
                          stream_config=stream_config, polish=args.polish,
                          polish_levels=args.polish_levels)
        print(f"features: {feats.shape} in {t_feat:.1f}s; "
              f"grid search {time.time() - t0:.1f}s")
        _report_grid(res, gammas, Cs)
        svm = LPDSVM(KernelParams("rbf", gamma=res.best_gamma), C=res.best_C,
                     budget=args.budget, tol=1e-2,
                     stream=True if force else None,
                     stream_config=stream_config)
        svm.fit(feats[:n_tr], y[:n_tr])
        err = svm.error(feats[n_tr:], y[n_tr:])
        print(f"test error: {err:.4f} (chance {1 - 1/args.classes:.2f})")
        return err

    svm = LPDSVM(KernelParams("rbf", gamma=args.gamma), C=args.C,
                 budget=args.budget, tol=1e-2,
                 stream=True if force else None,
                 stream_config=stream_config,
                 polish=args.polish, polish_levels=args.polish_levels)
    svm.fit(feats[:n_tr], y[:n_tr])
    err = svm.error(feats[n_tr:], y[n_tr:])
    print(f"features: {feats.shape} in {t_feat:.1f}s")
    _report(svm)
    print(f"test error: {err:.4f} (chance {1 - 1/args.classes:.2f})")
    return err


if __name__ == "__main__":
    main()
