"""Device-resident hot-row block cache for streamed stage 2.

The paper's recipe makes stage-2 row access *skewed*: after a few passes of
adaptive shrinking the active set is small and stable, yet the streamed
solver still re-ships every active row over H2D each cheap epoch.  This
module is the missing memory-hierarchy tier (disk -> host RAM -> wire ->
**HBM cache**): the shrinking-compacted active-row union is pinned
device-side under the unused remainder of `StreamConfig.device_budget_bytes`,
cheap epochs consult the cache before shipping, and only misses cross the
bus — so cheap epochs become cache-hit epochs with ~zero G H2D.

Correctness is *byte-exact and trajectory-exact by construction*: a cache
entry stores the exact device arrays the H2D put produced — the f32 block,
the bf16 block (upcast per use), or the int8 `QuantBlock` values + its
global-row-aligned scale table (dequantised per use, still fused) — so a
cached row decodes bit-identically to a streamed one.  PR 5's global group
scales are what make the int8 tier safe: the cached codes were encoded
against the same global stats every shared-pass block uses, so hit and miss
epochs optimise ONE consistent problem.

Eviction is by **violation recency**: when the union does not fit the cache
budget, blocks whose rows most recently violated KKT (smallest `unchanged`
counters — the rows the solver will revisit soonest) are pinned first and
the cold tail keeps streaming.  The pin plan is recomputed at every
shrinking compaction (`plan`), which is also the invalidation point: entries
whose row set no longer appears in the compacted block list are dropped.
Because keys are content-addressed by the global row ids in the block, a
*stable* active set re-pins its existing entries across compactions for
free — no re-ship on re-compaction.

The cache is deliberately payload-agnostic (entries carry opaque device
payloads plus their wire byte size), so its planning/eviction logic is pure
host bookkeeping, property-testable without a device
(`tests/test_property.py`).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.streaming import BYTES_F32, StreamConfig


def block_key(rows: np.ndarray, wire: str) -> bytes:
    """Content-addressed cache key of one compacted block: the GLOBAL row
    ids it carries plus the wire dtype (an f32 and an int8 encoding of the
    same rows are different device payloads).  Stable across compactions
    whenever the union slices into the same tile groups."""
    return wire.encode() + b"|" + np.ascontiguousarray(rows, np.int64).tobytes()


@dataclasses.dataclass
class CacheEntry:
    """One pinned block: opaque device payload + the wire bytes it replaces.

    ``payload`` is whatever the engine's decode step consumes — a device f32
    or bf16 array, or an (int8 values, (ng, 2) scales, group) triple for the
    quantised wire.  ``nbytes`` is the block's WIRE size (== its device
    residency for every supported format), the quantity both the budget
    check and the hit/miss byte accounting use."""

    payload: object
    nbytes: int


class HotRowBlockCache:
    """HBM block cache with violation-recency pinning.

    Lifecycle per shrinking compaction:

      1. `plan(keys, nbytes, scores)` — rank the compacted blocks by
         violation recency (ascending score = most recently violated
         first), pin greedily under ``budget_bytes``, evict entries that
         fell out of the plan.  Surviving entries keep their device arrays:
         a stable active set costs zero re-ship.
      2. cheap epochs call `lookup(key)` per block — a hit returns the
         pinned entry (zero H2D), a miss streams the block and `put`s the
         payload if the plan wants it.

    Invariants (property-tested): resident bytes never exceed the budget,
    and the hit set is always a subset of the planned pin set.
    """

    def __init__(self, budget_bytes: int):
        self.budget = max(0, int(budget_bytes))
        self._entries: Dict[bytes, CacheEntry] = {}
        self._pinned: set = set()
        self.resident_bytes = 0
        self.peak_resident_bytes = 0
        self.evictions = 0

    # ------------------------------------------------------------- planning
    def plan(self, keys: Sequence[bytes], nbytes: Sequence[int],
             scores: Sequence[float]) -> set:
        """Recompute the pin set for a new compaction and evict stale
        entries.  Blocks are pinned in ascending ``scores`` order (violation
        recency: lower = more recently violated) until the cumulative wire
        bytes would exceed the budget; ties break on block order, so the
        plan is deterministic.  Returns the planned key set."""
        order = np.argsort(np.asarray(scores, np.float64), kind="stable")
        pinned: set = set()
        total = 0
        for i in order:
            nb = int(nbytes[i])
            if total + nb <= self.budget:
                pinned.add(keys[i])
                total += nb
        self._pinned = pinned
        for key in [k for k in self._entries if k not in pinned]:
            self.resident_bytes -= self._entries.pop(key).nbytes
            self.evictions += 1
        return pinned

    def invalidate(self) -> None:
        """Drop everything (the union grew back to the full row set, or the
        solve is re-compacting from scratch)."""
        self.plan([], [], [])

    # ------------------------------------------------------------ hit / miss
    def lookup(self, key: bytes) -> Optional[CacheEntry]:
        return self._entries.get(key)

    def put(self, key: bytes, payload: object, nbytes: int) -> bool:
        """Pin a block's device payload if the current plan wants it and it
        fits; returns True when stored.  A double `put` of the same key is
        a no-op (the first payload wins — both decode identically)."""
        if key not in self._pinned or key in self._entries:
            return False
        if self.resident_bytes + nbytes > self.budget:
            return False
        self._entries[key] = CacheEntry(payload=payload, nbytes=int(nbytes))
        self.resident_bytes += int(nbytes)
        self.peak_resident_bytes = max(self.peak_resident_bytes,
                                       self.resident_bytes)
        return True

    # ----------------------------------------------------------- observability
    @property
    def n_entries(self) -> int:
        return len(self._entries)

    @property
    def planned_keys(self) -> set:
        return set(self._pinned)

    def planned_fraction(self, keys: Sequence[bytes],
                         nbytes: Sequence[int]) -> float:
        """Fraction of the given blocks' wire bytes the current plan pins —
        the projected cheap-epoch hit rate once the cache is warm.  Drives
        the prefetch clamp: a majority-hit epoch needs no deeper H2D queue."""
        total = int(np.sum(np.asarray(nbytes, np.int64))) if len(nbytes) else 0
        if total == 0:
            return 0.0
        hit = sum(int(nb) for k, nb in zip(keys, nbytes) if k in self._pinned)
        return hit / total


def violation_recency_scores(union: np.ndarray, tile: int,
                             unchanged: np.ndarray,
                             active_masks: np.ndarray) -> List[float]:
    """Per-block violation-recency score over a compacted union.

    ``unchanged`` is the (T_live, n) counter matrix (0 = the row's alpha
    moved this epoch); ``active_masks`` the (T_live, n) activity masks the
    compaction derived the union from.  A row's recency is its smallest
    counter over the tasks it is active for; a block scores the MINIMUM of
    its rows — one hot row keeps the whole block pinned, matching the
    all-tasks-per-block streaming granularity.  Lower = hotter."""
    if len(union) == 0:
        return []
    u = np.where(active_masks[:, union], unchanged[:, union],
                 np.iinfo(np.int64).max).min(axis=0)
    return [float(u[s:s + tile].min()) for s in range(0, len(union), tile)]


def violation_recency_scores_tasks(union: np.ndarray, tile: int,
                                   u_windows: Sequence[np.ndarray],
                                   id_windows: Sequence[np.ndarray],
                                   ) -> List[float]:
    """`violation_recency_scores` over task-LOCAL coordinates — the same
    per-block minimum-recency ranking, computed from each live task's
    (active-row unchanged counters, active-row global ids) window pairs
    instead of (T_live, n) matrices, so scoring a grid farm's compaction is
    O(sum active task sizes) like the rest of the engine."""
    if len(union) == 0:
        return []
    best = np.full(len(union), np.iinfo(np.int64).max, np.int64)
    for u, ids in zip(u_windows, id_windows):
        if len(ids):
            np.minimum.at(best, np.searchsorted(union, ids),
                          np.asarray(u, np.int64))
    return [float(best[s:s + tile].min())
            for s in range(0, len(union), tile)]


def stage2_cache_budget(rank: int, n_tasks: int, tile: int,
                        prefetch: int, cfg: StreamConfig) -> int:
    """Cache byte budget for one engine: an explicit
    `StreamConfig.cache_budget_bytes`, else the unused remainder of
    `device_budget_bytes` after the resident per-task weights and the
    `prefetch`-deep in-flight block working set are carved out (the "more
    RAM" the budget model was leaving on the table).  Zero when caching is
    disabled."""
    from repro.core.solver_stream import (stage2_block_bytes,
                                          stage2_resident_bytes)

    if not cfg.cache_blocks:
        return 0
    if cfg.cache_budget_bytes is not None:
        return max(0, int(cfg.cache_budget_bytes))
    rem = (cfg.device_budget_bytes
           - stage2_resident_bytes(rank, n_tasks)
           - max(1, prefetch) * stage2_block_bytes(tile, rank, n_tasks))
    return max(0, int(rem))


def block_wire_nbytes(tile: int, rank: int, wire: str, group: int) -> int:
    """Wire (== cached-device) bytes of one padded (tile, rank) block in the
    given format — the byte model `auto_tile_rows` and the tests share."""
    from repro.core.quant import quant_scale_bytes

    if wire == "bf16":
        return tile * rank * (BYTES_F32 // 2)
    if wire == "int8":
        # compacted blocks carry per-ROW scale entries (group=1 gathers)
        return tile * rank + quant_scale_bytes(tile, 1)
    return tile * rank * BYTES_F32
