"""Kernel functions for LPD-SVM.

The paper uses the Gaussian kernel in all experiments; polynomial / tanh /
linear are supported since the solver only needs *batch* kernel evaluations
(sec. 4, "batch kernel computation ... matrix-matrix multiplication at their
core").  All kernels reduce to a blocked X @ Z.T plus an elementwise epilogue,
which is exactly what the Pallas gram kernel implements on TPU.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

KERNELS = ("rbf", "linear", "poly", "tanh")


@dataclasses.dataclass(frozen=True)
class KernelParams:
    """Hyperparameters of a kernel function (hashable, jit-static)."""

    kind: str = "rbf"
    gamma: float = 1.0     # rbf / poly / tanh scale
    coef0: float = 0.0     # poly / tanh offset
    degree: int = 3        # poly

    def __post_init__(self):
        if self.kind not in KERNELS:
            raise ValueError(f"unknown kernel {self.kind!r}; expected one of {KERNELS}")


def apply_epilogue(dot: jnp.ndarray, x_sq: jnp.ndarray, z_sq: jnp.ndarray,
                   params: KernelParams) -> jnp.ndarray:
    """Turn a block of inner products into kernel values.

    dot:  (n, m) block of <x_i, z_j>
    x_sq: (n,)  squared norms of the x rows
    z_sq: (m,)  squared norms of the z rows
    """
    if params.kind == "linear":
        return dot
    if params.kind == "rbf":
        d2 = x_sq[:, None] + z_sq[None, :] - 2.0 * dot
        d2 = jnp.maximum(d2, 0.0)  # numerical floor
        return jnp.exp(-params.gamma * d2)
    if params.kind == "poly":
        return (params.gamma * dot + params.coef0) ** params.degree
    if params.kind == "tanh":
        return jnp.tanh(params.gamma * dot + params.coef0)
    raise ValueError(params.kind)


@partial(jax.jit, static_argnames=("params",))
def gram(x: jnp.ndarray, z: jnp.ndarray, params: KernelParams) -> jnp.ndarray:
    """Reference (pure jnp) batch kernel matrix  K[i, j] = k(x_i, z_j)."""
    x = x.astype(jnp.float32)
    z = z.astype(jnp.float32)
    dot = x @ z.T
    x_sq = jnp.sum(x * x, axis=-1)
    z_sq = jnp.sum(z * z, axis=-1)
    return apply_epilogue(dot, x_sq, z_sq, params)


def median_gamma(x: np.ndarray, sample: int = 256, seed: int = 0) -> float:
    """Median-squared-distance heuristic: gamma = 1 / median ||x_i - x_j||^2
    over a random row subsample (host-side numpy — this is data inspection,
    not compute).  Random rows, not the head: real datasets are often
    label-sorted and a single-class prefix would bias the median."""
    x = np.asarray(x, np.float32)
    if x.shape[0] > sample:
        rows = np.random.default_rng(seed).choice(x.shape[0], sample,
                                                  replace=False)
        x = x[np.sort(rows)]
    d2 = ((x[:, None] - x[None]) ** 2).sum(-1)
    d2 = d2[d2 > 0]
    return float(1.0 / np.median(d2)) if d2.size else 1.0


def kernel_diag(x: jnp.ndarray, params: KernelParams) -> jnp.ndarray:
    """k(x_i, x_i) without forming the full matrix."""
    x_sq = jnp.sum(x.astype(jnp.float32) ** 2, axis=-1)
    if params.kind == "linear":
        return x_sq
    if params.kind == "rbf":
        return jnp.ones_like(x_sq)
    if params.kind == "poly":
        return (params.gamma * x_sq + params.coef0) ** params.degree
    if params.kind == "tanh":
        return jnp.tanh(params.gamma * x_sq + params.coef0)
    raise ValueError(params.kind)
