"""Out-of-core stage 1: stream the Nyström factor G in row chunks.

The paper's "more RAM" ingredient: the dataset and the (n, B') factor G live
in *host* memory (512 GB class), while the accelerator only ever holds one
row chunk's working set — the landmark block, the projector, and a few chunks
in flight.  That decouples the trainable n from device memory:

    host RAM                          device HBM
    ────────────────────────────      ─────────────────────────────
    x        (n, p)   read-only       landmarks  (B, p)    resident
    G        (n, B')  preallocated    projector  (B, B')   resident
                                      per chunk: x[s:e], K_chunk, G_chunk

The streaming loop exploits jax's async dispatch as the double buffer:
``jax.device_put`` of chunk k+1 and the Pallas ``gram`` launch for it are
enqueued while chunk k's result is still being fetched to host — the host
only blocks on the *oldest* in-flight chunk (``prefetch`` controls the queue
depth).  On TPU/GPU that overlaps H2D copy, MXU compute, and D2H copy; on the
CPU container it degrades gracefully to sequential execution with identical
numerics, which is what the tests pin down.

Passing ``devices`` round-robins disjoint chunk streams over several devices
(each with its own resident landmark/projector replica) —
`core/distributed.py` wraps that for a mesh.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from functools import partial
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.faults import check as _fault_check
from repro.core.kernel_fn import KernelParams, gram
from repro.core.quant import (GROUP_ROWS, QuantBlock, dequantize_rows,
                              quantize_rows)
from repro.core.trace import resolve as resolve_tracer

BYTES_F32 = 4

WIRE_DTYPES = ("f32", "bf16", "int8")


@dataclasses.dataclass(frozen=True)
class StreamConfig:
    """Knobs of the chunked stage-1 pipeline (all sizes in rows / bytes).

    ``device_budget_bytes`` is the stage-1 *working set* allowance on one
    device, not the physical HBM size — leave headroom for the stage-2 solver
    (G rows get re-materialised there) and the runtime itself.
    """

    device_budget_bytes: int = 2 << 30   # 2 GiB default working-set allowance
    chunk_rows: Optional[int] = None     # None -> derived from the budget
    prefetch: int = 2                    # chunks in flight (double buffering)
    min_chunk_rows: int = 256
    tile_rows: Optional[int] = None      # stage-2 G block rows (None -> derived)
    block_dtype: str = "f32"             # wire dtype of streamed stage-2 G
                                         # blocks: "f32", "bf16" (half H2D,
                                         # upcast on device) or "int8"
                                         # (quarter H2D, per-row-group
                                         # scale/zero codec, device dequant)
    stage1_dtype: str = "f32"            # wire dtype of streamed stage-1 x
                                         # chunks: "f32" or "int8" (symmetric
                                         # codec; dequant fused into the gram
                                         # kernel)
    quant_group_rows: int = GROUP_ROWS   # rows per int8 scale group (both
                                         # stages; 8 scale bytes per group)
    overlap_devices: bool = True         # >1 local device: overlapped task
                                         # farm behind one shared block reader
    autotune_prefetch: bool = True       # deepen the in-flight queue when the
                                         # first full pass is transfer-bound
    prefetch_cap: int = 8                # autotune ceiling on queue depth
    cache_blocks: bool = True            # pin the shrinking-compacted active
                                         # row union device-side (HBM block
                                         # cache); safe default — cached
                                         # blocks decode bit-identically to
                                         # streamed ones
    cache_budget_bytes: Optional[int] = None  # HBM cache allowance per
                                         # engine; None -> the unused
                                         # remainder of device_budget_bytes
    trace: Optional[object] = None       # core.trace.Tracer recording the
                                         # pipeline timeline; None -> the
                                         # process-wide tracer if installed,
                                         # else the no-op fast path
    # -- fault tolerance (core/resilience.py) --------------------------------
    checkpoint_dir: Optional[str] = None  # where stage-2 epoch snapshots and
                                         # the resumable stage-1 memmap live;
                                         # None -> checkpointing off
    checkpoint_every: int = 0            # full passes between stage-2 disk
                                         # snapshots (0 = never snapshot)
    resume: bool = False                 # continue from the latest snapshot /
                                         # completed stage-1 chunk ranges in
                                         # checkpoint_dir
    fail_fast: bool = True               # True (default): any worker error
                                         # kills the solve (pre-PR semantics).
                                         # False: transient H2D errors retry
                                         # with backoff, lost devices are
                                         # quarantined and their task shard
                                         # re-split onto survivors from the
                                         # last epoch-boundary snapshot
    max_retries: int = 3                 # bounded transient-H2D retries per
                                         # put (only when fail_fast=False)
    retry_backoff: float = 0.05          # base seconds of the exponential
                                         # retry backoff (doubles per attempt)
    watchdog_seconds: float = 0.0        # farm-barrier starvation watchdog:
                                         # raise a queue/thread diagnostic
                                         # instead of hanging (0 = off)
    checkpoint_keep: int = 3             # stage-2 snapshots retained on disk
                                         # (keep-last-k, delete-after-write;
                                         # 0 = keep every step_*.msgpack)
    # -- disk tier (core/shards.py) ------------------------------------------
    shard_dir: Optional[str] = None      # root of the checksummed shard
                                         # store(s); None -> disk tier off
    shard_rows: int = 4096               # rows per shard file (multiple of
                                         # quant.GROUP_ROWS so int8 scale
                                         # groups stay global-row-aligned)
    spill_g: bool = False                # stream stage-1 G into f32 shards
                                         # under shard_dir and read it back
                                         # in stage 2 (host G never built)
    verify_shards: bool = True           # recompute each shard's checksum on
                                         # every disk read (False = trust
                                         # the bytes; bench the difference)

    def __post_init__(self):
        if self.prefetch < 1:
            raise ValueError("prefetch must be >= 1")
        if self.chunk_rows is not None and self.chunk_rows < 1:
            raise ValueError("chunk_rows must be positive")
        if self.tile_rows is not None and self.tile_rows < 1:
            raise ValueError("tile_rows must be positive")
        if self.block_dtype not in WIRE_DTYPES:
            raise ValueError(f"block_dtype must be one of {WIRE_DTYPES}, "
                             f"got {self.block_dtype!r}")
        if self.stage1_dtype not in ("f32", "int8"):
            raise ValueError(f"stage1_dtype must be 'f32' or 'int8', "
                             f"got {self.stage1_dtype!r}")
        if self.quant_group_rows < 1:
            raise ValueError("quant_group_rows must be >= 1")
        if self.prefetch_cap < 1:
            raise ValueError("prefetch_cap must be >= 1")
        if self.cache_budget_bytes is not None and self.cache_budget_bytes < 0:
            raise ValueError("cache_budget_bytes must be >= 0")
        if self.checkpoint_every < 0:
            raise ValueError("checkpoint_every must be >= 0")
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if self.retry_backoff < 0:
            raise ValueError("retry_backoff must be >= 0")
        if self.watchdog_seconds < 0:
            raise ValueError("watchdog_seconds must be >= 0")
        if self.checkpoint_keep < 0:
            raise ValueError("checkpoint_keep must be >= 0")
        if self.shard_rows < 1 or self.shard_rows % GROUP_ROWS:
            raise ValueError(f"shard_rows must be a positive multiple of "
                             f"{GROUP_ROWS}, got {self.shard_rows}")
        if self.spill_g and not self.shard_dir:
            raise ValueError("spill_g=True requires shard_dir")
        if self.resume and not self.checkpoint_dir:
            raise ValueError("resume=True requires checkpoint_dir")


def tune_prefetch(h2d_seconds: float, compute_seconds: float, prefetch: int,
                  cap: int = 8) -> int:
    """Minimal overlap-autotune shared by BOTH streamed stages (ROADMAP): the
    in-flight queue hides min(H2D, compute) behind max(H2D, compute) only
    while it is deep enough to keep both sides busy.  When the measured H2D
    time of the first pipeline window exceeds the drain/compute time it is
    supposed to overlap, transfer lags compute — double the queue depth
    (bounded by ``cap``)."""
    if h2d_seconds > compute_seconds and prefetch < cap:
        return min(cap, max(prefetch * 2, prefetch + 1))
    return prefetch


@dataclasses.dataclass
class Stage1StreamStats:
    """Traffic accounting of one streamed stage-1 factor build.

    `bytes_h2d` counts the CHUNK wire bytes (the n-scaling traffic this
    pipeline exists to bound) — int8 scale tables included, broken out in
    `bytes_scales`; the one-time landmark/projector replicas are excluded so
    per-dtype comparisons stay exact."""

    chunks: int = 0
    rows: int = 0
    chunks_skipped: int = 0           # chunks already covered by a resumed
                                      # stage-1 progress log (zero H2D)
    rows_resumed: int = 0             # rows those skipped chunks carried
    rows_skipped: int = 0             # bad ingest rows dropped by the
                                      # on_bad_row="skip" policy upstream
    bytes_h2d: int = 0
    bytes_scales: int = 0
    put_seconds: float = 0.0          # host time inside chunk H2D puts
    drain_seconds: float = 0.0        # host time blocked on G-chunk fetches
    seconds: float = 0.0
    wire_dtype: str = "f32"
    prefetch_final: int = 0           # queue depth after autotune

    @property
    def h2d_gbps(self) -> float:
        """Effective H2D rate over host put time (GB/s)."""
        return self.bytes_h2d / max(self.put_seconds, 1e-12) / 1e9

    @property
    def overlap_efficiency(self) -> float:
        """Stall-free fraction of the wall clock: 1 minus the share spent
        blocked in puts/drains, clamped to [0, 1].  The trace-level
        `Tracer.overlap_efficiency` is the per-span timeline analogue."""
        if self.seconds <= 0.0:
            return 0.0
        busy = (self.put_seconds + self.drain_seconds) / self.seconds
        return min(1.0, max(0.0, 1.0 - busy))


def resident_bytes(p: int, budget: int) -> int:
    """Device-resident stage-1 state: landmark block + projector."""
    return (budget * p + budget * budget) * BYTES_F32


def chunk_bytes(rows: int, p: int, budget: int) -> int:
    """Working set of ONE in-flight chunk: input rows, K block, G block."""
    return rows * (p + 2 * budget) * BYTES_F32


def monolithic_bytes(n: int, p: int, budget: int) -> int:
    """Device working set of the one-shot path: x, K_nm, G all live at once."""
    return (n * p + 2 * n * budget) * BYTES_F32 + resident_bytes(p, budget)


def should_stream(n: int, p: int, budget: int, cfg: StreamConfig) -> bool:
    """True when the monolithic stage-1 working set blows the device budget."""
    return monolithic_bytes(n, p, budget) > cfg.device_budget_bytes


def auto_chunk_rows(n: int, p: int, budget: int, cfg: StreamConfig) -> int:
    """Largest chunk whose `prefetch` in-flight copies fit the budget.

    Solves  prefetch * chunk_bytes(r) + resident <= device_budget  for r,
    clamped to [min_chunk_rows, n] — the floor keeps tiny budgets from
    degenerating into per-row dispatch (latency-bound), accepting a mild
    budget overshoot instead.
    """
    if cfg.chunk_rows is not None:
        return min(cfg.chunk_rows, n)
    free = cfg.device_budget_bytes - resident_bytes(p, budget)
    per_row = cfg.prefetch * (p + 2 * budget) * BYTES_F32
    rows = free // per_row if free > 0 else 0
    return int(min(n, max(cfg.min_chunk_rows, rows)))


@partial(jax.jit, static_argnames=("params", "gram_fn"))
def _chunk_features(xb, landmarks, projector, params: KernelParams, gram_fn):
    """One chunk's G rows: K(x_chunk, landmarks) @ projector, fused under jit."""
    return gram_fn(xb, landmarks, params) @ projector


@partial(jax.jit, static_argnames=("params", "group", "gram_q8_fn"))
def _chunk_features_q8(vals, scales, landmarks, projector,
                       params: KernelParams, group: int, gram_q8_fn):
    """One chunk's G rows from the int8 wire: the H2D copy shipped int8
    values + the compact scale table, and the gram kernel dequantises fused
    (no fp32 x chunk ever materialises on device)."""
    return gram_q8_fn(vals, scales, landmarks, params, group=group) @ projector


def default_gram_q8_fn() -> Callable:
    """Fused-dequant Pallas gram on TPU; the jnp dequant+gram oracle
    elsewhere (interpret-mode Pallas is pure overhead on CPU)."""
    if jax.default_backend() == "tpu":
        from repro.kernels.ops import gram_q8
        return gram_q8
    from repro.kernels.ref import gram_q8_ref
    return gram_q8_ref


def stream_factor_blocks(
    blocks,
    n: int,
    landmarks: jnp.ndarray,
    projector: jnp.ndarray,
    params: KernelParams,
    *,
    prefetch: int = 2,
    gram_fn: Callable = gram,
    out: Optional[np.ndarray] = None,
    devices: Optional[Sequence] = None,
    wire_dtype: str = "f32",
    quant_group_rows: int = GROUP_ROWS,
    gram_q8_fn: Optional[Callable] = None,
    autotune_prefetch: bool = False,
    prefetch_cap: int = 8,
    stats: Optional[Stage1StreamStats] = None,
    trace=None,
    progress=None,
) -> np.ndarray:
    """Fill a host-resident G from an *iterator* of dense row blocks.

    The generic core of `stream_factor_rows`: ``blocks`` yields (rows, p)
    float32 arrays totalling ``n`` rows (e.g. `CSRData.iter_dense_blocks` or
    `read_libsvm_blocks`), so stage 1 never materialises the full dense
    (n, p) host matrix.  Each block is ``jax.device_put`` and the
    gram+project launch dispatched asynchronously, with at most ``prefetch``
    blocks in flight per device before the host blocks on the oldest one and
    copies it into ``out``.  Passing ``devices`` round-robins *disjoint*
    block streams across them (landmarks/projector replicated once per
    device up front).

    ``wire_dtype="int8"`` quantises each chunk host-side with the symmetric
    per-row-group codec (`core/quant.py`; zero padding through the Pallas
    tiles must dequantise to exact zeros, hence symmetric) and ships int8
    values + the compact scale table at ~quarter the H2D bytes; the gram
    consumer (``gram_q8_fn``, `default_gram_q8_fn` when None) fuses the
    dequantisation into its tile loads.

    ``autotune_prefetch`` closes the stage-1 overlap loop (ROADMAP): once
    the first full pipeline window has been measured, the in-flight depth is
    deepened via `tune_prefetch` when H2D put time exceeds drain/compute
    time (bounded by ``prefetch_cap``); the tuned depth lands in
    ``stats.prefetch_final``.

    ``progress`` (a `resilience.Stage1Progress`) makes the stream resumable:
    row ranges already logged as complete are skipped (counted in
    ``stats.chunks_skipped`` / ``rows_resumed``), and every drained chunk is
    durably marked — G flushed before the log line — so a killed stage 1
    restarts at the first missing chunk.
    """
    rank = projector.shape[1]
    if out is None:
        out = np.empty((n, rank), np.float32)
    if out.shape != (n, rank):
        raise ValueError(f"out buffer {out.shape} != {(n, rank)}")
    if devices is None:
        devices = [None]
    if wire_dtype not in ("f32", "int8"):
        raise ValueError(f"stage-1 wire_dtype must be 'f32' or 'int8', "
                         f"got {wire_dtype!r}")
    quant = wire_dtype == "int8"
    if quant and gram_q8_fn is None:
        gram_q8_fn = default_gram_q8_fn()
    st = stats if stats is not None else Stage1StreamStats()
    st.wire_dtype = wire_dtype
    tr = resolve_tracer(trace)
    t_start = time.perf_counter()

    # One resident replica of the landmark block per device.
    resident = []
    for d in devices:
        if d is None:
            resident.append((jnp.asarray(landmarks, jnp.float32),
                             jnp.asarray(projector, jnp.float32)))
        else:
            resident.append((jax.device_put(np.asarray(landmarks, np.float32), d),
                             jax.device_put(np.asarray(projector, np.float32), d)))

    inflight = collections.deque()  # (start, end, device_array)
    g_flush = getattr(out, "flush", None)   # memmap: make marked rows durable

    def drain_one():
        s, e, gb = inflight.popleft()
        t0 = tr.begin()
        out[s:e] = np.asarray(gb)   # blocks on this chunk only
        st.drain_seconds += tr.end("drain", "stage1_fetch", t0,
                                   bytes=int(gb.nbytes), rows=e - s)
        if progress is not None:
            progress.mark(s, e, flush=g_flush)

    def put(a, d):
        t0 = tr.begin()
        b = jnp.asarray(a) if d is None else jax.device_put(a, d)
        st.put_seconds += tr.end("h2d", "stage1_put", t0,
                                 bytes=int(a.nbytes))
        st.bytes_h2d += a.nbytes
        return b

    max_inflight = max(1, prefetch) * len(devices)
    tuned = not autotune_prefetch
    s = 0
    for i, xb in enumerate(blocks):
        # Blocks may arrive PRE-ENCODED as `quant.QuantBlock`s (the int8
        # shard store streams its stored codes straight onto the wire —
        # zero re-encode, and bit-equal to the host int8 path because shard
        # scale groups are global-row-aligned).  On the f32 wire they are
        # decoded host-side first.
        pre = isinstance(xb, QuantBlock)
        if pre and not quant:
            xb = dequantize_rows(xb.values, xb.scales, xb.group)
            pre = False
        if not pre:
            xb = np.asarray(xb, np.float32)
        e = s + xb.shape[0]
        if e > n:
            raise ValueError(f"block iterator produced more than {n} rows")
        if progress is not None and progress.covered(s, e):
            # Resumed: this row range is already durably in G — skip the
            # whole put/compute/drain for it (zero H2D).
            st.chunks_skipped += 1
            st.rows_resumed += e - s
            s = e
            continue
        _fault_check("stage1", chunk=i)
        d = devices[i % len(devices)]
        lm, pr = resident[i % len(devices)]
        if quant:
            if pre:
                vals, scales, grp = xb.values, xb.scales, xb.group
            else:
                t0 = tr.begin()
                vals, scales = quantize_rows(xb, quant_group_rows,
                                             symmetric=True)
                tr.end("encode", "stage1_quant", t0, rows=xb.shape[0],
                       bytes=int(vals.nbytes + scales.nbytes))
                grp = quant_group_rows
            st.bytes_scales += scales.nbytes
            bv, bs = put(vals, d), put(scales, d)
            t0 = tr.begin()
            gb = _chunk_features_q8(bv, bs, lm, pr,
                                    params, grp, gram_q8_fn)
            tr.end("kernel", "stage1_chunk", t0, rows=e - s)
        else:
            bx = put(xb, d)
            t0 = tr.begin()
            gb = _chunk_features(bx, lm, pr, params, gram_fn)
            tr.end("kernel", "stage1_chunk", t0, rows=e - s)
        st.chunks += 1
        st.rows += e - s
        inflight.append((s, e, gb))
        if len(inflight) >= max_inflight:
            drain_one()
            if not tuned:
                # First pipeline window measured: deepen the in-flight queue
                # if the H2D side could not hide behind the drain/compute.
                tuned = True
                prefetch = tune_prefetch(st.put_seconds, st.drain_seconds,
                                         prefetch, prefetch_cap)
                max_inflight = prefetch * len(devices)
        s = e
    while inflight:
        drain_one()
    if s != n:
        raise ValueError(f"block iterator produced {s} rows, expected {n}")
    st.prefetch_final = prefetch
    st.seconds = time.perf_counter() - t_start
    return out


def stream_factor_rows(
    x,
    landmarks: jnp.ndarray,
    projector: jnp.ndarray,
    params: KernelParams,
    *,
    chunk_rows: int,
    prefetch: int = 2,
    gram_fn: Callable = gram,
    out: Optional[np.ndarray] = None,
    devices: Optional[Sequence] = None,
    **wire_kwargs,
) -> np.ndarray:
    """Fill a host-resident G = K(x, landmarks) @ projector, chunk by chunk.

    ``x`` stays on host (numpy); row chunks of ``chunk_rows`` are sliced off
    it and fed through `stream_factor_blocks`' in-flight pipeline.  Extra
    keyword arguments (``wire_dtype``, ``stats``, ...) pass through.
    """
    x = np.asarray(x, np.float32)
    n = x.shape[0]
    blocks = (x[s:min(s + chunk_rows, n)] for s in range(0, n, chunk_rows))
    return stream_factor_blocks(
        blocks, n, landmarks, projector, params, prefetch=prefetch,
        gram_fn=gram_fn, out=out, devices=devices, **wire_kwargs)


def compute_factor_streamed(
    x,
    params: KernelParams,
    budget: int,
    *,
    key: Optional[jax.Array] = None,
    eig_rtol: Optional[float] = None,
    config: StreamConfig = StreamConfig(),
    gram_fn: Callable = gram,
    devices: Optional[Sequence] = None,
):
    """Out-of-core stage 1: same artifact as `nystrom.compute_factor`, but G
    is a host-resident numpy buffer filled by the chunked pipeline.

    The landmark eigendecomposition is unchanged (B x B fits any device); only
    the (n, B) gram + projection — the part that scales with n — streams.
    """
    if key is None:
        key = jax.random.PRNGKey(0)
    x = np.asarray(x, np.float32)
    n, p = x.shape

    if budget >= n:
        landmarks = jnp.asarray(x, jnp.float32)
    else:
        landmarks = jnp.asarray(_select_landmarks_host(x, budget, key),
                                jnp.float32)

    def make_blocks(chunk):
        return (x[s:min(s + chunk, n)] for s in range(0, n, chunk))

    return _streamed_factor_from_landmarks(
        landmarks, make_blocks, n, p, params, eig_rtol=eig_rtol,
        config=config, gram_fn=gram_fn, devices=devices,
        row_provider=lambda s, e: x[s:e])


def compute_factor_streamed_csr(
    data,
    params: KernelParams,
    budget: int,
    *,
    key: Optional[jax.Array] = None,
    eig_rtol: Optional[float] = None,
    config: StreamConfig = StreamConfig(),
    gram_fn: Callable = gram,
    devices: Optional[Sequence] = None,
):
    """Out-of-core stage 1 straight from a `CSRData` (LIBSVM) data set.

    The sparse triple stays the only full-data host object: landmarks are
    gathered row-wise from the CSR storage, and the (n, p) dense matrix is
    only ever materialised one `chunk_rows` block at a time on its way to the
    device (`CSRData.iter_dense_blocks` -> `stream_factor_blocks`).  Uses the
    same landmark permutation as `compute_factor_streamed`, so the factor is
    identical to densify-then-stream for a given key.
    """
    if key is None:
        key = jax.random.PRNGKey(0)
    n, p = data.n, data.n_features
    b = min(budget, n)
    if b >= n:
        lm_rows = np.arange(n)
    else:
        lm_rows = np.asarray(jax.random.choice(key, n, shape=(b,),
                                               replace=False))
    landmarks = jnp.asarray(data.densify_rows(lm_rows), jnp.float32)

    def make_blocks(chunk):
        return (blk for blk, _ in data.iter_dense_blocks(chunk))

    return _streamed_factor_from_landmarks(
        landmarks, make_blocks, n, p, params, eig_rtol=eig_rtol,
        config=config, gram_fn=gram_fn, devices=devices,
        row_provider=lambda s, e: data.densify(s, e))


def compute_factor_streamed_shards(
    store,
    params: KernelParams,
    budget: int,
    *,
    key: Optional[jax.Array] = None,
    eig_rtol: Optional[float] = None,
    config: StreamConfig = StreamConfig(),
    gram_fn: Callable = gram,
    devices: Optional[Sequence] = None,
):
    """Out-of-core stage 1 from a checksummed on-disk `shards.ShardStore`.

    The disk-tier twin of `compute_factor_streamed_csr`: the LIBSVM text was
    parsed ONCE into the shard store, and every subsequent epoch/run streams
    the verified binary shards instead of re-parsing.  Each shard is exactly
    one wire chunk (``chunk_rows`` is pinned to the store's ``shard_rows``),
    which keeps two invariants:

      * an f32 store is byte-identical input to the host-RAM stream, so the
        resulting factor is bit-equal to `compute_factor_streamed` on the
        same rows for EVERY stage-1 wire dtype;
      * an int8 store ships its STORED codes straight onto the int8 wire
        (`QuantBlock` pass-through in `stream_factor_blocks` — zero
        re-encode), its global-row-aligned scale groups landing exactly
        where the host quantiser would put them.

    Landmarks are gathered (and for int8 stores, decoded) from the shards
    with the same jax-derived permutation as the other constructors.
    """
    if key is None:
        key = jax.random.PRNGKey(0)
    n, p = store.n, store.cols
    b = min(budget, n)
    if b >= n:
        lm_rows = np.arange(n)
    else:
        lm_rows = np.asarray(jax.random.choice(key, n, shape=(b,),
                                               replace=False))
    landmarks = jnp.asarray(store.gather_rows(lm_rows), jnp.float32)

    wire = store.dtype == "int8"

    def make_blocks(chunk):
        return store.iter_blocks(wire=wire)

    def row_provider(s, e):
        if wire:
            return store.read_shard(s // store.shard_rows, wire=True)
        return store.read_rows(s, e)

    cfg = dataclasses.replace(config, chunk_rows=store.shard_rows)
    return _streamed_factor_from_landmarks(
        landmarks, make_blocks, n, p, params, eig_rtol=eig_rtol,
        config=cfg, gram_fn=gram_fn, devices=devices,
        row_provider=row_provider)


def _g_rebuilder(row_provider, chunk: int, n: int, landmarks, projector,
                 params: KernelParams, config: StreamConfig,
                 gram_fn: Callable, devices):
    """Rebuild closure for spilled-G shards: recompute G rows [lo, hi).

    Recomputes whole ORIGINAL chunks (chunk-aligned ranges, same wire dtype
    and quant grouping as the first pass) and slices out the shard — stage-1
    chunks are independent, so the recomputed rows are bit-equal to the
    spilled ones and the shard-digest check in `ShardStore._rebuild` holds.
    """
    def rebuild(lo: int, hi: int) -> np.ndarray:
        c0 = (lo // chunk) * chunk
        c1 = min(n, -(-hi // chunk) * chunk)
        blocks = (row_provider(s, min(s + chunk, c1))
                  for s in range(c0, c1, chunk))
        sub = stream_factor_blocks(
            blocks, c1 - c0, landmarks, projector, params,
            prefetch=config.prefetch, gram_fn=gram_fn, devices=devices,
            wire_dtype=config.stage1_dtype,
            quant_group_rows=config.quant_group_rows,
            autotune_prefetch=False, trace=config.trace)
        return sub[lo - c0:hi - c0]

    return rebuild


def _streamed_factor_from_landmarks(
    landmarks, make_blocks, n: int, p: int, params: KernelParams, *,
    eig_rtol: Optional[float], config: StreamConfig, gram_fn: Callable,
    devices: Optional[Sequence], row_provider=None,
):
    """Shared tail of the streamed stage-1 constructors: eigendecompose the
    landmark kernel, then stream ``make_blocks(chunk_rows)`` into G.

    ``row_provider(s, e)`` re-yields the input rows of [s, e) on demand; it
    is only called when ``config.spill_g`` is set and a spilled G shard
    later fails its checksum (quarantine -> recompute)."""
    from repro.core import nystrom  # deferred: nystrom routes back into us

    if eig_rtol is None:
        eig_rtol = nystrom.DEFAULT_EIG_RTOL
    k_mm = gram_fn(landmarks, landmarks, params)
    projector, evals, rank = nystrom._eig_projector(k_mm, params, eig_rtol)
    rank = int(rank)
    projector = projector[:, :rank]

    chunk = auto_chunk_rows(n, p, landmarks.shape[0], config)
    stats = Stage1StreamStats()
    out = progress = sink = None
    if config.spill_g and config.shard_dir:
        # Disk tier: G streams straight into checksummed f32 shards and is
        # handed to stage 2 as a `GShardView` — the (n, rank) host buffer
        # never exists.  Spill supersedes the stage-1 resume memmap (the
        # shard store IS the durable copy of G).
        import os as _os
        from repro.core.shards import ShardSpillSink
        sink = ShardSpillSink(_os.path.join(config.shard_dir, "g_spill"),
                              n, rank, shard_rows=config.shard_rows,
                              trace=config.trace)
        out = sink
    elif config.checkpoint_dir:
        # Resumable stage 1: G fills an on-disk memmap and completed chunk
        # ranges are logged durably, so a killed run restarts at the first
        # missing chunk.  Landmarks/projector are deterministic from the
        # PRNG key, so the recomputed resident state matches the logged G.
        import os as _os
        from repro.core.resilience import Stage1Progress, stage1_memmap
        out = stage1_memmap(config.checkpoint_dir, n, rank, config.resume)
        progress = Stage1Progress(
            _os.path.join(config.checkpoint_dir, "stage1_progress.log"),
            n, rank, resume=config.resume)
    try:
        G = stream_factor_blocks(
            make_blocks(chunk), n, landmarks, projector, params,
            prefetch=config.prefetch, gram_fn=gram_fn, devices=devices,
            wire_dtype=config.stage1_dtype,
            quant_group_rows=config.quant_group_rows,
            autotune_prefetch=config.autotune_prefetch,
            prefetch_cap=config.prefetch_cap, stats=stats, out=out,
            trace=config.trace, progress=progress)
    finally:
        if progress is not None:
            progress.close()
    if sink is not None:
        rebuilder = None
        if row_provider is not None:
            rebuilder = _g_rebuilder(row_provider, chunk, n, landmarks,
                                     projector, params, config, gram_fn,
                                     devices)
        G = sink.finish(
            rebuilder=rebuilder, verify=config.verify_shards,
            retries=0 if config.fail_fast else config.max_retries,
            retry_backoff=config.retry_backoff)

    return nystrom.LowRankFactor(
        G=G, landmarks=landmarks, projector=projector, eigvals=evals,
        effective_rank=rank, kernel=params, streamed=True,
        stage1_stats=stats)


def _select_landmarks_host(x: np.ndarray, budget: int, key) -> np.ndarray:
    """Landmark sample without shipping the full x to device first.

    `nystrom.select_landmarks` takes device-resident x; at out-of-core scale
    that defeats the purpose, so gather the B rows on host from the same
    jax-derived permutation (bit-identical landmark set for a given key).
    """
    idx = np.asarray(jax.random.choice(key, x.shape[0], shape=(budget,),
                                       replace=False))
    return x[idx]
