"""LPD-SVM core: the paper's contribution as a composable JAX module."""
from repro.core.block_cache import (HotRowBlockCache, block_key,
                                    stage2_cache_budget,
                                    violation_recency_scores,
                                    violation_recency_scores_tasks)
from repro.core.kernel_fn import KernelParams, gram, kernel_diag, median_gamma
from repro.core.nystrom import LowRankFactor, compute_factor, select_landmarks
from repro.core.dual_solver import (SolverConfig, TaskBatch, SolveResult,
                                    solve_one, solve_batch, duality_gap)
from repro.core.ovo import build_ovo_tasks, class_pairs, ovo_vote
from repro.core.polish import (PolishSchedule, PolishTrace, make_schedule,
                               solve_polished)
from repro.core.quant import (GROUP_ROWS, QuantBlock, dequant_rows,
                              dequantize_rows, quant_bytes, quantize_block,
                              quantize_rows)
from repro.core.solver_stream import (Stage2StreamStats, auto_tile_rows,
                                      block_windows, should_stream_stage2,
                                      solve_batch_streamed,
                                      solve_streamed_auto, tune_prefetch,
                                      wire_group)
from repro.core.svm import LPDSVM
from repro.core.cv import (build_cv_grid_tasks, grid_search, cross_validate,
                           kfold_masks)
from repro.core.distributed import (balance_chain_split, balance_task_split,
                                    solve_tasks_sharded,
                                    solve_tasks_streamed,
                                    solve_tasks_streamed_mesh,
                                    stream_factor_over_mesh)
from repro.core.faults import (DeviceLostError, FaultError, FaultPlan,
                               FaultSpec, InjectedIOError, SimulatedKill,
                               TransientH2DError, classify_error)
from repro.core.resilience import (Stage1Progress, StreamGuard,
                                   WatchdogTimeout, WorkerStuckError,
                                   g_fingerprint, load_snapshot,
                                   restore_engines, snapshot_engines,
                                   validate_snapshot)
from repro.core.shards import (GShardView, ShardCorruptionError, ShardError,
                               ShardSpillSink, ShardStore, ShardStoreStats,
                               ShardWriter, ingest_libsvm_shards,
                               open_or_ingest)
from repro.core.streaming import (Stage1StreamStats, StreamConfig,
                                  auto_chunk_rows, compute_factor_streamed,
                                  compute_factor_streamed_csr,
                                  compute_factor_streamed_shards,
                                  default_gram_q8_fn, should_stream,
                                  stream_factor_blocks, stream_factor_rows)
from repro.core.trace import (NULL, NullTracer, ProgressPrinter, Tracer,
                              install, uninstall)
from repro.core.trace import active as active_tracer
from repro.core.trace import resolve as resolve_tracer

__all__ = [
    "HotRowBlockCache", "block_key", "stage2_cache_budget",
    "violation_recency_scores", "violation_recency_scores_tasks",
    "KernelParams", "gram", "kernel_diag", "median_gamma",
    "LowRankFactor", "compute_factor", "select_landmarks",
    "SolverConfig", "TaskBatch", "SolveResult", "solve_one", "solve_batch",
    "duality_gap", "build_ovo_tasks", "class_pairs", "ovo_vote",
    "PolishSchedule", "PolishTrace", "make_schedule", "solve_polished",
    "GROUP_ROWS", "QuantBlock", "dequant_rows", "dequantize_rows",
    "quant_bytes", "quantize_block", "quantize_rows",
    "Stage2StreamStats", "auto_tile_rows", "block_windows",
    "should_stream_stage2",
    "solve_batch_streamed", "solve_streamed_auto", "tune_prefetch",
    "wire_group",
    "LPDSVM", "build_cv_grid_tasks", "grid_search", "cross_validate",
    "kfold_masks",
    "balance_chain_split", "balance_task_split",
    "solve_tasks_sharded", "solve_tasks_streamed",
    "solve_tasks_streamed_mesh", "stream_factor_over_mesh",
    "DeviceLostError", "FaultError", "FaultPlan", "FaultSpec",
    "InjectedIOError", "SimulatedKill", "TransientH2DError", "classify_error",
    "Stage1Progress", "StreamGuard", "WatchdogTimeout", "WorkerStuckError",
    "g_fingerprint", "load_snapshot", "restore_engines", "snapshot_engines",
    "validate_snapshot",
    "GShardView", "ShardCorruptionError", "ShardError", "ShardSpillSink",
    "ShardStore", "ShardStoreStats", "ShardWriter", "ingest_libsvm_shards",
    "open_or_ingest",
    "Stage1StreamStats", "StreamConfig", "auto_chunk_rows",
    "compute_factor_streamed", "compute_factor_streamed_csr",
    "compute_factor_streamed_shards",
    "default_gram_q8_fn", "should_stream", "stream_factor_blocks",
    "stream_factor_rows",
    "NULL", "NullTracer", "ProgressPrinter", "Tracer", "install", "uninstall",
    "active_tracer", "resolve_tracer",
]
