"""LPD-SVM core: the paper's contribution as a composable JAX module."""
from repro.core.kernel_fn import KernelParams, gram, kernel_diag
from repro.core.nystrom import LowRankFactor, compute_factor, select_landmarks
from repro.core.dual_solver import (SolverConfig, TaskBatch, SolveResult,
                                    solve_one, solve_batch, duality_gap)
from repro.core.ovo import build_ovo_tasks, class_pairs, ovo_vote
from repro.core.svm import LPDSVM
from repro.core.cv import grid_search, cross_validate, kfold_masks
from repro.core.distributed import solve_tasks_sharded, stream_factor_over_mesh
from repro.core.streaming import (StreamConfig, auto_chunk_rows,
                                  compute_factor_streamed, should_stream,
                                  stream_factor_rows)

__all__ = [
    "KernelParams", "gram", "kernel_diag",
    "LowRankFactor", "compute_factor", "select_landmarks",
    "SolverConfig", "TaskBatch", "SolveResult", "solve_one", "solve_batch",
    "duality_gap", "build_ovo_tasks", "class_pairs", "ovo_vote",
    "LPDSVM", "grid_search", "cross_validate", "kfold_masks",
    "solve_tasks_sharded", "stream_factor_over_mesh",
    "StreamConfig", "auto_chunk_rows", "compute_factor_streamed",
    "should_stream", "stream_factor_rows",
]
