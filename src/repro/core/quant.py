"""int8 per-row-group wire codec for the streamed pipelines.

Both streamed stages are H2D-bandwidth-bound (BENCH_streaming.json,
BENCH_stage2_mesh.json record the curves), which makes bytes-per-element the
single biggest lever on the hot path: an int8 wire format moves ~4x fewer
bytes across PCIe than f32 for the same rows.  This module is the shared
codec:

  * **Host side** (`quantize_rows`): rows are split into groups of
    ``group`` consecutive rows; each group gets one affine (scale, zero)
    pair from its min/max so that q = round((x - zero)/scale) fits int8
    with NO clipping loss (zero is the range midpoint, scale spans 254
    steps).  The per-group max quantisation error is (max-min)/508.
    ``symmetric=True`` pins zero = 0 (scale = absmax/127) — required when
    downstream zero-PADDING of the quantised values must dequantise to
    exact zeros (the Pallas gram kernel pads the feature axis).
  * **Device side** (`dequant_rows` / its jnp twin in consumers): the
    (ng, 2) scale table is expanded to per-row (scale, zero) and applied in
    fp32 — fused into the consuming kernel (the Pallas gram epilogue, the
    streamed SMO block prep) instead of a separate materialised upcast.

Wire cost per n-row block of width B:

    values  n * B           bytes   (int8)
    scales  ceil(n/group) * 8 bytes (f32 scale + zero per group)

so the f32 -> int8 ratio is 4 / (1 + 8/(group*B)) — ~3.99x at the default
group of 32 and B >= 64, comfortably above the >= 3x acceptance bar with
the scale bytes counted.

A constant group (max == min) quantises EXACTLY: scale falls back to 1.0,
every q is 0, and dequantisation returns the midpoint — so all-zero padding
groups round-trip bit-exactly in both codec modes.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Default rows per scale group.  32 is the int8 sublane tile on TPU (a
# (32, 128) native tile), keeps the scale overhead at 8/(32*B) of the
# payload, and divides every MXU-aligned row tile.
GROUP_ROWS = 32
SCALE_FIELDS = 2          # (scale, zero) per group, both f32
BYTES_SCALE = SCALE_FIELDS * 4


def n_groups(rows: int, group: int = GROUP_ROWS) -> int:
    return -(-rows // group)


def quant_bytes(rows: int, cols: int, group: int = GROUP_ROWS) -> int:
    """Total wire bytes of one quantised (rows, cols) block, scales included."""
    return rows * cols + n_groups(rows, group) * BYTES_SCALE


def quant_scale_bytes(rows: int, group: int = GROUP_ROWS) -> int:
    """Just the scale-table bytes of one quantised block."""
    return n_groups(rows, group) * BYTES_SCALE


@dataclasses.dataclass(frozen=True)
class QuantBlock:
    """One quantised wire block: int8 values + the (ng, 2) f32 scale table.

    Mimics the ndarray surface the streaming byte accounting relies on
    (`nbytes`, `shape`), so f32/bf16 ndarrays and QuantBlocks flow through
    the same reader/fan-out plumbing.
    """

    values: np.ndarray            # (rows, cols) int8
    scales: np.ndarray            # (ng, 2) f32: [:, 0] scale, [:, 1] zero
    group: int = GROUP_ROWS

    @property
    def nbytes(self) -> int:
        return self.values.nbytes + self.scales.nbytes

    @property
    def scale_bytes(self) -> int:
        return self.scales.nbytes

    @property
    def shape(self):
        return self.values.shape


def group_scales(x: np.ndarray, group: int = GROUP_ROWS, *,
                 symmetric: bool = False) -> np.ndarray:
    """Per-row-group (scale, zero) table of a (n, p) f32 block: (ng, 2) f32.

    Affine mode (default): scale = (max-min)/254, zero = midpoint, so
    q in [-127, 127] exactly — no clipping loss.  Symmetric mode: zero = 0,
    scale = absmax/127, so zero VALUES (and zero padding added after
    quantisation) are represented exactly.  Degenerate (constant) groups get
    scale 1.0: q ends up 0 and dequant returns the midpoint / zero exactly.
    """
    x = np.ascontiguousarray(x, np.float32)
    n = x.shape[0]
    if n == 0:
        return np.zeros((0, SCALE_FIELDS), np.float32)
    ng = n_groups(n, group)
    starts = np.arange(0, n, group)
    mn = np.minimum.reduceat(x.min(axis=1), starts)
    mx = np.maximum.reduceat(x.max(axis=1), starts)
    if symmetric:
        scale = np.maximum(np.abs(mn), np.abs(mx)) / 127.0
        zero = np.zeros((ng,), np.float32)
    else:
        scale = (mx - mn) / 254.0
        zero = (0.5 * (mx + mn)).astype(np.float32)
    scale = np.where(scale > 0.0, scale, 1.0).astype(np.float32)
    return np.stack([scale, zero], axis=1).astype(np.float32)


def expand_scales(scales: np.ndarray, group: int, n: int) -> np.ndarray:
    """(ng, 2) group table -> (n, 2) per-row table."""
    return np.repeat(scales, group, axis=0)[:n]


def encode_rows(x: np.ndarray, row_scales: np.ndarray) -> np.ndarray:
    """int8 codes of (n, p) f32 rows under a PER-ROW (n, 2) scale table.

    The encode half of the codec, factored out so consumers that need
    row-permuted encodings (the streamed solver's shrinking compaction
    gathers rows out of group order) can reuse each row's GLOBAL group
    scale — the decoded value of a row is then identical no matter which
    block shape it travelled in.
    """
    q = np.rint((x - row_scales[:, 1:2]) / row_scales[:, 0:1])
    return np.clip(q, -127, 127).astype(np.int8)


def quantize_rows(x: np.ndarray, group: int = GROUP_ROWS, *,
                  symmetric: bool = False) -> Tuple[np.ndarray, np.ndarray]:
    """Quantise a (n, p) f32 block to (int8 values, (ng, 2) f32 scales)."""
    x = np.ascontiguousarray(x, np.float32)
    scales = group_scales(x, group, symmetric=symmetric)
    if x.shape[0] == 0:
        return np.zeros((0, x.shape[1]), np.int8), scales
    return encode_rows(x, expand_scales(scales, group, x.shape[0])), scales


def quantize_block(x: np.ndarray, group: int = GROUP_ROWS, *,
                   symmetric: bool = False) -> QuantBlock:
    v, s = quantize_rows(x, group, symmetric=symmetric)
    return QuantBlock(values=v, scales=s, group=group)


def dequantize_rows(values: np.ndarray, scales: np.ndarray,
                    group: int = GROUP_ROWS) -> np.ndarray:
    """Host (numpy) dequantisation — the codec oracle for tests/tools."""
    n = values.shape[0]
    s = np.repeat(scales[:, 0], group)[:n, None]
    z = np.repeat(scales[:, 1], group)[:n, None]
    return values.astype(np.float32) * s + z


def dequantize_rows_range(values: np.ndarray, scales: np.ndarray,
                          lo: int, hi: int,
                          group: int = GROUP_ROWS) -> np.ndarray:
    """Host dequantisation of only rows [lo, hi) of a quantised block.

    Touches just the scale groups overlapping the range, so a partial read
    of a large on-disk shard (`core/shards.py` with its decoded-shard cache
    disabled) never pays for decoding the rows around it.  Identical values
    to ``dequantize_rows(values, scales, group)[lo:hi]``.
    """
    lo = max(0, lo)
    hi = min(values.shape[0], hi)
    if hi <= lo:
        return np.zeros((0, values.shape[1]), np.float32)
    g0 = lo // group
    sub = np.repeat(scales[g0:n_groups(hi, group)], group, axis=0)
    s = sub[lo - g0 * group:lo - g0 * group + (hi - lo)]
    return values[lo:hi].astype(np.float32) * s[:, 0:1] + s[:, 1:2]


@partial(jax.jit, static_argnames=("group",))
def dequant_rows(values: jnp.ndarray, scales: jnp.ndarray,
                 group: int = GROUP_ROWS) -> jnp.ndarray:
    """Device dequantisation of an int8 wire block back to fp32.

    The jit'd consumer-side half of the codec: expands the compact (ng, 2)
    scale table to per-row (scale, zero) and applies them in one fused
    elementwise pass — the H2D copy moved a quarter of the bytes, and no
    separate f32 staging buffer ever exists on host.
    """
    n = values.shape[0]
    ng = scales.shape[0]
    s = jnp.repeat(scales[:, 0], group, total_repeat_length=ng * group)[:n]
    z = jnp.repeat(scales[:, 1], group, total_repeat_length=ng * group)[:n]
    return values.astype(jnp.float32) * s[:, None] + z[:, None]


def max_quant_error(scales: np.ndarray) -> float:
    """Worst-case absolute reconstruction error promised by a scale table."""
    return float(0.5 * scales[:, 0].max()) if scales.size else 0.0
