"""Out-of-core stage 2: stream G row-blocks through the SMO epoch.

The paper keeps alpha on the GPU and the full factor G in host RAM ("more
RAM!"), so the trainable n is bounded by the 512 GB-class host, not device
HBM.  `dual_solver.solve_batch` re-materialises all of G on device when it
traces, silently re-capping n at HBM; this module closes that gap:

    host RAM                              device HBM
    ───────────────────────────────       ────────────────────────────────
    G        (n, B)   read-only           w        (T, B)   resident, chained
    alpha    (T, n)   scattered back      per block: G[s:e], y/c/q/alpha/
    unchanged(T, n)   per block                      unchanged slices

Per epoch, (tile, B) row-blocks of G are `device_put` with the same
prefetch-deep async double buffering as `core/streaming.py` (enqueue block
k+1's H2D + kernel launches before draining block k's alpha back to host),
and every streamed block updates EVERY task before eviction, so the H2D
traffic is amortised over the whole OVO/CV task batch.  The per-task weight
vector w stays device-resident across blocks and epochs — the cross-block
analogue of the SMO kernel's VMEM scratchpad (kernels/smo.py).

Shrinking follows `core/compact.py`'s bucket-compaction design, but here it
cuts H2D *bytes*, not just FLOPs: after every full pass the union of active
rows over all unconverged tasks is gathered host-side, and the cheap epochs
stream only those rows.  Tasks are expressed in GLOBAL row coordinates
(c = 0 rows are inert no-ops), which makes the streamed trajectory exactly
the monolithic `solve_one` trajectory — blocks only re-chunk the same
sequential coordinate sweep — so parity with `solve_batch` holds to float
accumulation order, including shrinking counters and warm starts.

Requirements on the TaskBatch: each task's real (c > 0) rows must be unique;
sorted idx (what `build_ovo_tasks`/`build_cv_tasks` produce) additionally
gives trajectory-exact parity with the monolithic path.

Scaling note: global row coordinates cost O(T * n) HOST memory for the task
state (y/c/alpha/unchanged) and stream every live task over every full-pass
block.  For OVO that is a ~k/2 overhead versus task-local padding
(n_pad ~ 2n/k) — negligible against the (n, B) G while 7*T << B, i.e. for
the tens-of-classes regime this repo drives.  Hundreds of OVO classes want
task-LOCAL streamed coordinates (per-block searchsorted windows into each
task's sorted idx); see the ROADMAP open item.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import math
import time
from functools import partial
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dual_solver import (DELTA_EPS, Q_FLOOR, SolveResult,
                                    SolverConfig, TaskBatch)
from repro.core.streaming import BYTES_F32, StreamConfig

_H2D_GUARD = getattr(jax, "transfer_guard_host_to_device", None)


# ---------------------------------------------------------------------------
# stage-2 memory budget model (documented in docs/architecture.md)
# ---------------------------------------------------------------------------

def stage2_resident_bytes(rank: int, n_tasks: int) -> int:
    """Device-resident stage-2 state: one (B,) weight vector per task."""
    return n_tasks * rank * BYTES_F32


def stage2_block_bytes(tile: int, rank: int, n_tasks: int) -> int:
    """Working set of ONE in-flight block: the G tile plus, per task, the
    five input vectors (y, c, q, alpha, unchanged) and two outputs."""
    return tile * (rank + 7 * n_tasks) * BYTES_F32


def stage2_monolithic_bytes(n: int, rank: int, n_tasks: int, n_pad: int) -> int:
    """Device working set of `solve_batch`: full G + per-task vectors."""
    return (n * rank + n_tasks * (7 * n_pad + 2 * rank)) * BYTES_F32


def should_stream_stage2(n: int, rank: int, n_tasks: int, n_pad: int,
                         cfg: StreamConfig) -> bool:
    """True when the monolithic stage-2 working set blows the device budget."""
    return stage2_monolithic_bytes(n, rank, n_tasks, n_pad) > cfg.device_budget_bytes


def route_stage2(factor, tasks: TaskBatch, stream,
                 stream_config: Optional[StreamConfig],
                 solve_fn, default_solve_fn) -> bool:
    """The ONE stage-2 routing predicate (`LPDSVM.fit`, `core/cv.py`, CLI):
    stream G row-blocks when G is already host-resident (`factor.streamed`),
    streaming is forced, or the monolithic working set exceeds the device
    budget.  A custom ``solve_fn`` (e.g. the sharded task farm) is always
    respected, and ``stream=False`` pins the monolithic path.
    """
    if solve_fn is not default_solve_fn or stream is False:
        return False
    if stream or getattr(factor, "streamed", False):
        return True
    if stream_config is None:
        return False
    n, rank = factor.G.shape
    return should_stream_stage2(n, rank, tasks.n_tasks, tasks.idx.shape[1],
                                stream_config)


def auto_tile_rows(n: int, rank: int, n_tasks: int, cfg: StreamConfig) -> int:
    """Largest row tile whose `prefetch` in-flight blocks fit the budget.

    Solves  prefetch * stage2_block_bytes(t) + resident <= budget  for t,
    floored at `min_chunk_rows` (tiny budgets should not degenerate into
    per-row dispatch) and rounded up to a multiple of 8.
    """
    if cfg.tile_rows is not None:
        return max(8, -(-min(cfg.tile_rows, n) // 8) * 8)
    free = cfg.device_budget_bytes - stage2_resident_bytes(rank, n_tasks)
    per_row = cfg.prefetch * (rank + 7 * n_tasks) * BYTES_F32
    rows = (free // per_row) // 8 * 8 if free > 0 else 0   # round down: budget
    return int(min(-(-n // 8) * 8, max(cfg.min_chunk_rows, rows, 8)))


# ---------------------------------------------------------------------------
# block-epoch kernels
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("full_pass", "shrink_k"))
def smo_epoch_oracle(G, y, c, q, alpha, unchanged, w, *, full_pass: bool,
                     shrink_k: int):
    """One sequential coordinate-ascent sweep over a (tile, B) block.

    Flat 1-D vectors in/out, same contract as `kernels.ops.smo_epoch`; the
    body mirrors `dual_solver.epoch_ref` op-for-op so that chaining blocks
    reproduces the monolithic trajectory exactly.
    """
    n = G.shape[0]

    def body(i, state):
        alpha, w, unchanged, viol = state
        row = G[i]
        a_i, c_i, y_i, q_i = alpha[i], c[i], y[i], q[i]
        active = jnp.logical_and(
            c_i > 0.0, jnp.logical_or(full_pass, unchanged[i] < shrink_k))
        g = 1.0 - y_i * jnp.dot(w, row)
        at_lo = a_i <= 0.0
        at_hi = a_i >= c_i
        pg = jnp.where(at_lo, jnp.maximum(g, 0.0),
                       jnp.where(at_hi, jnp.minimum(g, 0.0), g))
        pg = jnp.where(c_i > 0.0, pg, 0.0)
        a_new = jnp.clip(a_i + g / jnp.maximum(q_i, Q_FLOOR), 0.0, c_i)
        a_new = jnp.where(active, a_new, a_i)
        delta = a_new - a_i
        w = w + (delta * y_i) * row
        alpha = alpha.at[i].set(a_new)
        changed = jnp.abs(delta) > DELTA_EPS
        u_new = jnp.where(changed, 0, unchanged[i] + 1)
        u_new = jnp.where(active, u_new, unchanged[i])
        unchanged = unchanged.at[i].set(u_new)
        viol = jnp.where(active, jnp.maximum(viol, jnp.abs(pg)), viol)
        return alpha, w, unchanged, viol

    alpha, w, unchanged, viol = jax.lax.fori_loop(
        0, n, body, (alpha, w, unchanged, jnp.float32(0.0)))
    return alpha, unchanged, w, viol


def default_epoch_fn() -> Callable:
    """Pallas SMO kernel on TPU; the jnp oracle elsewhere (interpret-mode
    Pallas is pure overhead on CPU, and the oracle matches `epoch_ref`)."""
    if jax.default_backend() == "tpu":
        from repro.kernels.ops import smo_epoch
        return smo_epoch
    return smo_epoch_oracle


@jax.jit
def _row_sq(G):
    """Per-row squared norms — same op as `solve_one`'s q computation."""
    return jnp.sum(G ** 2, axis=-1)


@jax.jit
def _accum_w(w, G, alpha, y):
    """Warm-start w accumulation: w += (alpha * y) @ G_block."""
    return w + (alpha * y) @ G


def _put(a, device=None):
    """Deliberate H2D transfer of one bounded block.

    Kept as the single host->device choke point: tests run the whole solve
    under `jax.transfer_guard_host_to_device("disallow")` to prove the full
    G is never device-materialised; only these explicit block puts are
    allowed through.
    """
    cm = (_H2D_GUARD("allow") if _H2D_GUARD is not None
          else contextlib.nullcontext())
    with cm:
        return jax.device_put(a) if device is None else jax.device_put(a, device)


# ---------------------------------------------------------------------------
# the streamed batch solver
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Stage2StreamStats:
    """Traffic + convergence accounting of one streamed stage-2 solve."""

    tile_rows: int = 0
    epochs: int = 0
    full_passes: int = 0
    rows_streamed: int = 0            # sum of block rows over all epochs/passes
    blocks_streamed: int = 0
    kernel_calls: int = 0
    bytes_h2d: int = 0
    bytes_d2h: int = 0
    epoch_bytes: List[int] = dataclasses.field(default_factory=list)
    active_history: List[int] = dataclasses.field(default_factory=list)
    seconds: float = 0.0


class _BlockPipeline:
    """The prefetch-deep in-flight queue (async double buffer, cf.
    `streaming.stream_factor_rows`): results are only fetched to host when
    the queue is full or the pass ends, so H2D, compute, and D2H overlap."""

    def __init__(self, prefetch: int, a_g, u_g, q_host, stats):
        self.inflight = collections.deque()
        self.prefetch = max(1, prefetch)
        self.a_g, self.u_g, self.q_host = a_g, u_g, q_host
        self.stats = stats

    def push(self, sel, cnt, items, q_ref):
        self.inflight.append((sel, cnt, items, q_ref))
        if len(self.inflight) >= self.prefetch:
            self._drain_one()

    def flush(self):
        while self.inflight:
            self._drain_one()

    def _drain_one(self):
        sel, cnt, items, q_ref = self.inflight.popleft()
        if q_ref is not None:
            self.q_host[sel] = np.asarray(q_ref)[:cnt]
            self.stats.bytes_d2h += cnt * BYTES_F32
        for t, a_ref, u_ref in items:
            self.a_g[t][sel] = np.asarray(a_ref)[:cnt]
            self.u_g[t][sel] = np.asarray(u_ref)[:cnt]
            self.stats.bytes_d2h += 2 * cnt * BYTES_F32


def solve_batch_streamed(
    G,
    tasks: TaskBatch,
    config: SolverConfig = SolverConfig(),
    *,
    stream_config: Optional[StreamConfig] = None,
    epoch_fn: Optional[Callable] = None,
    device=None,
    return_stats: bool = False,
):
    """Drop-in `solve_batch` over a host-resident G (numpy buffer).

    G row-blocks of `tile` rows stream through `epoch_fn` (the SMO epoch
    kernel contract) with per-task w chained on device; alpha/unchanged live
    on host and are scattered back per block.  Returns a `SolveResult` whose
    fields are host numpy arrays (same shapes/layout as `solve_batch`), plus
    a `Stage2StreamStats` when ``return_stats=True``.
    """
    t_start = time.perf_counter()
    cfg = stream_config or StreamConfig()
    if epoch_fn is None:
        epoch_fn = default_epoch_fn()

    G = np.asarray(G, np.float32)
    n, rank = G.shape
    idx = np.asarray(tasks.idx)
    y_loc = np.asarray(tasks.y, np.float32)
    c_loc = np.asarray(tasks.c, np.float32)
    a0_loc = np.asarray(tasks.alpha0, np.float32)
    T, n_pad = idx.shape

    tile = auto_tile_rows(n, rank, T, cfg)
    stats = Stage2StreamStats(tile_rows=tile)

    # Scatter task-local vectors into global row coordinates: rows outside a
    # task carry c = 0 and are inert, exactly like the monolithic padding.
    y_g = np.ones((T, n), np.float32)
    c_g = np.zeros((T, n), np.float32)
    a_g = np.zeros((T, n), np.float32)
    u_g = np.zeros((T, n), np.int32)
    real_loc = c_loc > 0.0
    for t in range(T):
        r = idx[t][real_loc[t]]
        y_g[t, r] = y_loc[t][real_loc[t]]
        c_g[t, r] = c_loc[t][real_loc[t]]
        a_g[t, r] = np.clip(a0_loc[t][real_loc[t]], 0.0, c_loc[t][real_loc[t]])

    q_host = np.zeros((n,), np.float32)
    have_q = False
    w = [_put(np.zeros((rank,), np.float32), device) for _ in range(T)]
    pipe = _BlockPipeline(cfg.prefetch, a_g, u_g, q_host, stats)

    period = config.full_pass_period if config.shrink else 1
    shrink_k = config.shrink_k if config.shrink else 1 << 30

    def _padded(vec, fill, dtype):
        if vec.shape[0] == tile:
            return np.ascontiguousarray(vec, dtype)
        buf = np.full((tile,), fill, dtype)
        buf[: vec.shape[0]] = vec
        return buf

    def _pass(rows, live, *, full: bool, compute_q: bool,
              accumulate_w_only: bool = False, blk_active=None,
              rows_G=None, rows_q=None):
        """Stream one epoch (or the warm-start init pass) over `rows`
        (None = all of G); returns per-task violation refs on full passes.
        ``rows_G``/``rows_q`` are the once-per-compaction gathers of
        G[rows]/q[rows], so cheap-epoch blocks slice views instead of
        re-fancy-indexing the full host G every epoch."""
        m = n if rows is None else len(rows)
        n_blocks = math.ceil(m / tile)
        viol_refs = {t: [] for t in live}
        h2d_before = stats.bytes_h2d
        for b in range(n_blocks):
            s, e = b * tile, min((b + 1) * tile, m)
            cnt = e - s
            if rows is None:
                sel = slice(s, e)
                gb_host = G[s:e]
            else:
                sel = rows[s:e]
                gb_host = rows_G[s:e] if rows_G is not None else G[sel]
            if cnt < tile:
                pad = np.zeros((tile, rank), np.float32)
                pad[:cnt] = gb_host
                gb_host = pad
            gb = _put(gb_host, device)
            stats.bytes_h2d += gb.nbytes
            if compute_q:
                qb = _row_sq(gb)
                q_ref = qb
            else:
                qsrc = (rows_q[s:e] if rows_q is not None and rows is not None
                        else q_host[sel])
                qb = _put(_padded(qsrc, 0.0, np.float32), device)
                q_ref = None
                stats.bytes_h2d += qb.nbytes
            items = []
            for t in live:
                if blk_active is not None and not blk_active[t][b]:
                    continue
                ab = _put(_padded(a_g[t][sel], 0.0, np.float32), device)
                yb = _put(_padded(y_g[t][sel], 1.0, np.float32), device)
                stats.bytes_h2d += ab.nbytes + yb.nbytes
                if accumulate_w_only:
                    w[t] = _accum_w(w[t], gb, ab, yb)
                    stats.kernel_calls += 1
                    continue
                cb = _put(_padded(c_g[t][sel], 0.0, np.float32), device)
                ub = _put(_padded(u_g[t][sel], 0, np.int32), device)
                stats.bytes_h2d += cb.nbytes + ub.nbytes
                a2, u2, w2, viol = epoch_fn(
                    gb, yb, cb, qb, ab, ub, w[t],
                    full_pass=full, shrink_k=shrink_k)
                w[t] = w2
                items.append((t, a2, u2))
                stats.kernel_calls += 1
                if full:
                    viol_refs[t].append(viol)
            pipe.push(sel, cnt, items, q_ref)
            stats.blocks_streamed += 1
            stats.rows_streamed += cnt
        pipe.flush()
        stats.epoch_bytes.append(stats.bytes_h2d - h2d_before)
        return viol_refs

    all_tasks = list(range(T))
    # Warm starts need w0 = (alpha0 * y) @ G before the first coordinate
    # update, which costs one extra accumulation stream (it also fills q).
    if a_g.any():
        warm_live = [t for t in all_tasks if a_g[t].any()]
        _pass(None, warm_live, full=False, compute_q=True,
              accumulate_w_only=True)
        stats.epoch_bytes.pop()      # init pass is not an epoch
        have_q = True

    done = np.zeros((T,), bool)
    violation = np.full((T,), np.inf, np.float32)
    epochs_used = np.full((T,), config.max_epochs, np.int32)
    act: Optional[np.ndarray] = None          # compacted active-row union
    act_G = act_q = None                      # host gathers of G[act], q[act]
    blk_active = None                         # per-task block occupancy
    epochs_run = 0

    for epoch in range(config.max_epochs):
        live = [t for t in all_tasks if not done[t]]
        if not live:
            break
        full = (epoch % period == 0) or not config.shrink
        epochs_run = epoch + 1
        if full:
            viol_refs = _pass(None, live, full=True, compute_q=not have_q)
            have_q = True
            stats.full_passes += 1
            for t in live:
                v = max(float(np.asarray(r)) for r in viol_refs[t])
                violation[t] = v
                if v < config.tol:
                    done[t] = True
                    epochs_used[t] = epoch + 1
            # Re-compact: cheap epochs stream only rows active for at least
            # one unconverged task — shrinking cuts H2D bytes, not just FLOPs.
            act, act_G, act_q, blk_active = None, None, None, None
            live2 = [t for t in all_tasks if not done[t]]
            if config.shrink and live2:
                masks = (c_g[live2] > 0.0) & (u_g[live2] < shrink_k)
                union = np.where(masks.any(axis=0))[0]
                stats.active_history.append(int(len(union)))
                if len(union) < n:
                    act = union
                    act_G, act_q = G[act], q_host[act]
                    n_blocks = math.ceil(max(len(act), 1) / tile)
                    # Block b of a cheap epoch covers GLOBAL rows
                    # act[b*tile:(b+1)*tile]; a task skips it only when none
                    # of those rows are active for it.
                    blk_active = {
                        t: np.array([m[act[b * tile:(b + 1) * tile]].any()
                                     for b in range(n_blocks)])
                        for t, m in zip(live2, masks)
                    }
        else:
            if act is not None and len(act) == 0:
                continue    # everything shrunk: the epoch is a no-op
            _pass(act, live, full=False, compute_q=False,
                  blk_active=blk_active, rows_G=act_G, rows_q=act_q)

    stats.epochs = epochs_run

    # ------------------------------------------------------------- results
    W = np.stack([np.asarray(wt) for wt in w]) if T else np.zeros((0, rank))
    stats.bytes_d2h += W.nbytes
    alpha = np.zeros_like(a0_loc)
    for t in range(T):
        alpha[t][real_loc[t]] = a_g[t][idx[t][real_loc[t]]]
    dual = a_g.sum(axis=1) - 0.5 * (W * W).sum(axis=1)
    n_sv = (alpha > 0.0).sum(axis=1).astype(np.int32)
    stats.seconds = time.perf_counter() - t_start
    res = SolveResult(alpha=alpha, w=W.astype(np.float32),
                      epochs=epochs_used, violation=violation,
                      dual_obj=dual.astype(np.float32), n_sv=n_sv)
    return (res, stats) if return_stats else res
