"""Out-of-core stage 2: stream G row-blocks through the SMO epoch.

The paper keeps alpha on the GPU and the full factor G in host RAM ("more
RAM!"), so the trainable n is bounded by the 512 GB-class host, not device
HBM.  `dual_solver.solve_batch` re-materialises all of G on device when it
traces, silently re-capping n at HBM; this module closes that gap:

    host RAM                              device HBM
    ───────────────────────────────       ────────────────────────────────
    G        (n, B)   read-only           w        (T, B)   resident, chained
    alpha    (T, n)   scattered back      per block: G[s:e], y/c/q/alpha/
    unchanged(T, n)   per block                      unchanged slices

Per epoch, (tile, B) row-blocks of G are `device_put` with the same
prefetch-deep async double buffering as `core/streaming.py` (enqueue block
k+1's H2D + kernel launches before draining block k's alpha back to host),
and every streamed block updates EVERY task before eviction, so the H2D
traffic is amortised over the whole OVO/CV task batch.  The per-task weight
vector w stays device-resident across blocks and epochs — the cross-block
analogue of the SMO kernel's VMEM scratchpad (kernels/smo.py).

The per-epoch block pass lives in `_Stage2Engine`, a per-(device, task-shard)
state machine: a driver (`drive_streamed_engines`) owns the lockstep epoch
schedule, reads each (tile, B) block of G ONCE per shared pass
(`iter_shared_blocks`) and fans it out to every live engine, while compacted
cheap epochs run engine-locally over each shard's own active-row union.
`solve_batch_streamed` is the one-engine instantiation; the overlapped
multi-device task farm (`core/distributed.py::solve_tasks_streamed`) drives
many engines behind per-device host workers so H2D, compute, and D2H overlap
ACROSS devices and the host-resident G is streamed once per pass instead of
once per device.  Blocks can optionally cross the bus as bfloat16
(`StreamConfig.block_dtype="bf16"`, upcast on device) for half the stage-2
H2D bytes, or as int8 with per-row-group scale/zero tables
(`block_dtype="int8"`, the `core/quant.py` codec, dequantised fused on
device) for a quarter of them; `tune_prefetch` closes a minimal
overlap-autotune loop: when the first full pass measures H2D time exceeding
the compute/drain time it is meant to hide, the in-flight queue is deepened.

Shrinking follows `core/compact.py`'s bucket-compaction design, but here it
cuts H2D *bytes*, not just FLOPs: after every full pass the union of active
rows over all unconverged tasks is gathered host-side, and the cheap epochs
stream only those rows.

Task state is held in task-LOCAL streamed coordinates: per task, the sorted
real (c > 0) global row ids plus (y, c, alpha, unchanged) vectors of that
length, so host memory is O(sum task sizes) — not the O(T * n) a
global-coordinate scatter would cost (a ~k/2 blowup for OVO, and a
T/pairs-fold one for the CV-grid task farm where T = pairs x folds x |Cs|).
Each streamed block touches a task through a `searchsorted` WINDOW: a
precomputed per-task boundary table maps block b to the contiguous id slice
lo:hi whose rows fall inside the block, the (hi - lo) block-local rows are
gathered on device, and the epoch kernel sweeps only them — kernel work is
O(sum task sizes) per pass too (`Stage2StreamStats.coord_visits`).  Sweeping
a task's rows in sorted-global order is exactly what the inert-padded global
sweep did, so the streamed trajectory still reproduces the monolithic
`solve_one` trajectory to float accumulation order, including shrinking
counters and warm starts.

Requirements on the TaskBatch: each task's real (c > 0) rows must be unique;
sorted idx (what `build_ovo_tasks`/`build_cv_tasks` produce) additionally
gives trajectory-exact parity with the monolithic path (unsorted idx is
re-sorted internally — the sweep is global-row-ordered either way).

The task axis can also carry a C-LADDER: `chain_next[t] = s` declares task s
the warm-start successor of task t over the same rows (the CV grid's next-C
cell, `cv.build_cv_grid_tasks`).  Successor cells start dormant; when a
predecessor converges at a full pass its alphas are clipped into the new box
as the successor's seed, the successor's w0 accumulation rides the next
shared full pass (the driver promotes it), and the retired cell stops
consuming kernel calls — one G stream trains the whole grid.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import math
import time
from functools import partial
from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np

from repro.core.block_cache import (HotRowBlockCache, block_key,
                                    stage2_cache_budget,
                                    violation_recency_scores_tasks)
from repro.core.dual_solver import (DELTA_EPS, Q_FLOOR, SolveResult,
                                    SolverConfig, TaskBatch)
from repro.core.faults import check as _fault_check
from repro.core.faults import classify_error
from repro.core.quant import (GROUP_ROWS, QuantBlock, dequant_rows,
                              encode_rows, group_scales, quantize_block)
from repro.core.streaming import BYTES_F32, StreamConfig, tune_prefetch
from repro.core.trace import resolve as resolve_tracer

_H2D_GUARD = getattr(jax, "transfer_guard_host_to_device", None)


# ---------------------------------------------------------------------------
# stage-2 memory budget model (documented in docs/architecture.md)
# ---------------------------------------------------------------------------

def stage2_resident_bytes(rank: int, n_tasks: int) -> int:
    """Device-resident stage-2 state: one (B,) weight vector per task."""
    return n_tasks * rank * BYTES_F32


def stage2_block_bytes(tile: int, rank: int, n_tasks: int) -> int:
    """Working set of ONE in-flight block: the G tile plus, per task, the
    five input vectors (y, c, q, alpha, unchanged) and two outputs."""
    return tile * (rank + 7 * n_tasks) * BYTES_F32


def stage2_monolithic_bytes(n: int, rank: int, n_tasks: int, n_pad: int) -> int:
    """Device working set of `solve_batch`: full G + per-task vectors."""
    return (n * rank + n_tasks * (7 * n_pad + 2 * rank)) * BYTES_F32


def should_stream_stage2(n: int, rank: int, n_tasks: int, n_pad: int,
                         cfg: StreamConfig) -> bool:
    """True when the monolithic stage-2 working set blows the device budget."""
    return stage2_monolithic_bytes(n, rank, n_tasks, n_pad) > cfg.device_budget_bytes


def route_stage2(factor, tasks: TaskBatch, stream,
                 stream_config: Optional[StreamConfig],
                 solve_fn, default_solve_fn) -> bool:
    """The ONE stage-2 routing predicate (`LPDSVM.fit`, `core/cv.py`, CLI):
    stream G row-blocks when G is already host-resident (`factor.streamed`),
    streaming is forced, or the monolithic working set exceeds the device
    budget.  A custom ``solve_fn`` (e.g. the sharded task farm) is always
    respected, and ``stream=False`` pins the monolithic path.
    """
    if solve_fn is not default_solve_fn or stream is False:
        return False
    if stream or getattr(factor, "streamed", False):
        return True
    if stream_config is None:
        return False
    n, rank = factor.G.shape
    return should_stream_stage2(n, rank, tasks.n_tasks, tasks.idx.shape[1],
                                stream_config)


def auto_tile_rows(n: int, rank: int, n_tasks: int, cfg: StreamConfig) -> int:
    """Largest row tile whose `prefetch` in-flight blocks fit the budget.

    Solves  prefetch * stage2_block_bytes(t) + resident <= budget  for t,
    floored at `min_chunk_rows` (tiny budgets should not degenerate into
    per-row dispatch) and rounded up to a multiple of 8.  An EXPLICIT
    `cache_budget_bytes` is carved out of the free bytes first — that HBM is
    promised to the hot-row block cache; the default derived cache budget is
    *defined* as whatever this model leaves over (`stage2_cache_budget`), so
    it never shrinks the tile.
    """
    if cfg.tile_rows is not None:
        return max(8, -(-min(cfg.tile_rows, n) // 8) * 8)
    free = cfg.device_budget_bytes - stage2_resident_bytes(rank, n_tasks)
    if cfg.cache_blocks and cfg.cache_budget_bytes:
        free -= cfg.cache_budget_bytes
    per_row = cfg.prefetch * (rank + 7 * n_tasks) * BYTES_F32
    rows = (free // per_row) // 8 * 8 if free > 0 else 0   # round down: budget
    return int(min(-(-n // 8) * 8, max(cfg.min_chunk_rows, rows, 8)))


# ---------------------------------------------------------------------------
# block-epoch kernels
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("full_pass", "shrink_k"))
def smo_epoch_oracle(G, y, c, q, alpha, unchanged, w, *, full_pass: bool,
                     shrink_k: int):
    """One sequential coordinate-ascent sweep over a (tile, B) block.

    Flat 1-D vectors in/out, same contract as `kernels.ops.smo_epoch`; the
    body mirrors `dual_solver.epoch_ref` op-for-op so that chaining blocks
    reproduces the monolithic trajectory exactly.
    """
    n = G.shape[0]

    def body(i, state):
        alpha, w, unchanged, viol = state
        row = G[i]
        a_i, c_i, y_i, q_i = alpha[i], c[i], y[i], q[i]
        active = jnp.logical_and(
            c_i > 0.0, jnp.logical_or(full_pass, unchanged[i] < shrink_k))
        g = 1.0 - y_i * jnp.dot(w, row)
        at_lo = a_i <= 0.0
        at_hi = a_i >= c_i
        pg = jnp.where(at_lo, jnp.maximum(g, 0.0),
                       jnp.where(at_hi, jnp.minimum(g, 0.0), g))
        pg = jnp.where(c_i > 0.0, pg, 0.0)
        a_new = jnp.clip(a_i + g / jnp.maximum(q_i, Q_FLOOR), 0.0, c_i)
        a_new = jnp.where(active, a_new, a_i)
        delta = a_new - a_i
        w = w + (delta * y_i) * row
        alpha = alpha.at[i].set(a_new)
        changed = jnp.abs(delta) > DELTA_EPS
        u_new = jnp.where(changed, 0, unchanged[i] + 1)
        u_new = jnp.where(active, u_new, unchanged[i])
        unchanged = unchanged.at[i].set(u_new)
        viol = jnp.where(active, jnp.maximum(viol, jnp.abs(pg)), viol)
        return alpha, w, unchanged, viol

    alpha, w, unchanged, viol = jax.lax.fori_loop(
        0, n, body, (alpha, w, unchanged, jnp.float32(0.0)))
    return alpha, unchanged, w, viol


def default_epoch_fn() -> Callable:
    """Pallas SMO kernel on TPU; the jnp oracle elsewhere (interpret-mode
    Pallas is pure overhead on CPU, and the oracle matches `epoch_ref`)."""
    if jax.default_backend() == "tpu":
        from repro.kernels.ops import smo_epoch
        return smo_epoch
    return smo_epoch_oracle


@jax.jit
def _row_sq(G):
    """Per-row squared norms — same op as `solve_one`'s q computation.

    Recomputed on device from the streamed block every pass: q is a pure
    function of the block's bytes, so this is bit-identical to caching it on
    host while saving the q H2D/D2H round trips entirely.
    """
    return jnp.sum(G ** 2, axis=-1)


@jax.jit
def _accum_w(w, G, alpha, y):
    """Warm-start w accumulation: w += (alpha * y) @ G_block."""
    return w + (alpha * y) @ G


@jax.jit
def _upcast32(g):
    """Device-side upcast of a bf16 wire block back to the fp32 the epoch
    kernels accumulate in (the H2D copy moved half the bytes)."""
    return g.astype(jnp.float32)


@jax.jit
def _window(gb, qb, rl):
    """Device gather of one task's window out of a streamed block: the
    (win,) block-local row ids ``rl`` select the task's rows (and their
    precomputed q) so the epoch kernel sweeps only them."""
    return gb[rl], qb[rl]


@jax.jit
def _gather_rows(gb, rl):
    return gb[rl]


def _win_pad(m: int) -> int:
    """Pow2-bucketed device window length (floor 8): window kernels compile
    once per bucket instead of once per ragged window size; pad rows carry
    c = 0 and are inert in the epoch kernel."""
    return max(8, 1 << (int(m) - 1).bit_length())


def block_windows(ids: np.ndarray, tile: int, n_blocks: int) -> np.ndarray:
    """Boundary table of a task's SORTED global row ids against the block
    grid: entry b is the first position in ``ids`` at or past row b * tile,
    so block b's window is the contiguous slice bounds[b]:bounds[b+1] and
    its block-local rows are ids[lo:hi] - b * tile.  One O(m log m)
    searchsorted per task at engine build; O(1) per (task, block) after —
    the mapping that makes host state and kernel work O(sum task sizes)."""
    edges = np.arange(n_blocks + 1, dtype=np.int64) * tile
    return np.searchsorted(np.asarray(ids, np.int64), edges, side="left")


def _put(a, device=None):
    """Deliberate H2D transfer of one bounded block.

    Kept as the single host->device choke point: tests run the whole solve
    under `jax.transfer_guard_host_to_device("disallow")` to prove the full
    G is never device-materialised; only these explicit block puts are
    allowed through.
    """
    cm = (_H2D_GUARD("allow") if _H2D_GUARD is not None
          else contextlib.nullcontext())
    with cm:
        return jax.device_put(a) if device is None else jax.device_put(a, device)


# ---------------------------------------------------------------------------
# the streamed batch solver: stats, block reader, per-device engine, driver
# ---------------------------------------------------------------------------

BLOCK_DTYPES = {"f32": np.float32, "bf16": ml_dtypes.bfloat16,
                "int8": np.int8}


def wire_group(tile: int, cfg: StreamConfig) -> int:
    """Effective int8 scale-group rows for a given block tile.

    Group boundaries must ALIGN with block boundaries so that a row's
    encoding is the same whether it travels in a shared full-pass block or a
    compacted cheap-epoch block (group stats are global-row-aligned either
    way); `auto_tile_rows` makes every tile a multiple of 8, so
    gcd(tile, requested) is at least 8 for the default group of 32 — the
    scale overhead stays at 8 bytes per >= 8 rows."""
    return math.gcd(tile, max(1, cfg.quant_group_rows))


@dataclasses.dataclass
class Stage2StreamStats:
    """Traffic + convergence accounting of one streamed stage-2 solve.

    On a multi-device farm this is the MESH-level record.  Two H2D views:

    * `bytes_h2d` — UNIQUE bytes read out of the host-resident G (plus the
      partitioned per-task vector traffic).  Shared-pass G blocks count
      once no matter how many devices consume them: the host-RAM read and
      staging (pad/cast) happen once, which is what the shared reader
      dedupes — so per-pass `bytes_h2d` is independent of device count.
    * `bytes_put` — PHYSICAL per-device DMA bytes issued (each device still
      copies every broadcast block into its own memory, so the G component
      scales with device count; on real hardware those copies ride
      parallel per-device DMA engines).  Size bus bandwidth from this one.

    The unmerged per-device views live in `per_device`.
    """

    tile_rows: int = 0
    epochs: int = 0
    full_passes: int = 0
    rows_streamed: int = 0            # sum of block rows over all epochs/passes
    blocks_streamed: int = 0
    kernel_calls: int = 0
    coord_visits: int = 0             # real task-rows swept by epoch kernels
                                      # (the windowed analogue of the
                                      # monolithic epochs.sum() * task size)
    bytes_h2d: int = 0
    bytes_d2h: int = 0
    bytes_g: int = 0                  # G-block component of bytes_h2d alone
                                      # (shared-pass stages + compacted-epoch
                                      # misses; excludes per-task vectors) —
                                      # the figure the grid farm's "one pass
                                      # set per grid" claim is asserted on
    bytes_scales: int = 0             # int8 codec scale-table bytes (already
                                      # included in bytes_h2d / bytes_put —
                                      # broken out so the exact-byte
                                      # invariants stay assertable)
    epoch_bytes: List[int] = dataclasses.field(default_factory=list)
    active_history: List[int] = dataclasses.field(default_factory=list)
    # ^ per compaction: active-row union size (single device) / total rows
    #   streamed per cheap epoch across shards (mesh — unions may overlap)
    # HBM block-cache accounting.  Every compacted cheap-epoch G block lands
    # in exactly ONE of hit/miss: `bytes_miss` is what crossed the bus
    # (already inside `bytes_h2d`), `bytes_hit` is what the pinned union
    # served device-side instead.  With caching off every compacted block is
    # a miss, so cached.bytes_hit + cached.bytes_miss == uncached.bytes_miss
    # and cached.bytes_h2d == uncached.bytes_h2d - cached.bytes_hit — the
    # exact identities tests/test_block_cache.py asserts.
    bytes_hit: int = 0                # cache-served G bytes (zero H2D)
    bytes_miss: int = 0               # compacted cheap-epoch G bytes shipped
    cache_hits: int = 0               # block-granular counters of the same
    cache_misses: int = 0
    cache_evictions: int = 0
    cache_resident_bytes: int = 0     # peak pinned HBM bytes (sum over
                                      # devices on a farm)
    epoch_hit_bytes: List[int] = dataclasses.field(default_factory=list)
    epoch_miss_bytes: List[int] = dataclasses.field(default_factory=list)
    # ^ per-epoch hit/miss deltas, index-aligned with `epoch_bytes`, so
    #   benchmarks plot byte decay vs hit-rate without re-deriving it
    seconds: float = 0.0
    block_dtype: str = "f32"
    n_devices: int = 1
    bytes_put: int = 0                # physical per-device DMA bytes
    put_seconds: float = 0.0          # host time inside H2D puts
    drain_seconds: float = 0.0        # host time blocked on result fetches
    prefetch_final: int = 0           # queue depth after autotune
    per_device: Optional[List["Stage2StreamStats"]] = None

    @property
    def epoch_hit_rate(self) -> List[float]:
        """Per-epoch cache-hit fraction of compacted G bytes (0.0 for epochs
        with no compacted traffic, e.g. full passes)."""
        return [h / (h + m) if h + m else 0.0
                for h, m in zip(self.epoch_hit_bytes, self.epoch_miss_bytes)]

    @property
    def h2d_gbps(self) -> float:
        """Effective H2D rate over host put time (GB/s), on the PHYSICAL
        per-device DMA bytes (`bytes_put`) — put time is spent issuing every
        copy, broadcast or not."""
        return self.bytes_put / max(self.put_seconds, 1e-12) / 1e9

    @property
    def overlap_efficiency(self) -> float:
        """Stall-free fraction of the wall clock: 1 minus the share spent
        blocked in puts/drains, clamped to [0, 1].  The trace-level
        `Tracer.overlap_efficiency` is the per-span timeline analogue."""
        if self.seconds <= 0.0:
            return 0.0
        busy = (self.put_seconds + self.drain_seconds) / self.seconds
        return min(1.0, max(0.0, 1.0 - busy))


class _PadStage:
    """One reusable padded staging buffer for ragged tail blocks.

    `prep_block` used to `np.zeros((tile, B))` for EVERY ragged tail — once
    per pass per solve, and once per cheap epoch per engine.  A stage owns
    that buffer instead: allocated once, zero-tail-refreshed per use.  Reuse
    is safe wherever the previous occupant has been consumed before the next
    `pad` call: `jax.device_put` copies the host buffer before returning, so
    an engine's own sequential block loop may always reuse, and the shared
    reader may reuse ACROSS passes because the driver barriers every pass
    (all queued fan-out closures have run).  The int8 wire pads its encoded
    values through the same buffer (an int8 one) under the same rules.
    """

    def __init__(self, tile: int, rank: int, block_dtype: str):
        # int8 tails are padded AFTER encoding (zero codes + inert scale
        # entries), so the staging buffer holds the wire dtype either way.
        self.buf = np.zeros((tile, rank), BLOCK_DTYPES[block_dtype])

    def pad(self, gb: np.ndarray) -> np.ndarray:
        cnt = gb.shape[0]
        self.buf[:cnt] = gb
        self.buf[cnt:] = 0
        return self.buf


def pad_quant_block(qb: QuantBlock, tile: int,
                    stage: Optional[_PadStage] = None) -> QuantBlock:
    """Pad a quantised block to ``tile`` rows: zero codes for the pad rows
    and inert (scale 1, zero 0) entries for all-pad scale groups, so pads in
    a FULL pad group dequantise to exact zeros; pads sharing a ragged real
    group decode to that group's zero-point — harmless, the epoch kernel
    treats their c = 0 rows as inert."""
    cnt, ng = qb.values.shape[0], qb.scales.shape[0]
    ng_pad = -(-tile // qb.group)
    if stage is not None:
        values = stage.pad(qb.values)
    else:
        values = np.zeros((tile, qb.values.shape[1]), np.int8)
        values[:cnt] = qb.values
    scales = np.zeros((ng_pad, 2), np.float32)
    scales[:ng] = qb.scales
    scales[ng:, 0] = 1.0
    return QuantBlock(values=values, scales=scales, group=qb.group)


def prep_block(gb: np.ndarray, tile: int, block_dtype: str,
               group: int = GROUP_ROWS, stage: Optional[_PadStage] = None):
    """Pad a host G row-block to ``tile`` rows and encode it in the wire
    format: an f32/bf16 ndarray, or a `QuantBlock` (int8 values + per-row-
    group f32 scale/zero table) for ``block_dtype="int8"``.

    Full-tile f32/bf16 blocks already in the wire dtype pass through as views
    of an (immutable) host buffer — G itself, or an engine's wire-dtype
    `act_G` gather; a block that needs padding or casting gets a buffer from
    ``stage`` (reusable, see `_PadStage`) or a fresh one.  int8 blocks are
    quantised from the REAL rows only and padded after encoding
    (`pad_quant_block`) — with ``group`` dividing ``tile`` (see `wire_group`)
    the group stats equal the global-row-aligned stats, so a row's code is
    block-shape-independent and the shrinking-compacted cheap epochs re-emit
    the same decoded values (to FMA rounding).
    """
    if block_dtype == "int8":
        qb = quantize_block(np.asarray(gb, np.float32), group)
        return qb if gb.shape[0] == tile else pad_quant_block(qb, tile, stage)
    if gb.shape[0] == tile and gb.dtype == BLOCK_DTYPES[block_dtype]:
        return gb
    if gb.shape[0] != tile and stage is not None:
        # Only the ONE ragged tail per pass may use the shared stage buffer:
        # full-tile casts (bf16) must stay fresh — several sit in per-device
        # queues at once.
        return stage.pad(gb)
    buf = np.zeros((tile, gb.shape[1]), BLOCK_DTYPES[block_dtype])
    buf[: gb.shape[0]] = gb
    return buf


def iter_shared_blocks(G: np.ndarray, tile: int, block_dtype: str,
                       group: int = GROUP_ROWS,
                       stage: Optional[_PadStage] = None, trace=None):
    """The shared host block reader: yield each (tile, B) row-block of G
    exactly once as ``(sel, cnt, gb_send)`` — the driver fans every yielded
    buffer out to all live engines, so a full pass reads G (and, for the
    int8 wire, quantises it) once regardless of device count.  ``stage`` is
    the caller-owned reusable pad buffer; the driver allocates it once per
    solve and its per-pass barrier makes cross-pass reuse safe.  ``trace``
    records one ``read`` span per staged block (the host-RAM read + pad /
    encode work the reader dedupes across devices)."""
    n = G.shape[0]
    tr = resolve_tracer(trace)
    for b in range(math.ceil(n / tile)):
        s, e = b * tile, min((b + 1) * tile, n)
        t0 = tr.begin()
        try:
            _fault_check("reader", block=b)
            gb_send = prep_block(G[s:e], tile, block_dtype, group, stage)
        except BaseException as exc:
            # Close the in-flight span before propagating so a failed run
            # still exports a valid, complete trace timeline.
            tr.end("read", "stage_block", t0, rows=e - s, block=b,
                   error=type(exc).__name__)
            tr.instant("fault", "reader_error", block=b,
                       error=type(exc).__name__)
            raise
        tr.end("read", "stage_block", t0, bytes=int(gb_send.nbytes),
               rows=e - s, block=b)
        yield slice(s, e), e - s, gb_send


class _BlockPipeline:
    """The prefetch-deep in-flight queue (async double buffer, cf.
    `streaming.stream_factor_rows`): results are only fetched to host when
    the queue is full or the pass ends, so H2D, compute, and D2H overlap.
    ``prefetch`` is mutable — the overlap-autotune loop deepens it when the
    first full pass measures transfer lagging compute."""

    def __init__(self, prefetch: int, a_r, u_r, stats, trace=None):
        self.inflight = collections.deque()
        self.prefetch = max(1, prefetch)
        self.a_r, self.u_r = a_r, u_r
        self.stats = stats
        self.trace = resolve_tracer(trace)

    def push(self, items):
        if not items:
            return
        self.inflight.append(items)
        if len(self.inflight) >= self.prefetch:
            self._drain_one()

    def flush(self):
        while self.inflight:
            self._drain_one()

    def _drain_one(self):
        items = self.inflight.popleft()
        t0 = self.trace.begin()
        nb = 0
        for t, take, m, a_ref, u_ref in items:
            # ``take`` addresses the window in the task-LOCAL arrays: a
            # contiguous slice on full passes, an active-position gather on
            # compacted cheap epochs.
            self.a_r[t][take] = np.asarray(a_ref)[:m]
            self.u_r[t][take] = np.asarray(u_ref)[:m]
            self.stats.bytes_d2h += 2 * m * BYTES_F32
            nb += 2 * m * BYTES_F32
        self.stats.drain_seconds += self.trace.end(
            "drain", "block_drain", t0, bytes=nb, windows=len(items))


def _padded(vec, fill, dtype, tile):
    if vec.shape[0] == tile:
        return np.ascontiguousarray(vec, dtype)
    buf = np.full((tile,), fill, dtype)
    buf[: vec.shape[0]] = vec
    return buf


class _Stage2Engine:
    """One device's streamed stage-2 state machine — the reusable per-epoch
    block pass (window selection, q computation, SMO step, pipeline drain,
    shrinking compaction) parameterised by (device, task shard, w state).

    The engine owns its shard's host-side TASK-LOCAL coordinate state
    (sorted real row ids + y/c/alpha/unchanged of each task's own length —
    O(sum task sizes), never O(T * n)), the per-task `searchsorted` window
    tables against the block grid, the device-resident per-task w vectors,
    and the in-flight block pipeline.  A driver (`drive_streamed_engines`)
    owns the lockstep epoch schedule and feeds shared full-G passes block by
    block; compacted cheap epochs run engine-locally (`run_cheap_epoch`)
    over the shard's own active-row union.  Engines never count shared-pass
    G bytes — the reader stages each block once and accounts for it once —
    only their task-vector traffic and their own compacted-epoch gathers.

    ``chain_next`` lifts the task axis to warm-start LADDERS (the CV grid's
    ascending-C cells): successor tasks start dormant, are seeded from their
    converged predecessor's alphas, accumulate w0 during the next shared
    full pass (`pending_init`), and only then join the live sweep.
    """

    def __init__(self, G, tasks: TaskBatch, config: SolverConfig,
                 cfg: StreamConfig, *, epoch_fn: Callable, device, tile: int,
                 scale_cache: Optional[dict] = None, chain_next=None,
                 name: str = "dev0", task_ids=None):
        self.G = G
        self.config, self.cfg = config, cfg
        self.epoch_fn, self.device, self.tile = epoch_fn, device, tile
        self.name = name
        # Global task indices of this shard — the key space snapshots are
        # written in, so a checkpoint restores onto ANY device split.
        self.task_ids = (np.arange(tasks.n_tasks, dtype=np.int64)
                         if task_ids is None
                         else np.asarray(task_ids, np.int64))
        # Transient-H2D retry policy: 0 retries under fail_fast (the default
        # pre-PR semantics — a put either succeeds or raises immediately).
        self._retries = 0 if cfg.fail_fast else cfg.max_retries
        self._backoff = cfg.retry_backoff
        n, rank = G.shape
        self.n, self.rank = n, rank
        self.idx = np.asarray(tasks.idx)
        self.y_loc = np.asarray(tasks.y, np.float32)
        self.c_loc = np.asarray(tasks.c, np.float32)
        self.a0_loc = np.asarray(tasks.alpha0, np.float32)
        self.T, self.n_pad = self.idx.shape
        T = self.T

        # Task-LOCAL streamed coordinates: per task, the globally sorted
        # real (c > 0) rows and their solver state, plus the full-pass
        # window boundary table against the block grid.  `scat` remembers
        # each sorted row's position in the task's original padded layout
        # for the result scatter.
        self.real_loc = self.c_loc > 0.0
        self.n_blocks = math.ceil(n / tile)
        self.ids: List[np.ndarray] = []
        self.scat: List[np.ndarray] = []
        self.y_r: List[np.ndarray] = []
        self.c_r: List[np.ndarray] = []
        self.a_r: List[np.ndarray] = []
        self.u_r: List[np.ndarray] = []
        self.bounds: List[np.ndarray] = []
        for t in range(T):
            pos = np.where(self.real_loc[t])[0]
            ids = self.idx[t][pos].astype(np.int64)
            order = np.argsort(ids, kind="stable")
            ids, pos = ids[order], pos[order]
            self.ids.append(ids)
            self.scat.append(pos)
            self.y_r.append(np.ascontiguousarray(self.y_loc[t][pos]))
            self.c_r.append(np.ascontiguousarray(self.c_loc[t][pos]))
            self.a_r.append(np.clip(self.a0_loc[t][pos], 0.0, self.c_r[t]))
            self.u_r.append(np.zeros(len(ids), np.int32))
            self.bounds.append(block_windows(ids, tile, self.n_blocks))

        # C-ladder lifecycle: cold roots sweep from epoch 0; warm roots ride
        # the init pass first (pending); successor cells wait for their
        # predecessor's converged alphas.  `active` means "has its w0 and is
        # sweeping"; `first_sweep` anchors per-task LOCAL epoch counting so
        # `epochs_used` matches what a standalone solve of the cell reports.
        self.chain_next = (np.full((T,), -1, np.int64) if chain_next is None
                           else np.asarray(chain_next, np.int64))
        succ = {int(s) for s in self.chain_next if s >= 0}
        root = [t not in succ for t in range(T)]
        self.pending_init: List[int] = [t for t in range(T)
                                        if root[t] and self.a_r[t].any()]
        pend = set(self.pending_init)
        self.active = np.array([root[t] and t not in pend
                                for t in range(T)], bool)
        self.first_sweep = np.zeros((T,), np.int32)

        self.stats = Stage2StreamStats(tile_rows=tile,
                                       block_dtype=cfg.block_dtype)
        self.trace = resolve_tracer(cfg.trace)
        self.w = [_put(np.zeros((rank,), np.float32), device)
                  for _ in range(T)]
        self.pipe = _BlockPipeline(cfg.prefetch, self.a_r, self.u_r,
                                   self.stats, trace=self.trace)
        self.done = np.zeros((T,), bool)
        self.violation = np.full((T,), np.inf, np.float32)
        self.epochs_used = np.full((T,), config.max_epochs, np.int32)
        self.epochs_run = 0
        self.act: Optional[np.ndarray] = None    # compacted active-row union
        self.act_G: Optional[np.ndarray] = None  # host gather of G[act]
        self.act_q: Optional[List[QuantBlock]] = None
        # ^ int8 wire: per-tile-block quantised shadow of the gather (encoded
        #   once per compaction, reused by every cheap epoch until the next)
        self._cw: dict = {}
        # ^ per-compaction task windows: t -> (take, pos, bounds) where
        #   ``take`` indexes the task-local arrays at its ACTIVE rows,
        #   ``pos`` their sorted positions in the union, and ``bounds`` the
        #   searchsorted block table over pos (compacted analogue of
        #   `self.bounds`); restricting a task to its compaction-time active
        #   rows is trajectory-identical to sweeping them as kernel no-ops
        self.shrink_k = config.shrink_k if config.shrink else 1 << 30
        self._bf16 = cfg.block_dtype == "bf16"
        self._wire = cfg.block_dtype
        self._group = wire_group(tile, cfg)
        self._scale_cache = scale_cache if scale_cache is not None else {}
        # ^ lazy global-row-aligned (ng, 2) scale table of G — computed at
        #   the first compaction and SHARED across a farm's engines (they
        #   stream the same G; a concurrent double-compute is a benign race,
        #   both threads derive the identical table) so compacted rows
        #   re-encode with the exact scales their shared-pass blocks used
        self._stage = _PadStage(tile, rank, cfg.block_dtype)
        # ^ engine-local reusable pad buffer for compacted cheap epochs (the
        #   engine's block loop is sequential, so reuse is safe)
        self.cache = (HotRowBlockCache(
            stage2_cache_budget(rank, T, tile, cfg.prefetch, cfg))
            if cfg.cache_blocks else None)
        # ^ per-engine (hence per-device on a farm) HBM block cache over the
        #   compacted active-row union; shared passes never touch it, so the
        #   device-count-independent shared-reader byte invariant survives
        self._act_keys: Optional[List[bytes]] = None
        self._act_sizes: Optional[List[int]] = None
        self._hit_mark = self._miss_mark = 0
        self._epoch = -1
        self._epoch_mark = 0
        self._put_mark = self._drain_mark = 0.0
        self._kind = None
        self._live: List[int] = []
        self._init_live: List[int] = []
        self._viol = {}

    @property
    def host_state_bytes(self) -> int:
        """Host coordinate-state footprint: the O(sum task sizes) local
        arrays plus the O(T * n / tile) window boundary tables — the memory
        model the grid farm's T >> pairs regime depends on (asserted by the
        memory-model test: no O(T * n) allocation)."""
        per_task = sum(a.nbytes for arrs in (self.ids, self.scat, self.y_r,
                                             self.c_r, self.a_r, self.u_r)
                       for a in arrs)
        return per_task + sum(b.nbytes for b in self.bounds)

    # ------------------------------------------------------------ scheduling
    @property
    def needs_init(self) -> bool:
        """Warm starts need w0 = (alpha0 * y) @ G before the first update."""
        return bool(self.pending_init)

    @property
    def wants_full(self) -> bool:
        """True while freshly seeded ladder successors wait for their w0
        accumulation: it needs FULL row coverage, so the driver promotes the
        next epoch to a shared full pass (the init windows ride the same
        staged blocks — zero extra G traffic)."""
        return bool(self.pending_init)

    @property
    def all_done(self) -> bool:
        return bool(self.done.all())

    def start_epoch(self, epoch: int) -> None:
        self._epoch = epoch
        self._epoch_mark = self.stats.bytes_h2d
        self._hit_mark = self.stats.bytes_hit
        self._miss_mark = self.stats.bytes_miss

    def finish_epoch(self, epoch: int) -> None:
        self.epochs_run = epoch + 1
        self.stats.epoch_bytes.append(self.stats.bytes_h2d - self._epoch_mark)
        self.stats.epoch_hit_bytes.append(self.stats.bytes_hit
                                          - self._hit_mark)
        self.stats.epoch_miss_bytes.append(self.stats.bytes_miss
                                           - self._miss_mark)

    def autotune(self, cap: int) -> None:
        """Close the overlap loop from the FIRST full pass's measured rates:
        deepen the in-flight queue when transfer lagged compute.  The byte
        model still binds: the tuned depth may not push the in-flight device
        working set past `device_budget_bytes` (a deeper queue only helps
        when there is memory to hold it), so `cap` is tightened to the
        largest depth that fits before `tune_prefetch` runs."""
        free = (self.cfg.device_budget_bytes
                - stage2_resident_bytes(self.rank, self.T))
        per_block = stage2_block_bytes(self.tile, self.rank, self.T)
        fit = free // per_block if per_block > 0 else cap
        cap = max(self.pipe.prefetch, min(cap, int(fit)))
        if (self.cache is not None and self._act_keys is not None
                and self.cache.planned_fraction(self._act_keys,
                                                self._act_sizes) > 0.5):
            # The epochs this tune governs are majority cache-hit: most
            # blocks never cross the bus, so a deeper H2D queue buys nothing
            # and only holds extra HBM — keep the depth where it is.
            cap = self.pipe.prefetch
        put = self.stats.put_seconds - self._put_mark
        drain = self.stats.drain_seconds - self._drain_mark
        self.pipe.prefetch = tune_prefetch(put, drain, self.pipe.prefetch,
                                           cap)

    # ---------------------------------------------------------- shared passes
    def begin_pass(self, kind: str) -> None:
        """``kind``: "init" (warm-start w accumulation), "full" (violation-
        collecting epoch), "cheap" (uncompacted non-full epoch), or "compact"
        (engine-local compacted epoch).  Pending ladder tasks ride any
        FULL-COVERAGE pass (init/full/cheap — never compact) as pure
        `_accum_w` windows and join the sweep from the next epoch."""
        self._kind = kind
        self._init_live = list(self.pending_init) if kind != "compact" else []
        if kind == "init":
            self._live = []
        else:
            self._live = [t for t in range(self.T)
                          if self.active[t] and not self.done[t]]
        self._viol = {t: [] for t in self._live}
        self._put_mark = self.stats.put_seconds
        self._drain_mark = self.stats.drain_seconds

    def _h2d(self, a):
        """The engine's H2D put with the transient-retry policy: under
        `fail_fast` (default) this is exactly `_put` plus the fault-injection
        probe; with retries enabled, transient failures back off
        exponentially and re-issue the put — `_put` never partially applies
        (`jax.device_put` either returns an array or raises), so a retry is
        bit-identical to a first-try success."""
        attempt = 0
        while True:
            try:
                _fault_check("h2d", device=self.name, epoch=self._epoch)
                out = _put(a, self.device)
            except Exception as exc:
                if (attempt >= self._retries
                        or classify_error(exc) != "transient"):
                    raise
                self.trace.instant("fault", "h2d_retry", device=self.name,
                                   attempt=attempt,
                                   error=type(exc).__name__)
                delay = self._backoff * (2.0 ** attempt)
                if delay > 0:
                    time.sleep(delay)
                attempt += 1
                continue
            if attempt:
                self.trace.instant("recovery", "h2d_retry_ok",
                                   device=self.name, attempts=attempt)
            return out

    def _put_block(self, gb_send, cache_key: Optional[bytes] = None):
        t0 = self.trace.begin()
        if isinstance(gb_send, QuantBlock):
            # int8 wire: ship values + compact scale table, dequantise fused
            # on device — a quarter of the f32 bytes crossed the bus.
            vals = self._h2d(gb_send.values)
            scales = self._h2d(gb_send.scales)
            self.stats.put_seconds += self.trace.end(
                "h2d", "put_block", t0, bytes=int(gb_send.nbytes))
            self.stats.bytes_put += gb_send.nbytes
            if cache_key is not None:
                # Pin the WIRE arrays (int8 codes + scale table, a quarter
                # of the f32 residency); dequant stays fused per use.
                self._cache_store(cache_key, (vals, scales, gb_send.group),
                                  gb_send.nbytes)
            return dequant_rows(vals, scales, gb_send.group)
        gb = self._h2d(gb_send)
        self.stats.put_seconds += self.trace.end(
            "h2d", "put_block", t0, bytes=int(gb_send.nbytes))
        self.stats.bytes_put += gb_send.nbytes
        if cache_key is not None:
            # Pin the device array exactly as put (bf16 stays bf16 — the
            # upcast is re-run per use, same as the streamed path), so a
            # cached block decodes bit-identically to a shipped one.
            self._cache_store(cache_key, gb, gb_send.nbytes)
        return _upcast32(gb) if self._bf16 else gb

    def _cache_store(self, key: bytes, payload, nbytes: int) -> None:
        if self.cache is not None and self.cache.put(key, payload, nbytes):
            self.stats.cache_resident_bytes = self.cache.peak_resident_bytes

    def _decode_cached(self, payload):
        """Re-run the per-use decode step on a pinned payload — the SAME ops
        the miss path applies after its H2D put, so hit and miss blocks are
        bit-identical inputs to the epoch kernel."""
        if isinstance(payload, tuple):
            vals, scales, group = payload
            return dequant_rows(vals, scales, group)
        return _upcast32(payload) if self._bf16 else payload

    def _put_vec(self, vec, fill, dtype, length):
        t0 = self.trace.begin()
        b = self._h2d(_padded(np.asarray(vec), fill, dtype, length))
        self.stats.put_seconds += self.trace.end(
            "h2d", "put_vec", t0, bytes=int(b.nbytes))
        self.stats.bytes_h2d += b.nbytes
        self.stats.bytes_put += b.nbytes
        return b

    def feed_block(self, sel, cnt, gb_send) -> None:
        """Process one shared-pass block handed over by the driver's reader.
        The G bytes were staged (and accounted) once by the reader; only this
        engine's task-vector traffic is counted here."""
        gb = self._put_block(gb_send)
        b = sel.start // self.tile
        if self._init_live:
            # Pending ladder tasks: accumulate w0 from the task's window of
            # this block — w0 += (alpha * y) @ G[window] — while live tasks
            # sweep the same staged bytes below.
            for t in self._init_live:
                lo, hi = int(self.bounds[t][b]), int(self.bounds[t][b + 1])
                if lo == hi:
                    continue
                m = hi - lo
                wl = _win_pad(m)
                rl = (self.ids[t][lo:hi] - sel.start).astype(np.int32)
                rlb = self._put_vec(rl, 0, np.int32, wl)
                ab = self._put_vec(self.a_r[t][lo:hi], 0.0, np.float32, wl)
                yb = self._put_vec(self.y_r[t][lo:hi], 1.0, np.float32, wl)
                self.w[t] = _accum_w(self.w[t], _gather_rows(gb, rlb), ab, yb)
                self.stats.kernel_calls += 1
        if self._kind == "init" or not self._live:
            return
        qb = _row_sq(gb)
        base = sel.start
        items = []
        for t in self._live:
            lo, hi = int(self.bounds[t][b]), int(self.bounds[t][b + 1])
            if lo == hi:
                continue
            rl = (self.ids[t][lo:hi] - base).astype(np.int32)
            items.append(self._sweep_window(gb, qb, t, slice(lo, hi), rl,
                                            full=(self._kind == "full")))
        self.pipe.push(items)

    def _sweep_window(self, gb, qb, t, take, rl, *, full: bool):
        """Run the epoch kernel over ONE task's window of a staged block:
        gather the task's rows (and their q) on device, sweep only them.
        ``take`` addresses the window in the task-LOCAL arrays (a contiguous
        slice on full passes, an active-position gather on compacted
        epochs); ``rl`` holds the block-local row ids.  Windows are padded
        to a pow2 bucket (`_win_pad`) with inert c = 0 rows so kernels
        compile per bucket, not per ragged size."""
        m = len(rl)
        wl = _win_pad(m)
        rlb = self._put_vec(rl, 0, np.int32, wl)
        gw, qw = _window(gb, qb, rlb)
        ab = self._put_vec(self.a_r[t][take], 0.0, np.float32, wl)
        yb = self._put_vec(self.y_r[t][take], 1.0, np.float32, wl)
        cb = self._put_vec(self.c_r[t][take], 0.0, np.float32, wl)
        ub = self._put_vec(self.u_r[t][take], 0, np.int32, wl)
        t0 = self.trace.begin()
        a2, u2, w2, viol = self.epoch_fn(
            gw, yb, cb, qw, ab, ub, self.w[t],
            full_pass=full, shrink_k=self.shrink_k)
        self.w[t] = w2
        self.trace.end("kernel", "sweep_window", t0, rows=m, task=t)
        self.stats.kernel_calls += 1
        self.stats.coord_visits += m
        if full:
            self._viol[t].append(viol)
        return (t, take, m, a2, u2)

    def end_pass(self) -> None:
        self.pipe.flush()
        newly = self._init_live
        self._init_live = []
        if self._kind == "full":
            self.stats.full_passes += 1
            for t in self._live:
                # Empty generators (a task with no real rows, or none inside
                # this shard's blocks) converge trivially — exactly what the
                # old inert-padded sweep reported for them.
                v = max((float(np.asarray(r)) for r in self._viol[t]),
                        default=0.0)
                self.violation[t] = v
                if v < self.config.tol:
                    self.done[t] = True
                    self.epochs_used[t] = (self._epoch + 1
                                           - self.first_sweep[t])
                    s = int(self.chain_next[t])
                    if (s >= 0 and not self.active[s] and not self.done[s]
                            and s not in self.pending_init):
                        # Seed the ladder successor: the converged cell's
                        # alphas clipped into the next C box — the same
                        # warm chain serial `grid_search` builds, but the
                        # retired cell's farm slot frees immediately.
                        self.a_r[s][:] = np.clip(self.a_r[t], 0.0,
                                                 self.c_r[s])
                        self.u_r[s][:] = 0
                        if self.a_r[s].size and self.a_r[s].any():
                            self.pending_init.append(s)
                        else:
                            self.active[s] = True
                            self.first_sweep[s] = self._epoch + 1
        # Promote tasks whose w0 finished accumulating THIS pass: they sweep
        # from the next epoch and their local epoch count starts there.
        for t in newly:
            self.pending_init.remove(t)
            self.active[t] = True
            self.first_sweep[t] = self._epoch + 1
        if self._kind != "full":
            return
        self._recompact()

    def _recompact(self, record: bool = True) -> None:
        """Rebuild the compacted cheap-epoch state from the current
        unchanged-counters — a pure function of post-full-pass solver state,
        which is why checkpoints snapshot only that state and re-run this at
        restore (``record=False``: skip the stats/history appends the
        boundary's carry already contains).

        Cheap epochs then stream only rows active for at least one
        unconverged task — shrinking cuts H2D bytes, not just FLOPs."""
        t0 = self.trace.begin()
        self.act, self.act_G, self.act_q = None, None, None
        self._cw = {}
        self._act_keys = self._act_sizes = None
        live2 = [t for t in range(self.T)
                 if self.active[t] and not self.done[t]]
        if self.config.shrink and live2:
            act_take = {t: np.where(self.u_r[t] < self.shrink_k)[0]
                        for t in live2}
            union = np.unique(np.concatenate(
                [self.ids[t][act_take[t]] for t in live2]))
            if record:
                self.stats.active_history.append(int(len(union)))
            if len(union) < self.n:
                self.act = union
                # Gather (and, for bf16/int8 wire blocks, re-encode) ONCE
                # per compaction — the cheap epochs between full passes then
                # slice pass-through views (bf16/f32) or reuse the per-block
                # quantised shadow (int8) instead of re-encoding per epoch.
                # G itself stays f32: a persistent reduced-precision shadow
                # of the whole factor would cost +25-50% of the dominant
                # host allocation.
                act_G = self.G[union]
                if self._wire == "int8":
                    self.act_q = self._encode_compacted(union, act_G)
                else:
                    self.act_G = (act_G.astype(BLOCK_DTYPES["bf16"])
                                  if self._bf16 else act_G)
                n_blocks = math.ceil(max(len(union), 1) / self.tile)
                tile = self.tile
                # Per-task compacted windows: each live task's ACTIVE rows
                # mapped to their sorted union positions, with a
                # searchsorted boundary table over those positions —
                # restricting a task to its compaction-time active rows is
                # trajectory-identical to sweeping them as kernel no-ops
                # (an inactive row cannot reactivate between full passes).
                for t in live2:
                    ap = act_take[t]
                    pos = np.searchsorted(union, self.ids[t][ap])
                    self._cw[t] = (ap, pos,
                                   block_windows(pos, tile, n_blocks))
                if self.cache is not None:
                    # Re-plan the HBM pin set for the new union: keys are
                    # content-addressed by global row ids, so blocks whose
                    # row set survived the re-compaction keep their pinned
                    # device arrays (immediate hits); the rest are evicted
                    # here and re-pinned lazily by the first cheap epoch's
                    # misses.  Ranking is violation recency — hottest
                    # (most recently violating) blocks pin first when the
                    # union exceeds the cache budget.
                    self._act_keys = [
                        block_key(union[b * tile:(b + 1) * tile], self._wire)
                        for b in range(n_blocks)]
                    if self.act_q is not None:
                        self._act_sizes = [q.nbytes for q in self.act_q]
                    else:
                        blk_nb = (tile * self.rank
                                  * self._stage.buf.dtype.itemsize)
                        self._act_sizes = [blk_nb] * n_blocks
                    self.cache.plan(
                        self._act_keys, self._act_sizes,
                        violation_recency_scores_tasks(
                            union, tile,
                            [self.u_r[t][act_take[t]] for t in live2],
                            [self.ids[t][act_take[t]] for t in live2]))
                    self.stats.cache_evictions = self.cache.evictions
                    if record:
                        self.trace.instant(
                            "cache", "plan", blocks=n_blocks,
                            evictions=self.cache.evictions,
                            resident_bytes=self.cache.resident_bytes)
        if self.cache is not None and self._act_keys is None:
            # No compaction to serve (union == n, all tasks converged, or
            # shrinking off): nothing the cache could hit — drop the pins.
            self.cache.invalidate()
            self.stats.cache_evictions = self.cache.evictions
            if record:
                self.trace.instant("cache", "invalidate",
                                   evictions=self.cache.evictions)
        self.trace.end(
            "compact", "recompact", t0,
            union=int(len(self.act)) if self.act is not None else self.n,
            tasks=len(live2))

    # ----------------------------------------------------- compacted epochs
    def _encode_compacted(self, union: np.ndarray,
                          act_G: np.ndarray) -> List[QuantBlock]:
        """Quantised shadow of the compacted active rows, encoded ONCE per
        compaction and reused by every cheap epoch until the next.

        Each row keeps the (scale, zero) of its GLOBAL row group — the
        same entry its shared-pass block used (`wire_group` aligns group and
        block boundaries) — so the decoded value of a row is identical (to
        FMA rounding) between full passes and compacted cheap epochs.  The
        solver then
        optimises ONE consistent perturbed problem; re-grouping the gathered
        rows instead would re-quantise them against different stats and the
        full-pass KKT check could stall above tolerance forever.  The wire
        pays per-ROW scale entries (group=1) only on these gathered blocks.
        """
        gscales = self._scale_cache.get("gscales")
        if gscales is None:
            # A shard-backed G computes the table shard-by-shard on disk
            # (same values: shard boundaries are group-aligned); a host
            # ndarray takes the direct reduction.
            gs_fn = getattr(self.G, "group_scales", None)
            gscales = (gs_fn(self._group) if callable(gs_fn)
                       else group_scales(self.G, self._group))
            self._scale_cache["gscales"] = gscales
        srow = gscales[union // self._group]              # (n_act, 2)
        vals = encode_rows(act_G, srow)
        tile = self.tile
        out = []
        for b in range(math.ceil(max(len(union), 1) / tile)):
            s, e = b * tile, min((b + 1) * tile, len(union))
            qb = QuantBlock(values=vals[s:e], scales=srow[s:e], group=1)
            out.append(qb if e - s == tile else pad_quant_block(qb, tile))
        return out

    def run_cheap_epoch(self) -> None:
        """One engine-local non-full epoch over the shard's own compacted
        active-row union (the driver only calls this when `act` is set; an
        empty union makes the epoch a no-op)."""
        rows = self.act
        if rows is None or len(rows) == 0:
            return
        self.begin_pass("compact")
        tile = self.tile
        for b in range(math.ceil(len(rows) / tile)):
            s, e = b * tile, min((b + 1) * tile, len(rows))
            key = self._act_keys[b] if self._act_keys is not None else None
            ent = self.cache.lookup(key) if key is not None else None
            if ent is not None:
                # Cache hit: the block's wire arrays are already pinned in
                # HBM — decode per use, ZERO G bytes cross the bus (the
                # transfer-guard test in tests/test_block_cache.py pins
                # this down).
                self.stats.bytes_hit += ent.nbytes
                self.stats.cache_hits += 1
                self.trace.instant("cache", "hit", bytes=int(ent.nbytes),
                                   block=b)
                gb = self._decode_cached(ent.payload)
            else:
                gb_send = (self.act_q[b] if self.act_q is not None
                           else prep_block(self.act_G[s:e], tile,
                                           self.cfg.block_dtype, self._group,
                                           self._stage))
                self.stats.bytes_h2d += gb_send.nbytes
                self.stats.bytes_g += gb_send.nbytes
                self.stats.bytes_miss += gb_send.nbytes
                if isinstance(gb_send, QuantBlock):
                    self.stats.bytes_scales += gb_send.scale_bytes
                self.stats.blocks_streamed += 1
                self.stats.rows_streamed += e - s
                if self.cache is not None:
                    self.stats.cache_misses += 1
                    self.trace.instant("cache", "miss",
                                       bytes=int(gb_send.nbytes), block=b)
                gb = self._put_block(gb_send, cache_key=key)
            qb = _row_sq(gb)
            items = []
            for t in self._live:
                cw = self._cw.get(t)
                if cw is None:
                    continue
                ap, pos, bnd = cw
                lo, hi = int(bnd[b]), int(bnd[b + 1])
                if lo == hi:
                    continue
                # ``take`` gathers the task-local arrays at the window's
                # active positions; ``rl`` maps them to union-block rows.
                take = ap[lo:hi]
                rl = (pos[lo:hi] - s).astype(np.int32)
                items.append(self._sweep_window(gb, qb, t, take, rl,
                                                full=False))
            self.pipe.push(items)
        self.pipe.flush()

    # -------------------------------------------------------------- results
    def result(self):
        """Assemble this shard's `SolveResult` (host numpy, same layout as
        `solve_batch`) and its per-device stats record."""
        t0 = self.trace.begin()
        W = (np.stack([np.asarray(wt) for wt in self.w]) if self.T
             else np.zeros((0, self.rank), np.float32))
        self.stats.bytes_d2h += W.nbytes
        alpha = np.zeros_like(self.a0_loc)
        for t in range(self.T):
            alpha[t][self.scat[t]] = self.a_r[t]
        self.trace.end("scatter", "result", t0,
                       bytes=int(W.nbytes + alpha.nbytes), tasks=self.T)
        asum = (np.array([self.a_r[t].sum() for t in range(self.T)],
                         np.float32) if self.T
                else np.zeros((0,), np.float32))
        dual = asum - 0.5 * (W * W).sum(axis=1)
        n_sv = (alpha > 0.0).sum(axis=1).astype(np.int32)
        self.stats.epochs = self.epochs_run
        self.stats.prefetch_final = self.pipe.prefetch
        res = SolveResult(alpha=alpha, w=W.astype(np.float32),
                          epochs=self.epochs_used, violation=self.violation,
                          dual_obj=dual.astype(np.float32), n_sv=n_sv)
        return res, self.stats


class _InlineFanout:
    """Single-engine degenerate of the per-device worker fan-out: feed blocks
    on the calling thread (zero overhead at one device)."""

    def submit(self, engine, fn):
        fn()

    def barrier(self):
        pass

    def close(self, suppress: bool = False):
        pass


def drive_streamed_engines(engines: Sequence[_Stage2Engine], G, config:
                           SolverConfig, cfg: StreamConfig, *, tile: int,
                           fanout=None, guard=None) -> Stage2StreamStats:
    """Lockstep epoch driver over one or more engines.

    Reads each (tile, B) block of G ONCE per shared pass (warm-start init,
    full epochs, and uncompacted cheap epochs) and fans it out to every live
    engine via ``fanout`` (inline for one engine, per-device host workers for
    the overlapped farm), so per-pass G traffic is independent of device
    count.  Compacted cheap epochs run engine-locally and concurrently.
    Returns the shared-reader stats record (G-block traffic + epoch/pass
    counters); per-engine records accumulate task-vector traffic.

    ``guard`` (a `resilience.StreamGuard`) adds fault tolerance: epoch-
    boundary snapshots every `checkpoint_every` full passes, an in-memory
    degradation snapshot, and resume — the loop starts at the guard's
    ``start_epoch`` and the init pass is skipped when a restored snapshot
    already accumulated w0 (resumed ladder successors in ``pending_init``
    instead ride the next promoted full pass, exactly as the uninterrupted
    run would).
    """
    fan = fanout or _InlineFanout()
    tr = resolve_tracer(cfg.trace)
    reader = Stage2StreamStats(tile_rows=tile, block_dtype=cfg.block_dtype)
    # One reusable pad buffer for every shared pass of this solve: the
    # barrier below guarantees the previous pass's tail has been consumed.
    stage = _PadStage(tile, G.shape[1], cfg.block_dtype)

    def shared_pass(group, kind):
        g0 = reader.bytes_h2d
        for e in group:
            e.begin_pass(kind)
        for sel, cnt, gb in iter_shared_blocks(G, tile, cfg.block_dtype,
                                               wire_group(tile, cfg), stage,
                                               trace=tr):
            reader.bytes_h2d += gb.nbytes
            reader.bytes_g += gb.nbytes
            if isinstance(gb, QuantBlock):
                reader.bytes_scales += gb.scale_bytes
            reader.blocks_streamed += 1
            reader.rows_streamed += cnt
            for e in group:
                fan.submit(e, partial(e.feed_block, sel, cnt, gb))
        for e in group:
            fan.submit(e, e.end_pass)
        fan.barrier()
        return reader.bytes_h2d - g0

    ok = False
    try:
        if guard is not None:
            guard.on_start(engines, reader)
        init = [e for e in engines if e.needs_init]
        if init and (guard is None or not guard.init_done):
            # Resume skips this: a restored snapshot already holds the
            # accumulated w0 (restored `pending_init` tasks are ladder
            # successors seeded at the boundary — their w0 rides the next
            # promoted FULL pass, never a fresh init pass, so their
            # `first_sweep` anchors match the uninterrupted run).
            shared_pass(init, "init")   # init traffic counts, but no epoch
        if guard is not None and not guard.init_done:
            guard.mark_init(engines, reader)

        period = config.full_pass_period if config.shrink else 1
        tuned = not cfg.autotune_prefetch
        start = guard.start_epoch if guard is not None else 0
        for epoch in range(start, config.max_epochs):
            live = [e for e in engines if not e.all_done]
            if not live:
                break
            for e in live:
                e.start_epoch(epoch)
            if tr.enabled:
                te0 = tr.begin()
                cv0 = sum(e.stats.coord_visits for e in live)
            full = ((epoch % period == 0) or not config.shrink
                    or any(e.wants_full for e in live))
            # ^ freshly seeded C-ladder successors need a full-coverage pass
            #   for their w0 accumulation — promote rather than let them
            #   idle until the next scheduled full pass
            if full:
                reader.epoch_bytes.append(shared_pass(live, "full"))
                reader.full_passes += 1
                if not tuned:
                    tuned = True
                    for e in live:
                        e.autotune(cfg.prefetch_cap)
            else:
                # Engines WITH a compacted union stream their own gathered
                # rows; the rest (nothing shrunk yet) share one G read.
                own = [e for e in live if e.act is not None]
                shared = [e for e in live if e.act is None]
                for e in own:
                    fan.submit(e, e.run_cheap_epoch)
                if shared:
                    reader.epoch_bytes.append(shared_pass(shared, "cheap"))
                else:
                    fan.barrier()
                    reader.epoch_bytes.append(0)
            for e in live:
                e.finish_epoch(epoch)
            if guard is not None and full:
                # Snapshot AFTER finish_epoch (and after end_pass's ladder
                # seeding + re-compaction) — the boundary state restore
                # replays from; the kill probe sits after the save so a
                # killed run always has this boundary on disk.
                guard.on_boundary(engines, reader, epoch, trace=tr)
            _fault_check("epoch_boundary", epoch=epoch)
            if tr.enabled:
                _trace_epoch(tr, te0, epoch, "full" if full else "cheap",
                             live, reader, cv0)
        ok = True
    finally:
        # On the failure path close() must not raise over the propagating
        # exception — stuck workers are reported as a trace instant/warning
        # instead (see _DeviceWorkers.close).
        fan.close(suppress=not ok)
    return reader


def _trace_epoch(tr, t0: float, epoch: int, kind: str,
                 live: Sequence[_Stage2Engine], reader: Stage2StreamStats,
                 cv0: int) -> None:
    """Close the driver's per-epoch span: attrs aggregate the epoch's
    traffic/convergence counters across live engines — the `--verbose`
    progress listener and the trace-file epoch row both read from it."""
    eb = reader.epoch_bytes[-1] if reader.epoch_bytes else 0
    hit = miss = 0
    for e in live:
        eb += e.stats.epoch_bytes[-1] if e.stats.epoch_bytes else 0
        hit += e.stats.epoch_hit_bytes[-1] if e.stats.epoch_hit_bytes else 0
        miss += (e.stats.epoch_miss_bytes[-1]
                 if e.stats.epoch_miss_bytes else 0)
    rows = sum(e.stats.coord_visits for e in live) - cv0
    act = sum((len(e.act) if e.act is not None else e.n) for e in live)
    viols = np.concatenate([e.violation for e in live])
    viols = viols[np.isfinite(viols)]
    attrs = dict(epoch=epoch, kind=kind, bytes=int(eb), hit_bytes=int(hit),
                 miss_bytes=int(miss), rows=int(rows), active=int(act),
                 devices=len(live))
    if viols.size:
        attrs["viol"] = float(viols.max())
    tr.end("epoch", f"epoch_{epoch}", t0, **attrs)
    tr.counter("stage2/epoch_bytes", eb)
    tr.counter("stage2/active_rows", act)
    tr.counter("stage2/row_visits", rows)


def _elementwise_sum(lists: Sequence[Sequence[int]]) -> List[int]:
    out: List[int] = []
    for li in lists:
        for i, v in enumerate(li):
            if i < len(out):
                out[i] += v
            else:
                out.append(v)
    return out


def merge_stream_stats(reader: Stage2StreamStats,
                       per_dev: Sequence[Stage2StreamStats], *,
                       seconds: float, n_devices: int,
                       carry=None) -> Stage2StreamStats:
    """Aggregate the shared-reader record and the per-device engine records
    into the mesh-level `Stage2StreamStats`.  G blocks staged by the shared
    reader are counted ONCE in `bytes_h2d` (that is the point: per-pass
    unique G traffic does not scale with device count); task-vector traffic
    and compacted-epoch gathers sum over devices because they are
    partitioned, not replicated; `bytes_put` sums every device's physical
    DMA copies (== `bytes_h2d` at one device, G component ~D x beyond).

    ``carry`` is a `resilience` stats-carry tree of the segments BEFORE a
    resume (or device-quarantine restart): counters sum, per-epoch lists are
    prepended, so the merged record reads like one uninterrupted run.  Stats
    of a failed partial pass are rolled back to the last epoch boundary with
    the solver state — each `epoch_bytes` entry remains a COMPLETED pass's
    figure, which is what the device-count-invariance claim is asserted on."""
    out = Stage2StreamStats(tile_rows=reader.tile_rows,
                            block_dtype=reader.block_dtype,
                            n_devices=n_devices)
    out.bytes_h2d = reader.bytes_h2d
    out.bytes_g = reader.bytes_g
    out.bytes_scales = reader.bytes_scales
    out.blocks_streamed = reader.blocks_streamed
    out.rows_streamed = reader.rows_streamed
    for s in per_dev:
        out.bytes_h2d += s.bytes_h2d
        out.bytes_g += s.bytes_g
        out.bytes_scales += s.bytes_scales
        out.bytes_put += s.bytes_put
        out.bytes_d2h += s.bytes_d2h
        out.blocks_streamed += s.blocks_streamed
        out.rows_streamed += s.rows_streamed
        out.kernel_calls += s.kernel_calls
        out.coord_visits += s.coord_visits
        out.put_seconds += s.put_seconds
        out.drain_seconds += s.drain_seconds
        # Cache traffic is engine-local (compacted unions are partitioned
        # per shard), so it sums like the other partitioned traffic.
        out.bytes_hit += s.bytes_hit
        out.bytes_miss += s.bytes_miss
        out.cache_hits += s.cache_hits
        out.cache_misses += s.cache_misses
        out.cache_evictions += s.cache_evictions
        out.cache_resident_bytes += s.cache_resident_bytes
    out.epochs = max((s.epochs for s in per_dev), default=0)
    out.full_passes = max((s.full_passes for s in per_dev),
                          default=reader.full_passes)
    out.epoch_bytes = _elementwise_sum([reader.epoch_bytes]
                                       + [s.epoch_bytes for s in per_dev])
    out.epoch_hit_bytes = _elementwise_sum([s.epoch_hit_bytes
                                            for s in per_dev])
    out.epoch_miss_bytes = _elementwise_sum([s.epoch_miss_bytes
                                             for s in per_dev])
    # Shard unions can OVERLAP in rows (one class's rows are active in every
    # pair that references it, across shards), so this sum is the total rows
    # each cheap epoch streams farm-wide — an upper bound on the true union
    # that may exceed n; per-shard unions live in `per_device`.
    out.active_history = _elementwise_sum([s.active_history for s in per_dev])
    out.prefetch_final = max((s.prefetch_final for s in per_dev), default=0)
    out.seconds = seconds
    out.per_device = list(per_dev) if n_devices > 1 else None
    if carry is not None:
        from repro.core.resilience import apply_carry
        apply_carry(out, carry)
    return out


def solve_batch_streamed(
    G,
    tasks: TaskBatch,
    config: SolverConfig = SolverConfig(),
    *,
    stream_config: Optional[StreamConfig] = None,
    epoch_fn: Optional[Callable] = None,
    device=None,
    chain_next=None,
    return_stats: bool = False,
):
    """Drop-in `solve_batch` over a host-resident G (numpy buffer).

    G row-blocks of `tile` rows stream through `epoch_fn` (the SMO epoch
    kernel contract) with per-task w chained on device; alpha/unchanged live
    on host and are scattered back per block.  ``chain_next`` optionally
    declares C-ladder warm-start chains over the task axis (see the module
    docstring).  Returns a `SolveResult` whose fields are host numpy arrays
    (same shapes/layout as `solve_batch`), plus a `Stage2StreamStats` when
    ``return_stats=True``.  One-engine instantiation of the shared
    engine/driver; the overlapped multi-device farm lives in
    `core/distributed.py::solve_tasks_streamed`.
    """
    t_start = time.perf_counter()
    cfg = stream_config or StreamConfig()
    if epoch_fn is None:
        epoch_fn = default_epoch_fn()
    if not getattr(G, "is_shard_view", False):
        # A shards.GShardView stays on disk: asarray would materialise the
        # full (n, rank) factor and defeat the spill.  Its slice/gather
        # surface feeds the reader below directly.
        G = np.asarray(G, np.float32)
    n, rank = G.shape
    tile = auto_tile_rows(n, rank, tasks.n_tasks, cfg)
    eng = _Stage2Engine(G, tasks, config, cfg, epoch_fn=epoch_fn,
                        device=device, tile=tile, chain_next=chain_next)
    guard = None
    if cfg.checkpoint_dir:
        from repro.core.resilience import (StreamGuard, g_fingerprint,
                                           restore_engines)
        sizes = np.array([len(eng.ids[t]) for t in range(eng.T)], np.int64)
        guard = StreamGuard(cfg, n=n, rank=rank, sizes=sizes,
                            g_fp=g_fingerprint(G))
        if cfg.resume:
            snap = guard.try_resume()
            if snap is not None:
                guard.adopt(snap)
                restore_engines([eng], snap)
    reader = drive_streamed_engines([eng], G, config, cfg, tile=tile,
                                    guard=guard)
    res, est = eng.result()
    if not return_stats:
        return res
    stats = merge_stream_stats(reader, [est],
                               seconds=time.perf_counter() - t_start,
                               n_devices=1,
                               carry=guard.carry if guard else None)
    return res, stats


def solve_streamed_auto(
    G,
    tasks: TaskBatch,
    config: SolverConfig = SolverConfig(),
    *,
    stream_config: Optional[StreamConfig] = None,
    chain_next=None,
    return_stats: bool = False,
    resume: Optional[bool] = None,
):
    """The streamed stage-2 entry point every routed caller (`LPDSVM.fit`,
    `core/cv.py`, `solve_polished`'s final level, the CLI) goes through: with
    more than one local device the multi-device task farm — overlapped
    behind the shared block reader by default, or serial per-device streams
    when `StreamConfig.overlap_devices` is off — otherwise the single-device
    block stream.  ``resume`` overrides `StreamConfig.resume`: continue from
    the latest epoch-boundary snapshot in `StreamConfig.checkpoint_dir`."""
    cfg = stream_config or StreamConfig()
    if resume is not None and resume != cfg.resume:
        cfg = dataclasses.replace(cfg, resume=bool(resume))
    devices = jax.local_devices()
    if len(devices) > 1 and tasks.n_tasks > 1:
        from repro.core.distributed import solve_tasks_streamed
        return solve_tasks_streamed(G, tasks, config, devices=devices,
                                    stream_config=cfg,
                                    overlap=cfg.overlap_devices,
                                    chain_next=chain_next,
                                    return_stats=return_stats)
    return solve_batch_streamed(G, tasks, config, stream_config=cfg,
                                chain_next=chain_next,
                                return_stats=return_stats)
