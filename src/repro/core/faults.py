"""Deterministic fault injection + the streaming error taxonomy.

The chaos suite (tests/test_resilience.py) has to prove every recovery path
of the fault-tolerant streaming stack — checkpoint/resume, transient-retry,
device quarantine, watchdog — without wall-clock randomness: a fault fires
when a *site* is reached with matching attributes (block index, device name,
epoch), never on a timer.  Production code calls `check(site, **attrs)` at
its injection points; with no plan installed that is a single module-level
``None`` test (the same zero-overhead discipline as `core/trace.py`'s NULL
tracer).

Sites wired into the pipelines:

    "reader"          shared stage-2 block reader, attrs: block
    "h2d"             engine block/vector puts, attrs: device, epoch
    "epoch_boundary"  the stage-2 driver after each epoch, attrs: epoch
    "stage1"          stage-1 chunk stream, attrs: chunk
    "stall"           worker-queue stall (waits on a plan-held Event —
                      the test releases it; no sleeps)
    "shard_write"     shard-store writer before a shard lands, attrs: shard
    "shard_read"      shard-store reader before the file read, attrs: shard
    "shard_corrupt"   same read point, attrs: shard, path — the "corrupt"
                      kind flips one payload byte of the file IN PLACE and
                      returns (no exception): the injected bit rot must be
                      caught by the checksum, not by the injector

The taxonomy below is ALSO the real one: `classify_error` is what the farm
uses to decide between bounded retry (transient), device quarantine
(persistent), and fail-fast re-raise (fatal) for genuine runtime errors.
"""
from __future__ import annotations

import dataclasses
import os
import threading
from typing import Dict, List, Optional


class FaultError(Exception):
    """Base class of injected (and injectable) streaming faults."""


class TransientH2DError(FaultError):
    """A transfer failure worth retrying (cf. spurious DMA/RPC hiccups)."""


class DeviceLostError(FaultError):
    """A device is gone for good — quarantine it, re-shard onto survivors."""


class InjectedIOError(OSError, FaultError):
    """Reader-side IO failure (disk/page-cache error while staging a block)."""


class SimulatedKill(BaseException):
    """Stands in for SIGKILL / sys.exit mid-run.  BaseException on purpose:
    recovery code that catches ``Exception`` must NOT swallow it — only the
    test harness (or a real process boundary) sees it."""


#: substrings of real runtime errors that are worth one more try
_TRANSIENT_MARKERS = ("RESOURCE_EXHAUSTED", "UNAVAILABLE", "DEADLINE_EXCEEDED",
                      "transient")
#: substrings that mean the device itself is gone
_PERSISTENT_MARKERS = ("DEVICE_LOST", "device lost", "INTERNAL: Failed to",
                       "NCCL", "DATA_LOSS")


def classify_error(exc: BaseException) -> str:
    """Map an exception to the recovery taxonomy: "transient" (bounded retry),
    "persistent" (quarantine the device, re-shard), or "fatal" (re-raise)."""
    if isinstance(exc, TransientH2DError):
        return "transient"
    if isinstance(exc, DeviceLostError):
        return "persistent"
    msg = str(exc)
    if any(m in msg for m in _TRANSIENT_MARKERS):
        return "transient"
    if any(m in msg for m in _PERSISTENT_MARKERS):
        return "persistent"
    return "fatal"


@dataclasses.dataclass
class FaultSpec:
    """One deterministic fault: fires at ``site`` when every key in ``at``
    equals the corresponding `check` attribute, up to ``times`` times.

    ``kind``: "transient" -> TransientH2DError, "persistent" ->
    DeviceLostError, "io" -> InjectedIOError, "kill" -> SimulatedKill,
    "stall" -> block on the plan's Event until `FaultPlan.release`,
    "corrupt" -> flip one byte of the file named by the ``path`` attr in
    place and return silently (simulated bit rot the checksum must catch).
    """

    site: str
    kind: str = "transient"
    at: Dict[str, object] = dataclasses.field(default_factory=dict)
    times: int = 1
    fired: int = 0

    def matches(self, site: str, attrs: Dict[str, object]) -> bool:
        if site != self.site or self.fired >= self.times:
            return False
        return all(k in attrs and attrs[k] == v for k, v in self.at.items())


class FaultPlan:
    """A set of `FaultSpec`s plus the shared stall Event.  Thread-safe:
    device workers hit `check` concurrently."""

    def __init__(self, specs: Optional[List[FaultSpec]] = None):
        self.specs: List[FaultSpec] = list(specs or [])
        self._lock = threading.Lock()
        self._stall = threading.Event()
        self.fired: List[Dict[str, object]] = []   # audit log for tests

    def add(self, site: str, kind: str = "transient", times: int = 1,
            **at) -> "FaultPlan":
        self.specs.append(FaultSpec(site=site, kind=kind, at=dict(at),
                                    times=times))
        return self

    def release(self) -> None:
        """Un-stall every "stall" fault (the deterministic replacement for a
        slow-device sleep)."""
        self._stall.set()

    def check(self, site: str, attrs: Dict[str, object]) -> None:
        hit = None
        with self._lock:
            for spec in self.specs:
                if spec.matches(site, attrs):
                    spec.fired += 1
                    self.fired.append(dict(site=site, kind=spec.kind, **attrs))
                    hit = spec
                    break
        if hit is None:
            return
        if hit.kind == "stall":
            self._stall.wait()
            return
        if hit.kind == "corrupt":
            _flip_byte(str(attrs["path"]))
            return
        where = f"{site} {attrs}"
        if hit.kind == "transient":
            raise TransientH2DError(f"injected transient fault at {where}")
        if hit.kind == "persistent":
            raise DeviceLostError(f"injected device loss at {where}")
        if hit.kind == "io":
            raise InjectedIOError(f"injected IO error at {where}")
        if hit.kind == "kill":
            raise SimulatedKill(f"injected kill at {where}")
        raise ValueError(f"unknown fault kind {hit.kind!r}")


def _flip_byte(path: str, offset: Optional[int] = None) -> None:
    """Deterministic in-place bit rot: XOR one payload byte of ``path``.

    The default offset lands mid-file (inside the payload for any real
    shard), so header parsing still succeeds and ONLY the checksum can
    notice — exactly the silent-corruption case the store must catch."""
    size = os.path.getsize(path)
    if size == 0:
        return
    pos = size // 2 if offset is None else offset % size
    with open(path, "r+b") as f:
        f.seek(pos)
        b = f.read(1)
        f.seek(pos)
        f.write(bytes([b[0] ^ 0x01]))
        f.flush()
        os.fsync(f.fileno())


_PLAN: Optional[FaultPlan] = None


def install(plan: FaultPlan) -> FaultPlan:
    """Install ``plan`` process-wide (tests only; uninstall in a finally)."""
    global _PLAN
    _PLAN = plan
    return plan


def uninstall() -> None:
    global _PLAN
    if _PLAN is not None:
        _PLAN.release()   # never leave a worker parked on a stall Event
    _PLAN = None


def active() -> Optional[FaultPlan]:
    return _PLAN


def check(site: str, **attrs) -> None:
    """Injection point: no-op (one None test) unless a plan is installed."""
    if _PLAN is None:
        return
    _PLAN.check(site, attrs)
