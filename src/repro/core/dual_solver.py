"""Stage 2 of LPD-SVM: dual coordinate ascent on the precomputed factor G.

With the approximate kernel GG^T, the dual SVM problem

    max_{alpha in [0,C]^n}  1^T alpha - 1/2 alpha^T (Y GG^T Y) alpha

is exactly a *linear* SVM whose data points are the rows of G (paper, sec. 4).
The solver below is a LIBLINEAR-style dual coordinate ascent with:

  * truncated Newton coordinate steps
        alpha_i <- clip(alpha_i + (1 - y_i <w, g_i>) / <g_i, g_i>, 0, C)
    while maintaining w = sum_i alpha_i y_i g_i in R^B (iteration cost O(B));
  * the paper's simplistic-but-robust shrinking: a variable whose value did not
    change for `shrink_k = 5` consecutive touches is deactivated, and every
    `full_pass_period = 20`-th epoch (= the eta ~ 5% compute fraction) is a full
    pass over ALL variables that re-activates any variable with a KKT violation;
  * an adaptive stopping criterion: converge when a *full* pass observes a
    maximum projected-gradient KKT violation below `tol` (LIBLINEAR-style);
  * warm starts: `alpha0` seeds the solve (used across the C grid).

Tasks are described by index vectors into the shared G so that one-vs-one /
cross-validation / grid tasks never copy G.  Padding rows carry c = 0, which
pins alpha = 0 and makes them inert.  Everything is jit- and vmap-compatible;
`solve_batch` is the building block the distributed task farm shards.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

DELTA_EPS = 0.0   # "did not change": exact in float (bound hits are exact clips)
Q_FLOOR = 1e-12   # guards division for zero rows (padding)


@dataclasses.dataclass(frozen=True)
class SolverConfig:
    tol: float = 0.1               # max KKT violation on a full pass
    max_epochs: int = 1000
    shrink_k: int = 5              # paper: k = 5 consecutive no-change touches
    full_pass_period: int = 20     # paper: eta ~ 5% -> every 20th epoch is full
    shrink: bool = True


class TaskBatch(NamedTuple):
    """A batch of binary SVM tasks over a shared factor G (leading task axis)."""

    idx: jnp.ndarray     # (T, n_pad) int32 rows of G
    y: jnp.ndarray       # (T, n_pad) float32 in {-1, +1} (padding value is free)
    c: jnp.ndarray       # (T, n_pad) float32 box bound; 0 for padding -> inert
    alpha0: jnp.ndarray  # (T, n_pad) warm start

    @property
    def n_tasks(self) -> int:
        return self.idx.shape[0]


class SolveResult(NamedTuple):
    alpha: jnp.ndarray          # (T, n_pad)
    w: jnp.ndarray              # (T, B) primal weight in the low-rank space
    epochs: jnp.ndarray         # (T,) epochs consumed
    violation: jnp.ndarray      # (T,) max KKT violation at the last full pass
    dual_obj: jnp.ndarray       # (T,)
    n_sv: jnp.ndarray           # (T,) support-vector count


def _projected_gradient(g, alpha, c):
    """KKT violation of coordinate i: projected dual gradient for box [0, c]."""
    at_lo = alpha <= 0.0
    at_hi = alpha >= c
    pg = jnp.where(at_lo, jnp.maximum(g, 0.0), jnp.where(at_hi, jnp.minimum(g, 0.0), g))
    return jnp.where(c > 0.0, pg, 0.0)   # padding never violates


def epoch_ref(G, idx, y, c, q, alpha, w, unchanged, shrink_k, full_pass):
    """One sequential coordinate-ascent epoch (pure-jnp oracle for the Pallas
    SMO kernel; also the path used inside jit/vmap).

    Returns (alpha, w, unchanged, max_violation_seen).
    """
    n_pad = idx.shape[0]

    def body(i, state):
        alpha, w, unchanged, viol = state
        row = G[idx[i]]
        a_i, c_i, y_i, q_i = alpha[i], c[i], y[i], q[i]
        active = jnp.logical_and(
            c_i > 0.0, jnp.logical_or(full_pass, unchanged[i] < shrink_k))
        g = 1.0 - y_i * jnp.dot(w, row)
        pg = _projected_gradient(g, a_i, c_i)
        a_new = jnp.clip(a_i + g / jnp.maximum(q_i, Q_FLOOR), 0.0, c_i)
        a_new = jnp.where(active, a_new, a_i)
        delta = a_new - a_i
        w = w + (delta * y_i) * row
        alpha = alpha.at[i].set(a_new)
        changed = jnp.abs(delta) > DELTA_EPS
        # A full pass touches every variable, so a shrunk-but-violating variable
        # changes there and is re-activated (unchanged -> 0): the paper's
        # "dedicate a fraction of compute to re-checking removed variables".
        u_new = jnp.where(changed, 0, unchanged[i] + 1)
        u_new = jnp.where(active, u_new, unchanged[i])
        unchanged = unchanged.at[i].set(u_new)
        viol = jnp.where(active, jnp.maximum(viol, jnp.abs(pg)), viol)
        return alpha, w, unchanged, viol

    return jax.lax.fori_loop(0, n_pad, body, (alpha, w, unchanged, jnp.float32(0.0)))


def _init_w(G, idx, y, alpha0):
    rows = G[idx]                                   # (n_pad, B)
    return (alpha0 * y) @ rows


@partial(jax.jit, static_argnames=("config",))
def solve_one(G, idx, y, c, alpha0, config: SolverConfig) -> SolveResult:
    """Solve a single binary task to convergence (while_loop over epochs)."""
    n_pad = idx.shape[0]
    rows_q = jnp.sum(G[idx] ** 2, axis=-1)          # q_ii = <g_i, g_i>
    w0 = _init_w(G, idx, y, alpha0)
    unchanged0 = jnp.zeros((n_pad,), dtype=jnp.int32)
    period = config.full_pass_period if config.shrink else 1
    shrink_k = config.shrink_k if config.shrink else jnp.iinfo(jnp.int32).max

    def cond(state):
        _, _, epoch, done = state
        return jnp.logical_and(~done, epoch < config.max_epochs)

    def body(state):
        (alpha, w, unchanged), viol_last, epoch, _ = state
        full_pass = (epoch % period) == 0
        alpha, w, unchanged, viol = epoch_ref(
            G, idx, y, c, rows_q, alpha, w, unchanged, shrink_k, full_pass)
        done = jnp.logical_and(full_pass, viol < config.tol)
        viol_rec = jnp.where(full_pass, viol, viol_last)
        return ((alpha, w, unchanged), viol_rec, epoch + 1, done)

    init = ((alpha0, w0, unchanged0), jnp.float32(jnp.inf), jnp.int32(0),
            jnp.bool_(False))
    (alpha, w, _), viol, epochs, _ = jax.lax.while_loop(cond, body, init)
    dual = jnp.sum(alpha) - 0.5 * jnp.dot(w, w)
    n_sv = jnp.sum(alpha > 0.0)
    return SolveResult(alpha, w, epochs, viol, dual, n_sv)


@partial(jax.jit, static_argnames=("config",))
def solve_batch(G, tasks: TaskBatch, config: SolverConfig) -> SolveResult:
    """vmap of `solve_one` over the task axis (shared G)."""
    fn = lambda idx, y, c, a0: solve_one(G, idx, y, c, a0, config)
    return jax.vmap(fn)(tasks.idx, tasks.y, tasks.c, tasks.alpha0)


# ----------------------------------------------------------------------------
# objective helpers (tests / benchmarks)
# ----------------------------------------------------------------------------

def dual_objective(G, idx, y, alpha):
    w = _init_w(G, idx, y, alpha)
    return jnp.sum(alpha) - 0.5 * jnp.dot(w, w)


def primal_objective(G, idx, y, c, w):
    """P(w) = lambda/2 ||w||^2 + 1/n sum hinge, with lambda = 1/(C n).

    Uses the *box* c to identify real examples (c > 0) and the common C
    (assumed constant across real examples of the task).
    """
    real = c > 0.0
    n = jnp.sum(real)
    C = jnp.max(c)
    lam = 1.0 / (C * n)
    margins = y * (G[idx] @ w)
    hinge = jnp.where(real, jnp.maximum(0.0, 1.0 - margins), 0.0)
    # rescale to the dual's units: dual D corresponds to primal C * sum hinge
    return 0.5 * jnp.dot(w, w) + C * jnp.sum(hinge), lam, n


def duality_gap(G, idx, y, c, alpha):
    w = _init_w(G, idx, y, alpha)
    p, _, _ = primal_objective(G, idx, y, c, w)
    d = jnp.sum(alpha) - 0.5 * jnp.dot(w, w)
    return p - d
