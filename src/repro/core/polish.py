"""Polishing: coarse-to-fine warm-started stage-2 training (the paper title's
first ingredient).

The paper trains an approximate predictor cheaply and then *polishes* it:
rather than cold-starting the full-data solve at the final tolerance, a
ladder of nested row-subsample problems (e.g. n/16 -> n/4 -> n) is solved
with per-level tolerance annealing, each level warm-starting the next.  The
expensive full-data pass then starts near the optimum and is a short polish
instead of a full optimization — the same reuse pattern `core/cv.py`
exploits for C grids (paper Table 3), applied along the data axis (cf.
Tyree et al., arXiv:1404.1066, where coarse-then-refine dominates cold
parallel solves).

Mechanics per level:

  * **restriction** — each task keeps a nested, class-stratified random
    prefix of its real (c > 0) rows; the union of kept rows over the task
    batch is gathered into a compact level factor `G[union]`, so coarse
    levels stay monolithic on device even when the full G is a host-resident
    streamed buffer;
  * **solve** — the routed solver: `solve_batch` (or an injected
    `solve_fn`) for levels that fit the device budget, `solve_batch_streamed`
    when they do not; the FINAL level goes through the exact same
    `route_stage2` predicate as an unpolished fit, so a streamed fit still
    streams where it matters;
  * **prolongation** — the level's solved alphas are scattered back into the
    task's full index space (clipped to the box); rows not yet seen keep
    their incoming warm start (so C-grid warm starts compose: coarse levels
    start from the previous C's solution too).  The next level's w is
    recomputed from the prolonged alpha by the solver (`w0 = (a0*y) @ G`),
    which is exactly the dual-feasible prolongation.

The ladder also overrides the solver's full-pass verification cadence
(`PolishSchedule.full_pass_period`, default 1): warm-started levels converge
in a handful of passes, and the cold solver's 20-epoch shrinking cadence
would quantise every level to >= 21 epochs.  `benchmarks/polish.py` records
a period-1 cold baseline alongside, so the cadence effect is never silently
attributed to the warm starts.

When it pays: problems where a subsample's solution transfers — the
near-separable, few-SV regime of good (deep) features, the paper's ImageNet
setting.  Fine-structure problems (sharp-gamma checkerboards) transfer
coarse solutions poorly and break even.  Either way correctness is
unchanged: the final level enforces the same KKT tolerance as a cold solve,
so the polished solution is duality-gap-matched (tests/test_polish.py).

Everything is bookkeeping over the existing solvers — the subsystem adds a
control layer, not new numerics.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, List, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.dual_solver import (SolveResult, SolverConfig, TaskBatch,
                                    solve_batch)
from repro.core.solver_stream import (Stage2StreamStats, route_stage2,
                                      should_stream_stage2,
                                      solve_batch_streamed,
                                      solve_streamed_auto)
from repro.core.streaming import StreamConfig
from repro.core.trace import resolve as resolve_tracer


@dataclasses.dataclass(frozen=True)
class PolishSchedule:
    """The coarse-to-fine ladder: ascending row fractions (last one must be
    1.0 — the full-data polish pass) with per-level tolerance annealing
    (`tol * tol_factor`, final factor 1.0 = `SolverConfig.tol`)."""

    fractions: Tuple[float, ...] = (1 / 16, 1 / 4, 1.0)
    tol_factors: Tuple[float, ...] = (16.0, 4.0, 1.0)
    min_rows: int = 64     # per-task floor: coarse levels never degenerate
    seed: int = 0          # row-priority RNG (nested prefixes)
    scale_C: bool = False  # True scales the coarse box by n/m (constant
                           # lambda = 1/(C n)); False keeps the paper's
                           # unnormalised C * sum(hinge) objective per level
    full_pass_period: Optional[int] = 1
                           # override SolverConfig.full_pass_period for
                           # MONOLITHIC level solves: every jit epoch costs
                           # the same, warm-started levels converge in a
                           # handful of passes, and the stock 20-epoch
                           # verification cadence would quantise every level
                           # to >= 21 epochs (None = keep the config's)
    stream_full_pass_period: Optional[int] = 5
                           # override for STREAMED level solves: cheap epochs
                           # are the point there (shrinking compacts H2D
                           # bytes), but the cold 20-epoch cadence still
                           # over-quantises a warm-started polish pass; 5
                           # balances verification latency against
                           # compaction (None = keep the config's)

    def __post_init__(self):
        if len(self.fractions) != len(self.tol_factors):
            raise ValueError("fractions and tol_factors must align")
        if not self.fractions or abs(self.fractions[-1] - 1.0) > 1e-9:
            raise ValueError("last level must be the full data (fraction 1.0)")
        if any(f <= 0.0 or f > 1.0 for f in self.fractions):
            raise ValueError("fractions must lie in (0, 1]")
        if any(b <= a for a, b in zip(self.fractions, self.fractions[1:])):
            raise ValueError("fractions must be strictly ascending")
        if any(f < 1.0 for f in self.tol_factors):
            raise ValueError("tol_factors anneal TOWARD tol; need >= 1")

    @property
    def n_levels(self) -> int:
        return len(self.fractions)


def make_schedule(levels: int = 3, ratio: float = 4.0, tol_growth: float = 4.0,
                  min_rows: int = 64, seed: int = 0,
                  scale_C: bool = False,
                  full_pass_period: Optional[int] = 1,
                  stream_full_pass_period: Optional[int] = 5) -> PolishSchedule:
    """Geometric ladder: fractions ratio^-(L-1) ... 1, tols tol*growth^(L-1)
    ... tol (levels=3, ratio=4 -> the paper-style n/16 -> n/4 -> n).

    The default ``full_pass_period=1`` makes every ladder epoch a full
    verification pass: warm-started levels stop the moment they are KKT-
    converged instead of waiting out the cold solver's 20-epoch cadence.
    """
    if levels < 1:
        raise ValueError("need at least one level")
    fr = tuple(float(ratio) ** -(levels - 1 - l) for l in range(levels))
    tf = tuple(float(tol_growth) ** (levels - 1 - l) for l in range(levels))
    return PolishSchedule(fractions=fr, tol_factors=tf, min_rows=min_rows,
                          seed=seed, scale_C=scale_C,
                          full_pass_period=full_pass_period,
                          stream_full_pass_period=stream_full_pass_period)


@dataclasses.dataclass
class PolishLevelStats:
    """Convergence + work accounting of one ladder level."""

    fraction: float
    tol: float
    n_rows: int                   # union of task rows gathered at this level
    n_pad: int
    streamed: bool
    epochs: np.ndarray            # (T,)
    violations: np.ndarray        # (T,)
    duality_gap: np.ndarray       # (T,) nan when gap_trace=False
    row_visits: int               # coordinate visits charged to this level
    seconds: float
    stream_stats: Optional[Stage2StreamStats] = None


@dataclasses.dataclass
class PolishTrace:
    """Per-level trajectory of one polished solve (FitStats.polish_trace)."""

    levels: List[PolishLevelStats] = dataclasses.field(default_factory=list)

    @property
    def total_row_visits(self) -> int:
        return sum(l.row_visits for l in self.levels)

    @property
    def total_seconds(self) -> float:
        return sum(l.seconds for l in self.levels)

    @property
    def final(self) -> PolishLevelStats:
        return self.levels[-1]


def task_duality_gap(rows, y, c, alpha) -> float:
    """Host-side duality gap of one task from its gathered G rows (numpy, so
    a streamed host-resident G is never device-materialised for the trace);
    mirrors `dual_solver.duality_gap`."""
    rows = np.asarray(rows, np.float32)
    y = np.asarray(y, np.float32)
    c = np.asarray(c, np.float32)
    alpha = np.asarray(alpha, np.float32)
    w = (alpha * y) @ rows
    real = c > 0.0
    C = float(c.max()) if real.any() else 1.0
    margins = y * (rows @ w)
    hinge = np.where(real, np.maximum(0.0, 1.0 - margins), 0.0)
    p = 0.5 * float(w @ w) + C * float(hinge.sum())
    d = float(alpha.sum()) - 0.5 * float(w @ w)
    return p - d


def _level_positions(idx: np.ndarray, y: np.ndarray, c: np.ndarray,
                     schedule: PolishSchedule, n_rows: int) -> List[List[np.ndarray]]:
    """Per (level, task): positions into the PADDED task layout, sorted by
    global row index.  Selection is a class-stratified random prefix under a
    fixed per-row priority, so levels are nested (coarse rows never leave)
    and idx stays sorted — the streamed solver's contract."""
    T = idx.shape[0]
    prio = np.random.default_rng(schedule.seed).random(n_rows)
    floor_p = schedule.min_rows // 2
    floor_n = schedule.min_rows - floor_p
    sel: List[List[np.ndarray]] = [[None] * T for _ in schedule.fractions]
    for t in range(T):
        real_pos = np.where(c[t] > 0.0)[0]
        rt = idx[t][real_pos]
        yt = y[t][real_pos]
        pr = prio[rt]
        pos_p = np.where(yt > 0)[0]
        pos_n = np.where(yt <= 0)[0]
        ord_p = pos_p[np.argsort(pr[pos_p], kind="stable")]
        ord_n = pos_n[np.argsort(pr[pos_n], kind="stable")]
        for li, f in enumerate(schedule.fractions):
            if f >= 1.0:
                sl = np.arange(len(real_pos))
            else:
                kp = min(len(ord_p), max(math.ceil(f * len(ord_p)), floor_p))
                kn = min(len(ord_n), max(math.ceil(f * len(ord_n)), floor_n))
                sl = np.sort(np.concatenate([ord_p[:kp], ord_n[:kn]]))
            sel[li][t] = real_pos[sl]
    return sel


def _route_level(n_rows: int, rank: int, n_tasks: int, n_pad: int,
                 stream, stream_config: Optional[StreamConfig],
                 solve_fn: Callable) -> bool:
    """Routing for a COARSE level: the gathered sub-factor is its own
    problem, so only its own working set decides — a forced `stream=True`
    streams the final level (via `route_stage2`) but must not force tiny
    gathered levels off device."""
    if solve_fn is not solve_batch or stream is False or stream_config is None:
        return False
    return should_stream_stage2(n_rows, rank, n_tasks, n_pad, stream_config)


def solve_polished(
    factor,
    tasks: TaskBatch,
    config: SolverConfig = SolverConfig(),
    schedule: Optional[PolishSchedule] = None,
    *,
    stream=None,
    stream_config: Optional[StreamConfig] = None,
    solve_fn: Callable = solve_batch,
    gap_trace: bool = True,
    return_trace: bool = False,
    trace=None,
):
    """Coarse-to-fine warm-started drop-in for the routed stage-2 solve.

    Solves the schedule's nested subsample ladder, prolongating each level's
    alpha into the next, and returns the FINAL level's `SolveResult` (same
    shapes/layout as `solve_batch(factor.G, tasks, config)`), plus a
    `PolishTrace` when ``return_trace=True``.  Incoming `tasks.alpha0` (the
    C-grid warm start) seeds every level's not-yet-solved rows.
    """
    if schedule is None:
        schedule = PolishSchedule()
    G = factor.G
    n, rank = int(G.shape[0]), int(G.shape[1])
    host_G = isinstance(G, np.ndarray)
    idx = np.asarray(tasks.idx)
    y_loc = np.asarray(tasks.y, np.float32)
    c_loc = np.asarray(tasks.c, np.float32)
    T, n_pad = idx.shape
    af = np.clip(np.asarray(tasks.alpha0, np.float32), 0.0, c_loc)

    # `trace` observes only; level ROUTING still keys off `stream_config`
    tr = resolve_tracer(trace if trace is not None
                        else getattr(stream_config, "trace", None))
    sel = _level_positions(idx, y_loc, c_loc, schedule, n)
    # Drop redundant coarse levels (min_rows flooring can make a level equal
    # its successor; nested prefixes => equal sizes means equal sets).
    keep = [li for li in range(schedule.n_levels - 1)
            if any(len(sel[li][t]) < len(sel[li + 1][t]) for t in range(T))]
    keep.append(schedule.n_levels - 1)

    trace = PolishTrace()
    res: Optional[SolveResult] = None

    def _level_config(li: int, streamed: bool) -> SolverConfig:
        period = (schedule.stream_full_pass_period if streamed
                  else schedule.full_pass_period) or config.full_pass_period
        return dataclasses.replace(
            config, tol=float(config.tol * schedule.tol_factors[li]),
            full_pass_period=period)

    for li in keep:
        frac = schedule.fractions[li]
        final = frac >= 1.0
        t0 = tr.begin()
        sstats = None
        if final:
            tasks_l = TaskBatch(idx=tasks.idx, y=tasks.y, c=tasks.c,
                                alpha0=jnp.asarray(np.clip(af, 0.0, c_loc)))
            streamed = route_stage2(factor, tasks_l, stream, stream_config,
                                    solve_fn, solve_batch)
            cfg_l = _level_config(li, streamed)
            if streamed:
                # Final level: the full-size stream — overlapped over every
                # local device when there are several (shared block reader).
                res, sstats = solve_streamed_auto(
                    G, tasks_l, cfg_l, stream_config=stream_config,
                    return_stats=True)
            else:
                res = solve_fn(jnp.asarray(G) if host_G else G, tasks_l, cfg_l)
            af = np.asarray(res.alpha)
            res_l, n_pad_l, n_rows_l = res, n_pad, n
            pos_l = sel[li]
            level_G = G          # gap rows gathered lazily below
        else:
            pos_l = sel[li]
            n_pad_l = max(8, -(-max(len(p) for p in pos_l) // 8) * 8)
            union = np.unique(np.concatenate(
                [idx[t][p] for t, p in enumerate(pos_l)]))
            n_rows_l = len(union)
            level_G = G[union]      # host gather (np G) or device gather (jnp)
            idx_l = np.zeros((T, n_pad_l), np.int32)
            y_l = np.ones((T, n_pad_l), np.float32)
            c_l = np.zeros((T, n_pad_l), np.float32)
            a_l = np.zeros((T, n_pad_l), np.float32)
            for t, p in enumerate(pos_l):
                k = len(p)
                m_full = int(np.sum(c_loc[t] > 0.0))
                scale = (m_full / max(k, 1)) if schedule.scale_C else 1.0
                idx_l[t, :k] = np.searchsorted(union, idx[t][p])
                y_l[t, :k] = y_loc[t][p]
                c_l[t, :k] = c_loc[t][p] * scale
                a_l[t, :k] = np.clip(af[t][p], 0.0, c_l[t, :k])
            tasks_l = TaskBatch(idx=jnp.asarray(idx_l), y=jnp.asarray(y_l),
                                c=jnp.asarray(c_l), alpha0=jnp.asarray(a_l))
            streamed = _route_level(n_rows_l, rank, T, n_pad_l, stream,
                                    stream_config, solve_fn)
            cfg_l = _level_config(li, streamed)
            if streamed:
                res_l, sstats = solve_batch_streamed(
                    np.asarray(level_G), tasks_l, cfg_l,
                    stream_config=stream_config, return_stats=True)
            else:
                res_l = solve_fn(jnp.asarray(level_G) if host_G else level_G,
                                 tasks_l, cfg_l)
            # prolongation: solved rows overwrite (raw, in the level's scaled
            # box — each use site clips into its own box); unseen rows keep
            # their incoming warm start
            a_res = np.asarray(res_l.alpha)
            for t, p in enumerate(pos_l):
                af[t][p] = a_res[t][: len(p)]

        visits = (sstats.coord_visits if sstats is not None
                  else int(np.asarray(res_l.epochs).sum()) * n_pad_l)
        gaps = np.full((T,), np.nan, np.float32)
        if gap_trace and final and not host_G:
            # device-resident G: compute the gap on device (scalars back)
            # instead of copying the full (n, B) factor to host
            from repro.core.dual_solver import duality_gap as _gap_dev
            for t in range(T):
                gaps[t] = float(_gap_dev(G, tasks.idx[t], tasks.y[t],
                                         tasks.c[t],
                                         jnp.asarray(res_l.alpha)[t]))
        elif gap_trace:
            # host numpy path: coarse levels use the small gathered factor;
            # a streamed final level must never device-materialise G
            G_np = level_G if isinstance(level_G, np.ndarray) \
                else np.asarray(level_G)
            a_np = np.asarray(res_l.alpha)
            for t, p in enumerate(pos_l):
                k = len(p)
                if final:
                    gaps[t] = task_duality_gap(G_np[idx[t][p]], y_loc[t][p],
                                               c_loc[t][p], a_np[t][p])
                else:
                    # the LEVEL's own problem (scaled box): that is the
                    # quantity the tolerance annealing drives toward zero
                    gaps[t] = task_duality_gap(G_np[idx_l[t, :k]], y_l[t, :k],
                                               c_l[t, :k], a_np[t][:k])
        dt = tr.end("polish", f"level_{li}", t0, fraction=float(frac),
                    tol=float(cfg_l.tol), rows=n_rows_l,
                    streamed=streamed, row_visits=visits)
        trace.levels.append(PolishLevelStats(
            fraction=frac, tol=cfg_l.tol, n_rows=n_rows_l, n_pad=n_pad_l,
            streamed=streamed, epochs=np.asarray(res_l.epochs),
            violations=np.asarray(res_l.violation), duality_gap=gaps,
            row_visits=visits, seconds=dt,
            stream_stats=sstats))

    return (res, trace) if return_trace else res
