"""Process-wide span/counter tracer for the streaming pipelines.

The paper's thesis is "fully exploit the machine"; this module is how we
*check* that claim on ourselves.  The stats dataclasses
(`Stage1StreamStats`, `Stage2StreamStats`, ...) stay the assertable source
of truth for byte/second totals — the tracer is the timeline view over the
same measurements: every hot-path `perf_counter` pair becomes a *span*
``(category, name, t_start, t_end, thread, attrs)`` whose duration still
feeds the stats field it always fed, plus instant events (cache hits,
evictions) and gauge samples (queue depth).

Design constraints, in order:

1. **Near-zero overhead when disabled.**  The module-level `NULL` tracer is
   what every call site sees by default; its `begin()`/`end()` still return
   `perf_counter` readings (so `put_seconds` etc. keep their exact
   pre-tracer meanings) but record nothing, allocate nothing, and take no
   lock.  Solver outputs with tracing disabled are bit-identical to the
   un-instrumented code.
2. **Thread safety.**  The stage-2 farm runs one worker thread per device
   behind a shared reader; recording is a single append of an immutable
   tuple under one lock, and export snapshots under the same lock.
3. **Two export views.**  ``export(path)`` writes Chrome-trace/Perfetto
   JSON (open in https://ui.perfetto.dev, one row per thread);
   ``summary()`` aggregates seconds per category, effective H2D GB/s,
   rows/s, and the *overlap efficiency* — the fraction of reader/put span
   time hidden under device compute (kernel/drain spans on other threads).

Usage::

    tr = Tracer()
    with tr.span("h2d", "put_block", bytes=nbytes): ...
    # or the stats-feeding pair form:
    t0 = tr.begin()
    ...
    stats.put_seconds += tr.end("h2d", "put_block", t0, bytes=nbytes)
    tr.export("trace.json"); print(tr.summary())

Call sites resolve their tracer via `resolve(explicit)`: an explicitly
passed tracer wins, else the process-wide one set by `install()`, else
`NULL`.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

__all__ = [
    "Tracer", "NullTracer", "NULL", "ProgressPrinter",
    "install", "uninstall", "active", "resolve",
]

# Event record layout (immutable tuple — one allocation per record):
#   (ph, category, name, t_abs, dur, tid, attrs)
# ph: "X" complete span | "i" instant | "C" counter sample
# t_abs/dur in perf_counter seconds; attrs a (possibly empty) dict.
_SPAN, _INSTANT, _COUNTER = "X", "i", "C"

_TRANSFER_CATEGORIES = ("read", "h2d")     # host-side staging / put time
_COMPUTE_CATEGORIES = ("kernel", "drain")  # device compute / result fetch


class _NullSpan:
    """Shared no-op context manager returned by `NullTracer.span`."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):
        pass


_NULL_SPAN = _NullSpan()


class NullTracer:
    """Disabled-mode tracer: the module-level no-op fast path.

    `begin`/`end` still bracket the region with `perf_counter` so durations
    returned to stats fields keep their exact meanings; nothing is recorded,
    no lock is taken, no allocation happens."""

    __slots__ = ()
    enabled = False

    def begin(self) -> float:
        return time.perf_counter()

    def end(self, category: str, name: str, t0: float, **attrs) -> float:
        return time.perf_counter() - t0

    def span(self, category: str, name: str, **attrs):
        return _NULL_SPAN

    def instant(self, category: str, name: str, **attrs) -> None:
        pass

    def counter(self, name: str, value) -> None:
        pass

    def add_listener(self, fn: Callable) -> None:
        pass


NULL = NullTracer()


class _Span:
    """Context-manager span for sites that do not feed a stats field."""

    __slots__ = ("_tracer", "category", "name", "attrs", "_t0")

    def __init__(self, tracer: "Tracer", category: str, name: str,
                 attrs: dict):
        self._tracer = tracer
        self.category = category
        self.name = name
        self.attrs = attrs

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def set(self, **attrs):
        """Attach attrs discovered mid-span (e.g. result sizes)."""
        self.attrs.update(attrs)
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter()
        self._tracer._record(_SPAN, self.category, self.name, self._t0,
                             t1 - self._t0, self.attrs)
        return False


class Tracer:
    """Thread-safe in-memory span/instant/counter recorder."""

    enabled = True

    def __init__(self):
        self._lock = threading.Lock()
        self._events: List[tuple] = []
        self._thread_names: Dict[int, str] = {}
        self._listeners: List[Callable] = []
        self.pid = os.getpid()
        self.t0 = time.perf_counter()

    # ---- recording ------------------------------------------------------
    def begin(self) -> float:
        """Start a stats-feeding span; pair with `end`."""
        return time.perf_counter()

    def end(self, category: str, name: str, t0: float, **attrs) -> float:
        """Close a `begin` span, record it, and return its duration so call
        sites can feed the existing stats field in the same expression."""
        t1 = time.perf_counter()
        self._record(_SPAN, category, name, t0, t1 - t0, attrs)
        return t1 - t0

    def span(self, category: str, name: str, **attrs) -> _Span:
        """Context-manager span for non-stats regions."""
        return _Span(self, category, name, attrs)

    def instant(self, category: str, name: str, **attrs) -> None:
        """Point event (cache hit/miss/evict, ...)."""
        self._record(_INSTANT, category, name, time.perf_counter(), 0.0,
                     attrs)

    def counter(self, name: str, value) -> None:
        """Gauge sample (queue depth, active rows, ...)."""
        self._record(_COUNTER, "counter", name, time.perf_counter(), 0.0,
                     {"value": float(value)})

    def add_listener(self, fn: Callable) -> None:
        """Subscribe ``fn(event_tuple)`` to every record (e.g. the per-epoch
        progress printer).  Listeners run on the recording thread, outside
        the lock — keep them cheap and thread-safe."""
        self._listeners.append(fn)

    def _record(self, ph: str, category: str, name: str, t_abs: float,
                dur: float, attrs: dict) -> None:
        tid = threading.get_ident()
        ev = (ph, category, name, t_abs, dur, tid, attrs)
        with self._lock:
            if tid not in self._thread_names:
                self._thread_names[tid] = threading.current_thread().name
            self._events.append(ev)
        for fn in self._listeners:
            fn(ev)

    # ---- introspection --------------------------------------------------
    @property
    def n_events(self) -> int:
        with self._lock:
            return len(self._events)

    def events(self) -> List[tuple]:
        """Snapshot of all records (immutable tuples, safe to share)."""
        with self._lock:
            return list(self._events)

    def categories(self) -> Dict[str, int]:
        """Record count per category."""
        out: Dict[str, int] = {}
        for ev in self.events():
            out[ev[1]] = out.get(ev[1], 0) + 1
        return out

    # ---- export ---------------------------------------------------------
    def export(self, path: str) -> None:
        """Write Chrome-trace/Perfetto JSON (load in ui.perfetto.dev or
        chrome://tracing).  Timestamps are µs relative to tracer creation;
        one timeline row per recording thread, named after the thread."""
        with self._lock:
            events = list(self._events)
            names = dict(self._thread_names)
        out = []
        for tid, tname in sorted(names.items()):
            out.append({"ph": "M", "name": "thread_name", "pid": self.pid,
                        "tid": tid, "args": {"name": tname}})
        for ph, cat, name, t_abs, dur, tid, attrs in events:
            ts = (t_abs - self.t0) * 1e6
            ev = {"ph": ph, "cat": cat, "name": name, "ts": ts,
                  "pid": self.pid, "tid": tid}
            if ph == _SPAN:
                ev["dur"] = dur * 1e6
                if attrs:
                    ev["args"] = attrs
            elif ph == _INSTANT:
                ev["s"] = "t"
                if attrs:
                    ev["args"] = attrs
            else:  # counter
                ev["args"] = attrs
            out.append(ev)
        payload = {"traceEvents": out, "displayTimeUnit": "ms",
                   "otherData": {"tool": "repro.core.trace"}}
        with open(path, "w") as f:
            json.dump(payload, f, default=_json_default)

    # ---- aggregation ----------------------------------------------------
    def summary(self) -> str:
        """Aggregated text view: seconds/records per category, effective
        H2D GB/s, rows/s, and timeline overlap efficiency."""
        events = self.events()
        spans = [e for e in events if e[0] == _SPAN]
        if not events:
            return "trace: no events recorded"
        by_cat: Dict[str, List[tuple]] = {}
        for e in spans:
            by_cat.setdefault(e[1], []).append(e)
        t_lo = min(e[3] for e in events)
        t_hi = max(e[3] + e[4] for e in events)
        wall = max(t_hi - t_lo, 1e-12)

        lines = [f"trace summary ({len(events)} events, "
                 f"{len(self._thread_names)} threads, wall {wall:.3f}s)"]
        for cat in sorted(by_cat):
            evs = by_cat[cat]
            secs = sum(e[4] for e in evs)
            nbytes = sum(e[6].get("bytes", 0) for e in evs)
            line = f"  {cat:<8s} {len(evs):6d} spans  {secs:9.3f}s"
            if nbytes:
                line += (f"  {nbytes / 1e9:8.3f} GB"
                         f"  {nbytes / max(secs, 1e-12) / 1e9:7.2f} GB/s")
            lines.append(line)

        h2d = by_cat.get("h2d", [])
        h2d_secs = sum(e[4] for e in h2d)
        h2d_bytes = sum(e[6].get("bytes", 0) for e in h2d)
        if h2d_bytes:
            lines.append(f"  effective H2D: "
                         f"{h2d_bytes / max(h2d_secs, 1e-12) / 1e9:.2f} GB/s "
                         f"({h2d_bytes / 1e9:.3f} GB in {h2d_secs:.3f}s)")
        rows = sum(e[6].get("rows", 0) for e in by_cat.get("kernel", []))
        if rows:
            lines.append(f"  rows/s: {rows / wall:,.0f} "
                         f"({rows:,} row visits in {wall:.3f}s wall)")
        ov = self.overlap_efficiency()
        if ov is not None:
            lines.append(f"  overlap efficiency: {ov:.2f} "
                         f"(fraction of read/h2d time hidden under "
                         f"compute on other threads)")
        for cat, label in (("cache", "cache events"),
                           ("fault", "fault events"),
                           ("recovery", "recovery events")):
            inst = {}
            for e in events:
                if e[0] == _INSTANT and e[1] == cat:
                    inst[e[2]] = inst.get(e[2], 0) + 1
            if inst:
                lines.append(f"  {label}: " + ", ".join(
                    f"{k}={v}" for k, v in sorted(inst.items())))
        return "\n".join(lines)

    def overlap_efficiency(self) -> Optional[float]:
        """Fraction of transfer (read/h2d) span time that overlaps compute
        (kernel/drain) spans *on other threads* — the timeline analogue of
        the stats-level `overlap_efficiency` properties.  None when there
        are no transfer spans; 0.0 in single-thread (inline) runs, where
        nothing can be hidden."""
        spans = [e for e in self.events() if e[0] == _SPAN]
        xfer = [e for e in spans if e[1] in _TRANSFER_CATEGORIES]
        comp = [(e[3], e[3] + e[4], e[5]) for e in spans
                if e[1] in _COMPUTE_CATEGORIES]
        if not xfer:
            return None
        total = sum(e[4] for e in xfer)
        if total <= 0.0:
            return 0.0
        hidden = 0.0
        merged_cache: Dict[int, List[Tuple[float, float]]] = {}
        for ph, cat, name, t_abs, dur, tid, attrs in xfer:
            if tid not in merged_cache:
                merged_cache[tid] = _merge_intervals(
                    [(a, b) for a, b, ctid in comp if ctid != tid])
            hidden += _overlap_with(t_abs, t_abs + dur, merged_cache[tid])
        return min(1.0, hidden / total)


def _merge_intervals(iv: Sequence[Tuple[float, float]]
                     ) -> List[Tuple[float, float]]:
    """Union of half-open intervals, sorted and non-overlapping."""
    out: List[Tuple[float, float]] = []
    for a, b in sorted(iv):
        if out and a <= out[-1][1]:
            if b > out[-1][1]:
                out[-1] = (out[-1][0], b)
        else:
            out.append((a, b))
    return out


def _overlap_with(a: float, b: float,
                  merged: Sequence[Tuple[float, float]]) -> float:
    """Length of [a, b) covered by a merged interval list."""
    cov = 0.0
    for lo, hi in merged:
        if hi <= a:
            continue
        if lo >= b:
            break
        cov += min(b, hi) - max(a, lo)
    return cov


def _json_default(o):
    """numpy scalars and other non-JSON attrs degrade gracefully."""
    try:
        return float(o)
    except (TypeError, ValueError):
        return str(o)


class ProgressPrinter:
    """Event listener printing one line per stage-2 epoch (`--verbose`).

    Subscribes to the driver's per-epoch ``epoch`` spans, whose attrs carry
    the aggregated counters (active rows, bytes moved, cache hit rate, row
    visits, max KKT violation); everything on the line comes from the same
    event stream the trace file records."""

    def __init__(self, stream=None):
        import sys
        self._out = stream if stream is not None else sys.stderr

    def __call__(self, ev) -> None:
        ph, cat, name, t_abs, dur, tid, attrs = ev
        if ph != _SPAN or cat != "epoch":
            return
        a = attrs
        hit = a.get("hit_bytes", 0)
        miss = a.get("miss_bytes", 0)
        rate = hit / (hit + miss) if hit + miss else 0.0
        rows = a.get("rows", 0)
        viol = a.get("viol")
        viol_s = f"{viol:9.3e}" if viol is not None else "      n/a"
        print(f"epoch {a.get('epoch', '?'):>4} [{a.get('kind', '?'):<5s}] "
              f"active={a.get('active', 0):>8,} "
              f"bytes={a.get('bytes', 0) / 1e6:9.2f}MB "
              f"hit={rate:5.1%} "
              f"rows/s={rows / max(dur, 1e-12):12,.0f} "
              f"viol={viol_s} "
              f"({dur:.3f}s)", file=self._out, flush=True)


# ---- process-wide tracer ------------------------------------------------
_active: Optional[Tracer] = None


def install(tracer: Optional[Tracer]) -> None:
    """Set the process-wide tracer picked up by `resolve` everywhere."""
    global _active
    _active = tracer


def uninstall() -> None:
    """Clear the process-wide tracer (back to the no-op fast path)."""
    install(None)


def active() -> Optional[Tracer]:
    """The installed process-wide tracer, or None."""
    return _active


def resolve(tracer=None):
    """Tracer for a call site: explicit argument > installed global > NULL."""
    if tracer is not None:
        return tracer
    return _active if _active is not None else NULL
