"""Stage 1 of LPD-SVM: complete precomputation of the low-rank factor G.

Paper, sec. 4:
  * sample B landmark points (a random subset of the training set — Nyström);
  * eigendecompose the B x B landmark kernel matrix K_mm (NOT Cholesky — kernel
    matrices are routinely only *semi*-definite and Cholesky "regularly runs
    into numerical problems");
  * drop eigenvalues below a threshold close to machine precision times the
    largest eigenvalue — those subspaces carry mostly numerical noise, and
    dropping them adaptively reduces the effective dimension B' <= B;
  * fully precompute G = K_nm @ V @ diag(lambda^-1/2)  of shape (n, B') so that
    G @ G.T ~= K.  The whitening (the lambda^-1/2) comes "nearly for free".

Everything here is jit-compatible except the adaptive rank choice, which is a
*data-dependent shape*: we keep the full B columns and zero out dropped
directions, plus report the effective rank.  A `compact=True` path (host-side)
physically slices the factor for the production two-stage flow.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kernel_fn import KernelParams, gram

# float32 machine epsilon is ~1.19e-7; the paper drops eigenvalues "as soon as
# the eigenvalues fall below a threshold close to the machine precision times
# the largest eigenvalue".
DEFAULT_EIG_RTOL = 1e-6


@dataclasses.dataclass
class LowRankFactor:
    """The fully precomputed stage-1 artifact, shared across folds/grid/pairs."""

    G: jnp.ndarray                # (n, B') feature rows; GG^T ~= K
    landmarks: jnp.ndarray        # (B, p) landmark points
    projector: jnp.ndarray        # (B, B') V * lambda^{-1/2} : maps K_xm -> features
    eigvals: jnp.ndarray          # (B,) spectrum of K_mm (descending)
    effective_rank: int           # B' after eigenvalue dropping
    kernel: KernelParams
    streamed: bool = False        # True -> G is a host-resident numpy buffer
                                  # produced by the out-of-core chunked path
    stage1_stats: Optional[object] = None
                                  # streaming.Stage1StreamStats of the build
                                  # (chunk wire bytes / dtype / autotune)

    @property
    def n(self) -> int:
        return self.G.shape[0]

    @property
    def rank(self) -> int:
        return self.G.shape[1]

    def features(self, x: jnp.ndarray) -> jnp.ndarray:
        """Map new points into the low-rank feature space (prediction path)."""
        k_xm = gram(x, self.landmarks, self.kernel)
        return k_xm @ self.projector


def wait_for_factor(G) -> None:
    """Block until a factor's G is ready: device arrays wait on the async
    dispatch queue, a streamed (host numpy) G is ready by construction."""
    if hasattr(G, "block_until_ready"):
        G.block_until_ready()


def select_landmarks(x: jnp.ndarray, budget: int, key: jax.Array) -> jnp.ndarray:
    """Uniform random landmark (Nyström) sample; the paper's choice.

    "we settle on a fixed (yet data dependent) feature space representation
    based on a random sample" — equivalent to projection-based budget
    maintenance with all projections precomputed.
    """
    n = x.shape[0]
    if budget >= n:
        return x
    idx = jax.random.choice(key, n, shape=(budget,), replace=False)
    return jnp.take(x, idx, axis=0)


@partial(jax.jit, static_argnames=("params",))
def _eig_projector(k_mm: jnp.ndarray, params: KernelParams, rtol: float):
    """eigh of K_mm -> (projector with dropped dirs zeroed, eigvals desc, rank)."""
    # Symmetrize: batch kernel evaluation is deterministic but accumulate order
    # can differ between the two triangles on real hardware.
    k_mm = 0.5 * (k_mm + k_mm.T)
    evals, evecs = jnp.linalg.eigh(k_mm)           # ascending
    evals = evals[::-1]
    evecs = evecs[:, ::-1]
    lam_max = jnp.maximum(evals[0], 0.0)
    keep = evals > rtol * lam_max                  # adaptive rank
    inv_sqrt = jnp.where(keep, 1.0 / jnp.sqrt(jnp.where(keep, evals, 1.0)), 0.0)
    projector = evecs * inv_sqrt[None, :]          # (B, B), dropped cols zeroed
    return projector, evals, jnp.sum(keep)


def compute_factor(
    x: jnp.ndarray,
    params: KernelParams,
    budget: int,
    *,
    key: Optional[jax.Array] = None,
    eig_rtol: float = DEFAULT_EIG_RTOL,
    compact: bool = True,
    block_rows: int = 65536,
    gram_fn=gram,
    stream: Optional[bool] = None,
    stream_config=None,
) -> LowRankFactor:
    """Run stage 1: landmarks -> K_mm -> eigh (+drop) -> G = K_nm @ projector.

    ``gram_fn`` is injectable so the Pallas TPU gram kernel (kernels/ops.py)
    can replace the pure-jnp reference; both satisfy gram(x, z, params).
    ``block_rows`` streams K_nm row-blocks so the (n, B) intermediate never
    coexists with a second (n, B) temporary — the paper's "streaming fashion"
    requirement for G bigger than GPU memory.

    Out-of-core routing: ``stream=True`` forces the chunked host-resident
    pipeline (`core/streaming.py`); ``stream=None`` with a ``stream_config``
    auto-routes when the monolithic working set exceeds the config's device
    budget; ``stream=False`` (or no config) keeps the device-resident path.
    """
    from repro.core import streaming as _streaming

    if key is None:
        key = jax.random.PRNGKey(0)

    if not hasattr(x, "shape"):
        x = np.asarray(x, np.float32)
    n, p = x.shape
    if stream is None and stream_config is not None:
        stream = _streaming.should_stream(n, p, min(budget, n), stream_config)
    if stream:
        cfg = stream_config or _streaming.StreamConfig()
        return _streaming.compute_factor_streamed(
            x, params, budget, key=key, eig_rtol=eig_rtol, config=cfg,
            gram_fn=gram_fn)

    x = jnp.asarray(x, dtype=jnp.float32)
    n = x.shape[0]
    landmarks = select_landmarks(x, budget, key)
    k_mm = gram_fn(landmarks, landmarks, params)
    projector, evals, rank = _eig_projector(k_mm, params, eig_rtol)
    rank = int(rank)

    if compact:
        projector = projector[:, :rank]

    blocks = []
    for start in range(0, n, block_rows):
        xb = x[start:start + block_rows]
        blocks.append(gram_fn(xb, landmarks, params) @ projector)
    G = jnp.concatenate(blocks, axis=0) if len(blocks) > 1 else blocks[0]

    return LowRankFactor(
        G=G, landmarks=landmarks, projector=projector, eigvals=evals,
        effective_rank=rank, kernel=params,
    )


def approximation_error(factor: LowRankFactor, x: jnp.ndarray,
                        params: KernelParams, probe: int = 256,
                        key: Optional[jax.Array] = None) -> float:
    """Relative Frobenius error of GG^T vs K on a random probe block (test aid)."""
    if key is None:
        key = jax.random.PRNGKey(1)
    n = x.shape[0]
    idx = np.asarray(jax.random.choice(key, n, shape=(min(probe, n),), replace=False))
    k_true = gram(x[idx], x[idx], params)
    g = factor.G[idx]
    k_hat = g @ g.T
    return float(jnp.linalg.norm(k_true - k_hat) / jnp.linalg.norm(k_true))
