"""Public LPD-SVM estimator: the paper's two-stage algorithm behind one API.

    svm = LPDSVM(kernel=KernelParams("rbf", gamma=2**-7), C=2**5, budget=1000)
    svm.fit(x, y)           # stage 1 (factor G) + stage 2 (dual CA, OVO)
    svm.predict(x_test)

Stage 1 can be reused across fits (cross-validation, C grids, OVO pairs) by
passing a precomputed `LowRankFactor` — see `core/cv.py` which exploits
exactly the reuse pattern the paper measures in Table 3.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dual_solver import SolveResult, SolverConfig, TaskBatch, solve_batch
from repro.core.kernel_fn import KernelParams, gram
from repro.core.nystrom import LowRankFactor, compute_factor, wait_for_factor
from repro.core.ovo import build_ovo_tasks, ovo_decision_values, ovo_vote
from repro.core.polish import (PolishSchedule, PolishTrace, make_schedule,
                               solve_polished)
from repro.core.solver_stream import (Stage2StreamStats, route_stage2,
                                      solve_streamed_auto)
from repro.core.streaming import StreamConfig
from repro.core.trace import resolve as resolve_tracer


@dataclasses.dataclass
class FitStats:
    """Timings of the stages (paper figure 3 breakdown)."""

    stage1_seconds: float = 0.0     # preparation + computation of G
    stage2_seconds: float = 0.0     # linear SVM training (SMO)
    n_tasks: int = 0
    epochs: Optional[np.ndarray] = None
    violations: Optional[np.ndarray] = None
    effective_rank: int = 0
    stage1_streamed: bool = False   # True -> G came from the out-of-core path
    stage1_stats: Optional[object] = None  # streaming.Stage1StreamStats
                                           # (chunk wire bytes / dtype)
    stage2_streamed: bool = False   # True -> solver streamed G row-blocks
    stage2_stats: Optional[Stage2StreamStats] = None
    polished: bool = False          # True -> stage 2 ran the polish ladder
    polish_trace: Optional[PolishTrace] = None  # per-level epochs/violations/
                                                # duality-gap trajectory


class LPDSVM:
    def __init__(
        self,
        kernel: KernelParams = KernelParams("rbf", gamma=1.0),
        C: float = 1.0,
        budget: int = 1000,
        tol: float = 1e-2,
        max_epochs: int = 1000,
        shrink: bool = True,
        seed: int = 0,
        gram_fn: Callable = gram,
        solve_fn: Callable = solve_batch,
        stream: Optional[bool] = None,
        stream_config: Optional[StreamConfig] = None,
        polish: bool = False,
        polish_levels: int = 3,
        polish_schedule: Optional[PolishSchedule] = None,
        polish_gap_trace: bool = True,
    ):
        self.kernel = kernel
        self.C = float(C)
        self.budget = int(budget)
        self.config = SolverConfig(tol=tol, max_epochs=max_epochs, shrink=shrink)
        self.seed = seed
        self.gram_fn = gram_fn
        self.solve_fn = solve_fn
        # Out-of-core training: `stream` forces it, `stream_config`'s device
        # budget auto-routes it (see core/streaming.py + core/solver_stream.py
        # — both stages stream, so fitting scales past HBM end to end); both
        # None -> always the monolithic device-resident paths.
        self.stream = stream
        self.stream_config = stream_config
        # Polishing (core/polish.py): coarse-to-fine warm-started stage 2.
        # `polish=True` builds the default geometric ladder (`polish_levels`
        # deep); an explicit `polish_schedule` wins.
        self.polish_schedule = (
            polish_schedule if polish_schedule is not None
            else make_schedule(levels=polish_levels) if polish else None)
        # Per-level duality gaps in the trace cost extra host/device work at
        # scale (one G sweep per task per level) — disablable for hot fits.
        self.polish_gap_trace = polish_gap_trace
        # fitted state
        self.factor: Optional[LowRankFactor] = None
        self.classes_: Optional[np.ndarray] = None
        self.pairs_ = None
        self.W_: Optional[jnp.ndarray] = None      # (T, B) per-pair weights
        self.alpha_: Optional[jnp.ndarray] = None  # (T, n_pad)
        self.tasks_: Optional[TaskBatch] = None
        self.stats = FitStats()

    # ------------------------------------------------------------------ stage 1
    def prepare(self, x: np.ndarray, trace=None) -> LowRankFactor:
        """Compute (or return the cached) low-rank factor G for `x`."""
        if self.factor is None:
            tr = resolve_tracer(
                trace if trace is not None
                else getattr(self.stream_config, "trace", None))
            t0 = tr.begin()
            if self.stream or self.stream_config is not None:
                # Host numpy in, so the streamed path never materialises the
                # full x on device; the monolithic path converts internally.
                x = np.asarray(x, np.float32)
            self.factor = compute_factor(
                x, self.kernel, self.budget,
                key=jax.random.PRNGKey(self.seed), gram_fn=self.gram_fn,
                stream=self.stream, stream_config=self.stream_config)
            wait_for_factor(self.factor.G)
            self.stats.stage1_seconds = tr.end(
                "fit", "stage1", t0, rows=int(np.asarray(x).shape[0]),
                budget=self.budget)
            self.stats.effective_rank = self.factor.effective_rank
            self.stats.stage1_streamed = self.factor.streamed
            self.stats.stage1_stats = getattr(self.factor, "stage1_stats",
                                              None)
        return self.factor

    # ------------------------------------------------------------------ stage 2
    def fit(self, x: np.ndarray, y: np.ndarray,
            factor: Optional[LowRankFactor] = None,
            warm_alpha: Optional[np.ndarray] = None,
            trace=None,
            checkpoint_dir: Optional[str] = None,
            checkpoint_every: Optional[int] = None,
            resume: Optional[bool] = None) -> "LPDSVM":
        """Two-stage fit.  ``trace`` optionally records the run's pipeline
        timeline (a `core.trace.Tracer`): it is threaded into the streamed
        paths via `StreamConfig.trace`, wins over an installed process-wide
        tracer, and with ``trace=None`` the no-op fast path keeps outputs
        bit-identical to an un-instrumented fit.

        ``checkpoint_dir`` / ``checkpoint_every`` / ``resume`` thread
        fault-tolerance into the streamed paths (core/resilience.py): stage 1
        resumes completed G row-chunks from ``<dir>/stage1_G.npy`` and stage 2
        snapshots full solver state every ``checkpoint_every`` full passes,
        resumable bit-exactly after a kill.  Setting any of them forces the
        streamed route (checkpoints only exist there); they are folded into
        ``stream_config`` exactly like ``trace``."""
        if (checkpoint_dir is not None or checkpoint_every is not None
                or resume is not None):
            upd = {}
            if checkpoint_dir is not None:
                upd["checkpoint_dir"] = checkpoint_dir
            if checkpoint_every is not None:
                upd["checkpoint_every"] = int(checkpoint_every)
            if resume is not None:
                upd["resume"] = bool(resume)
            self.stream_config = dataclasses.replace(
                self.stream_config or StreamConfig(), **upd)
            if self.stream is None and self.stream_config.checkpoint_dir:
                self.stream = True   # checkpoints only exist on that path
        if trace is not None and self.stream_config is not None \
                and self.stream_config.trace is None:
            self.stream_config = dataclasses.replace(self.stream_config,
                                                     trace=trace)
        tr = resolve_tracer(
            trace if trace is not None
            else getattr(self.stream_config, "trace", None))
        y = np.asarray(y)
        self.classes_, labels = np.unique(y, return_inverse=True)
        n_classes = len(self.classes_)
        if n_classes < 2:
            raise ValueError("need at least two classes")
        if factor is not None:
            self.factor = factor
            self.stats.effective_rank = factor.effective_rank
            self.stats.stage1_streamed = factor.streamed
            self.stats.stage1_stats = getattr(factor, "stage1_stats", None)
        self.prepare(x, trace=trace)

        warm = None
        if warm_alpha is not None:
            warm = [np.asarray(a) for a in warm_alpha]
        tasks, self.pairs_ = build_ovo_tasks(labels, n_classes, self.C, alpha0=warm)
        self.tasks_ = tasks
        t0 = tr.begin()
        res: SolveResult = self._solve_stage2(tasks, trace=trace)
        wait_for_factor(res.w)
        self.stats.stage2_seconds = tr.end("fit", "stage2", t0,
                                           tasks=tasks.n_tasks)
        self.stats.n_tasks = tasks.n_tasks
        self.stats.epochs = np.asarray(res.epochs)
        self.stats.violations = np.asarray(res.violation)
        self.W_ = res.w
        self.alpha_ = res.alpha
        return self

    def _solve_stage2(self, tasks: TaskBatch, trace=None) -> SolveResult:
        """Stage-2 dispatch (see `solver_stream.route_stage2`): the polish
        ladder when enabled, the streamed row-block solver when G must stay
        host-resident (overlapped over every local device when there are
        several — `solve_streamed_auto`), else the jit'd `solve_batch`."""
        G = self.factor.G
        # Routing always uses self.stream_config (a trace must never change
        # which solver runs); a fit(trace=...) with no explicit StreamConfig
        # still reaches the streamed paths via a default config carrying it.
        cfg = self.stream_config
        if trace is not None and cfg is None:
            cfg = StreamConfig(trace=trace)
        self.stats.stage2_streamed = False      # refits must not report the
        self.stats.stage2_stats = None          # previous fit's stream stats
        self.stats.polished = False
        self.stats.polish_trace = None
        if self.polish_schedule is not None:
            res, ptrace = solve_polished(
                self.factor, tasks, self.config, self.polish_schedule,
                stream=self.stream, stream_config=self.stream_config,
                solve_fn=self.solve_fn, gap_trace=self.polish_gap_trace,
                return_trace=True, trace=trace)
            self.stats.polished = True
            self.stats.polish_trace = ptrace
            self.stats.stage2_streamed = ptrace.final.streamed
            self.stats.stage2_stats = ptrace.final.stream_stats
            return res
        if not route_stage2(self.factor, tasks, self.stream,
                            self.stream_config, self.solve_fn, solve_batch):
            return self.solve_fn(G, tasks, self.config)
        res, stats = solve_streamed_auto(
            G, tasks, self.config, stream_config=cfg, return_stats=True)
        self.stats.stage2_streamed = True
        self.stats.stage2_stats = stats
        return res

    # --------------------------------------------------------------- prediction
    def decision_function(self, x: np.ndarray) -> np.ndarray:
        if self.W_ is None:
            raise RuntimeError("fit first")
        feats = self.factor.features(jnp.asarray(x, jnp.float32))
        return np.asarray(ovo_decision_values(feats, self.W_))

    def predict(self, x: np.ndarray) -> np.ndarray:
        d = self.decision_function(x)
        return self._vote(d)

    def _vote(self, d: np.ndarray) -> np.ndarray:
        if len(self.classes_) == 2:
            pred = np.where(d[:, 0] > 0, 0, 1)
        else:
            pred = ovo_vote(d, self.pairs_, len(self.classes_))
        return self.classes_[pred]

    def predict_from_factor(self, rows: Optional[np.ndarray] = None) -> np.ndarray:
        """Predict TRAINING rows straight from the fitted factor's G — no
        kernel evaluations and no dense x required (the `--libsvm` CLI path
        scores this way so the dense (n, p) matrix is never materialised)."""
        if self.W_ is None:
            raise RuntimeError("fit first")
        G = self.factor.G
        if G.shape[0] == 0:
            raise RuntimeError(
                "G is not persisted in checkpoints (it is recomputable from "
                "the landmarks); refit or use predict(x) on a loaded model")
        g = G if rows is None else G[np.asarray(rows)]
        return self._vote(np.asarray(g @ np.asarray(self.W_).T))

    def score(self, x: np.ndarray, y: np.ndarray) -> float:
        return float(np.mean(self.predict(x) == np.asarray(y)))

    def error(self, x: np.ndarray, y: np.ndarray) -> float:
        return 1.0 - self.score(x, y)

    # -------------------------------------------------------------- persistence
    def save(self, directory: str, step: int = 0) -> str:
        """Persist the fitted model (landmarks + projector + per-pair weights).

        Only stage-1 artifacts and the solution are stored — G itself is a
        training-time object and is NOT persisted (it is n x B; the paper's
        point is that it can always be recomputed from the landmarks).
        ``step`` versions successive saves; `load` picks the latest.
        """
        if self.W_ is None:
            raise RuntimeError("fit first")
        from repro.checkpoint import save_checkpoint
        tree = {
            "landmarks": self.factor.landmarks,
            "projector": self.factor.projector,
            "eigvals": self.factor.eigvals,
            "W": self.W_,
            "classes": jnp.asarray(self.classes_),
            "meta": {
                "gamma": jnp.float32(self.kernel.gamma),
                "coef0": jnp.float32(self.kernel.coef0),
                "degree": jnp.int32(self.kernel.degree),
                "C": jnp.float32(self.C),
                "kind": jnp.int32(("rbf", "linear", "poly", "tanh")
                                  .index(self.kernel.kind)),
            },
        }
        return save_checkpoint(directory, step, tree)

    @classmethod
    def load(cls, directory: str, step: Optional[int] = None) -> "LPDSVM":
        import msgpack  # noqa: F401  (checkpoint backend)
        import os
        from repro.checkpoint import latest_step
        # Discover the newest checkpoint unless a step is pinned; shapes are
        # read straight from the payload (no template needed).
        if step is None:
            step = latest_step(directory)
            if step is None:
                raise FileNotFoundError(f"no step_*.msgpack under {directory}")
        path = os.path.join(directory, f"step_{step:08d}.msgpack")
        with open(path, "rb") as f:
            payload = msgpack.unpackb(f.read(), raw=False)

        def arr(key):
            rec = payload[key]
            return jnp.asarray(np.frombuffer(rec["data"],
                                             dtype=np.dtype(rec["dtype"]))
                               .reshape(rec["shape"]))

        kinds = ("rbf", "linear", "poly", "tanh")
        kernel = KernelParams(
            kind=kinds[int(arr("meta/kind"))],
            gamma=float(arr("meta/gamma")),
            coef0=float(arr("meta/coef0")),
            degree=int(arr("meta/degree")),
        )
        svm = cls(kernel=kernel, C=float(arr("meta/C")))
        landmarks = arr("landmarks")
        projector = arr("projector")
        from repro.core.nystrom import LowRankFactor
        svm.factor = LowRankFactor(
            G=jnp.zeros((0, projector.shape[1]), jnp.float32),
            landmarks=landmarks, projector=projector,
            eigvals=arr("eigvals"),
            effective_rank=projector.shape[1], kernel=kernel)
        svm.W_ = arr("W")
        svm.classes_ = np.asarray(arr("classes"))
        from repro.core.ovo import class_pairs
        svm.pairs_ = class_pairs(len(svm.classes_))
        return svm
