"""Checkpoint/resume + graceful degradation for the streamed solvers.

Three pieces, all keyed by GLOBAL task index so the saved state is
device-count independent (per-task trajectories are numerically independent
of tile size and device placement — PR 7's task-LOCAL coordinates):

* `snapshot_engines` / `restore_engines` — serialise the full stage-2 solver
  state of one or more `_Stage2Engine`s at a FULL-PASS epoch boundary
  (alpha/unchanged/w per task, ladder lifecycle flags, convergence counters,
  merged stream-stats carry) into a flat tree for `repro.checkpoint`'s
  msgpack format, and restore it onto freshly built engines — possibly split
  over a DIFFERENT device count.  Restores re-run the engine's shrinking
  re-compaction (a pure function of the restored unchanged-counters), so a
  resumed run replays the uninterrupted trajectory bit-for-bit.

* `StreamGuard` — the driver-side policy object: writes a disk checkpoint
  every `checkpoint_every` full passes, keeps the last epoch-boundary
  snapshot in memory when graceful degradation is on (`fail_fast=False`), and
  carries the already-accounted stream stats across resume segments so the
  merged record matches an uninterrupted run.

* `Stage1Progress` — resumable stage-1 factor streaming: G fills an on-disk
  memmap and every drained chunk appends its row range to an append-only log
  (data flushed before the log line, so logged ranges are durable); a
  restarted stage 1 skips the covered chunks.

Snapshots happen ONLY at full-pass boundaries: the engine's compaction state
is a pure function of post-full-pass state, so it is recomputed at restore
instead of serialised, and a failure mid-cheap-epoch rolls back to the last
full pass and replays deterministically.
"""
from __future__ import annotations

import dataclasses
import os
import re
import time
from typing import Dict, List, Optional, Sequence

import msgpack
import numpy as np

from repro.checkpoint.ckpt import latest_step, save_checkpoint
from repro.core.faults import classify_error  # noqa: F401  (re-export: the
#   real recovery taxonomy lives with the injectable faults)


class WatchdogTimeout(RuntimeError):
    """The farm barrier starved past `StreamConfig.watchdog_seconds` — raised
    with queue/thread diagnostics instead of hanging forever."""


class WorkerStuckError(RuntimeError):
    """`_DeviceWorkers.close()` found a worker thread still alive after its
    join timeout (previously a silent leak)."""


# ---------------------------------------------------------------------------
# stream-stats carry: the already-accounted counters of previous segments
# ---------------------------------------------------------------------------

_CARRY_SUM = ("bytes_h2d", "bytes_d2h", "bytes_g", "bytes_scales",
              "bytes_put", "bytes_hit", "bytes_miss", "blocks_streamed",
              "rows_streamed", "kernel_calls", "coord_visits", "cache_hits",
              "cache_misses", "cache_evictions", "cache_resident_bytes",
              "full_passes")
_CARRY_SUM_F = ("put_seconds", "drain_seconds", "seconds")
_CARRY_MAX = ("epochs", "prefetch_final")
_CARRY_LIST = ("epoch_bytes", "epoch_hit_bytes", "epoch_miss_bytes",
               "active_history")


def stats_to_carry(stats) -> Dict[str, np.ndarray]:
    """Flatten the carry-relevant fields of a `Stage2StreamStats`."""
    out: Dict[str, np.ndarray] = {}
    for f in _CARRY_SUM + _CARRY_MAX:
        out[f] = np.asarray(getattr(stats, f), np.int64)
    for f in _CARRY_SUM_F:
        out[f] = np.asarray(getattr(stats, f), np.float64)
    for f in _CARRY_LIST:
        out[f] = np.asarray(getattr(stats, f), np.int64)
    return out


def add_carry(carry: Dict[str, np.ndarray],
              base: Optional[Dict[str, np.ndarray]]) -> Dict[str, np.ndarray]:
    """Fold an EARLIER segment's carry (``base``) under ``carry``: counters
    sum, high-water marks max, per-epoch lists concatenate (base first)."""
    if base is None:
        return carry
    out = dict(carry)
    for f in _CARRY_SUM:
        out[f] = np.asarray(int(carry[f]) + int(base[f]), np.int64)
    for f in _CARRY_SUM_F:
        out[f] = np.asarray(float(carry[f]) + float(base[f]), np.float64)
    for f in _CARRY_MAX:
        out[f] = np.asarray(max(int(carry[f]), int(base[f])), np.int64)
    for f in _CARRY_LIST:
        out[f] = np.concatenate([np.asarray(base[f], np.int64),
                                 np.asarray(carry[f], np.int64)])
    return out


def apply_carry(stats, carry: Optional[Dict[str, np.ndarray]]):
    """Fold a carry tree into a freshly merged `Stage2StreamStats` (the
    resumed segment): the result reads like one uninterrupted run."""
    if carry is None:
        return stats
    for f in _CARRY_SUM:
        setattr(stats, f, getattr(stats, f) + int(carry[f]))
    for f in _CARRY_SUM_F:
        setattr(stats, f, getattr(stats, f) + float(carry[f]))
    for f in _CARRY_MAX:
        setattr(stats, f, max(getattr(stats, f), int(carry[f])))
    for f in _CARRY_LIST:
        setattr(stats, f, [int(v) for v in carry[f]] + getattr(stats, f))
    return stats


# ---------------------------------------------------------------------------
# stage-2 snapshot / restore (global-task-keyed)
# ---------------------------------------------------------------------------

def g_fingerprint(G) -> float:
    """Cheap content stamp of the factor (guards resuming onto the wrong G,
    e.g. another gamma's checkpoint directory).

    A shard-backed G (`shards.GShardView`) publishes its own fingerprint,
    derived from the store manifest's per-shard digests — so snapshots
    record the shard-manifest identity and ``resume`` refuses to continue
    against a store that was re-ingested or otherwise mutated, without
    reading a single row back from disk."""
    fp = getattr(G, "g_fingerprint", None)
    if fp is not None:
        return float(fp)
    n = G.shape[0]
    if n == 0:
        return 0.0
    return float(np.float64(G[0].sum()) + np.float64(G[-1].sum())
                 + np.float64(n) * G.shape[1])


def snapshot_engines(engines: Sequence, sizes: np.ndarray, *,
                     epoch_next: int, init_done: bool,
                     carry: Dict[str, np.ndarray], n: int, rank: int,
                     g_fp: float) -> Dict:
    """Serialise the engines' solver state into a global-task-keyed tree.

    ``sizes[g]`` is global task g's real-row count; per-task alpha/unchanged
    are concatenated in global task order.  w is fetched D2H here — it is
    device-resident incremental float state, so bit-parity REQUIRES saving it
    rather than recomputing it from alpha.
    """
    sizes = np.asarray(sizes, np.int64)
    T = len(sizes)
    off = np.concatenate([np.zeros(1, np.int64), np.cumsum(sizes)])
    a_cat = np.zeros(int(off[-1]), np.float32)
    u_cat = np.zeros(int(off[-1]), np.int32)
    w = np.zeros((T, rank), np.float32)
    done = np.zeros(T, np.uint8)
    violation = np.zeros(T, np.float32)
    epochs_used = np.zeros(T, np.int32)
    first_sweep = np.zeros(T, np.int32)
    active = np.zeros(T, np.uint8)
    pending = np.zeros(T, np.uint8)
    epochs_run = 0
    for e in engines:
        pend = set(e.pending_init)
        for t in range(e.T):
            g = int(e.task_ids[t])
            s0, s1 = int(off[g]), int(off[g + 1])
            if s1 - s0 != len(e.a_r[t]):
                raise ValueError(f"task {g}: snapshot size {s1 - s0} != "
                                 f"engine rows {len(e.a_r[t])}")
            a_cat[s0:s1] = e.a_r[t]
            u_cat[s0:s1] = e.u_r[t]
            w[g] = np.asarray(e.w[t])
            done[g] = e.done[t]
            violation[g] = e.violation[t]
            epochs_used[g] = e.epochs_used[t]
            first_sweep[g] = e.first_sweep[t]
            active[g] = e.active[t]
            pending[g] = t in pend
        epochs_run = max(epochs_run, e.epochs_run)
    return {
        "meta": {
            "epoch_next": np.asarray(epoch_next, np.int64),
            "init_done": np.asarray(int(init_done), np.int64),
            "epochs_run": np.asarray(epochs_run, np.int64),
            "n": np.asarray(n, np.int64),
            "rank": np.asarray(rank, np.int64),
            "T": np.asarray(T, np.int64),
            "g_fp": np.asarray(g_fp, np.float64),
        },
        "sizes": sizes,
        "a": a_cat, "u": u_cat, "w": w,
        "done": done, "violation": violation, "epochs_used": epochs_used,
        "first_sweep": first_sweep, "active": active, "pending": pending,
        "stats": carry,
    }


def restore_engines(engines: Sequence, snap: Dict) -> None:
    """Restore a snapshot onto freshly built engines (any device split that
    partitions the same global task set).  Re-runs each engine's shrinking
    re-compaction (`_recompact(record=False)`) so the compacted cheap-epoch
    state matches what the uninterrupted run had after the boundary's full
    pass — without double-appending its stats/history records."""
    from repro.core.solver_stream import _put

    sizes = np.asarray(snap["sizes"], np.int64)
    off = np.concatenate([np.zeros(1, np.int64), np.cumsum(sizes)])
    epochs_run = int(snap["meta"]["epochs_run"])
    for e in engines:
        pending: List[int] = []
        for t in range(e.T):
            g = int(e.task_ids[t])
            s0, s1 = int(off[g]), int(off[g + 1])
            if s1 - s0 != len(e.a_r[t]):
                raise ValueError(f"task {g}: checkpoint rows {s1 - s0} != "
                                 f"engine rows {len(e.a_r[t])}")
            e.a_r[t][:] = snap["a"][s0:s1]
            e.u_r[t][:] = snap["u"][s0:s1]
            e.w[t] = _put(np.ascontiguousarray(snap["w"][g], np.float32),
                          e.device)
            e.done[t] = bool(snap["done"][g])
            e.violation[t] = snap["violation"][g]
            e.epochs_used[t] = snap["epochs_used"][g]
            e.first_sweep[t] = snap["first_sweep"][g]
            e.active[t] = bool(snap["active"][g])
            if snap["pending"][g]:
                pending.append(t)
        e.pending_init = pending
        e.epochs_run = epochs_run
        e._epoch = epochs_run - 1
        e._recompact(record=False)


def validate_snapshot(snap: Dict, *, n: int, rank: int, sizes,
                      g_fp: float) -> None:
    meta = snap["meta"]
    if int(meta["n"]) != n or int(meta["rank"]) != rank:
        raise ValueError(
            f"checkpoint shape mismatch: saved (n={int(meta['n'])}, "
            f"rank={int(meta['rank'])}), solve has (n={n}, rank={rank})")
    sizes = np.asarray(sizes, np.int64)
    if int(meta["T"]) != len(sizes) or not np.array_equal(
            np.asarray(snap["sizes"], np.int64), sizes):
        raise ValueError("checkpoint task structure does not match this solve")
    if abs(float(meta["g_fp"]) - g_fp) > 1e-6 * max(1.0, abs(g_fp)):
        raise ValueError("checkpoint factor fingerprint does not match G — "
                         "resuming against a different factor?")


def load_snapshot(directory: str, step: Optional[int] = None) -> Optional[Dict]:
    """Load a stage-2 snapshot written by `StreamGuard` (latest step when
    ``step`` is None).  Template-free: snapshot trees hold variable-length
    per-epoch lists, so shapes come from the file itself."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            return None
    path = os.path.join(directory, f"step_{step:08d}.msgpack")
    with open(path, "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    out: Dict = {}
    for key, rec in payload.items():
        arr = np.frombuffer(rec["data"], dtype=np.dtype(rec["dtype"]))
        arr = arr.reshape(rec["shape"]).copy()
        node = out
        parts = key.split("/")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = arr
    return out


# ---------------------------------------------------------------------------
# the driver-side guard
# ---------------------------------------------------------------------------

class StreamGuard:
    """Policy + state for checkpointing and degradation of ONE streamed
    stage-2 solve.  The driver calls `on_start` / `mark_init` /
    `on_boundary`; the solve entry points call `try_resume` and read
    `start_epoch` / `carry`."""

    def __init__(self, cfg, *, n: int, rank: int, sizes, g_fp: float,
                 degrade: bool = False):
        self.cfg = cfg
        self.dir = cfg.checkpoint_dir
        self.every = cfg.checkpoint_every if self.dir else 0
        self.degrade = degrade
        self.n, self.rank, self.g_fp = n, rank, g_fp
        self.sizes = np.asarray(sizes, np.int64)
        self.start_epoch = 0
        self.init_done = False
        self.carry: Optional[Dict[str, np.ndarray]] = None
        self.mem: Optional[Dict] = None    # last epoch-boundary snapshot
        self.saved_steps: List[int] = []
        self._fulls = 0
        self._t0 = time.perf_counter()

    # -- resume -------------------------------------------------------------
    def try_resume(self) -> Optional[Dict]:
        if not self.dir:
            return None
        snap = load_snapshot(self.dir)
        if snap is None:
            return None
        validate_snapshot(snap, n=self.n, rank=self.rank, sizes=self.sizes,
                          g_fp=self.g_fp)
        return snap

    def adopt(self, snap: Dict) -> None:
        """Continue from ``snap``: the next driver segment starts at its
        epoch boundary and the already-accounted stats ride `carry`."""
        self.mem = snap
        self.start_epoch = int(snap["meta"]["epoch_next"])
        self.init_done = bool(int(snap["meta"]["init_done"]))
        self.carry = snap.get("stats")
        self._t0 = time.perf_counter()

    def adopt_mem(self) -> None:
        if self.mem is None:
            raise RuntimeError("no epoch-boundary snapshot to degrade from")
        self.adopt(self.mem)

    # -- driver hooks -------------------------------------------------------
    def _snapshot(self, engines, reader, epoch_next: int) -> Dict:
        from repro.core.solver_stream import merge_stream_stats
        cur = merge_stream_stats(reader, [e.stats for e in engines],
                                 seconds=time.perf_counter() - self._t0,
                                 n_devices=len(engines))
        cur.epochs = max((e.epochs_run for e in engines), default=0)
        cur.prefetch_final = max((e.pipe.prefetch for e in engines), default=0)
        carry = add_carry(stats_to_carry(cur), self.carry)
        return snapshot_engines(engines, self.sizes, epoch_next=epoch_next,
                                init_done=self.init_done, carry=carry,
                                n=self.n, rank=self.rank, g_fp=self.g_fp)

    def on_start(self, engines, reader) -> None:
        """Before the init pass: seed the in-memory degradation snapshot so a
        failure before the first boundary can still re-shard."""
        if self.degrade and self.mem is None:
            self.mem = self._snapshot(engines, reader, self.start_epoch)

    def mark_init(self, engines, reader) -> None:
        self.init_done = True
        if self.degrade:
            self.mem = self._snapshot(engines, reader, self.start_epoch)

    def on_boundary(self, engines, reader, epoch: int, trace=None) -> None:
        """After `finish_epoch` of a FULL-pass epoch — the only state the
        snapshot format covers (compaction is recomputed at restore)."""
        self._fulls += 1
        snap = None
        if self.every and self._fulls % self.every == 0:
            snap = self._snapshot(engines, reader, epoch + 1)
            save_checkpoint(self.dir, epoch + 1, snap)
            self.saved_steps.append(epoch + 1)
            if trace is not None:
                trace.instant("recovery", "checkpoint", epoch=epoch,
                              step=epoch + 1)
            self._prune()
        if self.degrade:
            self.mem = snap if snap is not None else self._snapshot(
                engines, reader, epoch + 1)

    def _prune(self) -> None:
        """Keep-last-k snapshot retention (``cfg.checkpoint_keep``, 0 = keep
        everything).  Strictly delete-AFTER-write: pruning runs only once
        the new snapshot has atomically landed, and deletes ascending from
        the oldest — a crash mid-prune can never remove the newest good
        snapshot, only leave extra old ones behind."""
        keep = int(getattr(self.cfg, "checkpoint_keep", 0))
        if keep <= 0 or not self.dir:
            return
        steps = sorted(int(m.group(1)) for f in os.listdir(self.dir)
                       if (m := re.match(r"step_(\d+)\.msgpack$", f)))
        for s in steps[:-keep]:
            try:
                os.remove(os.path.join(self.dir, f"step_{s:08d}.msgpack"))
            except OSError:
                pass


# ---------------------------------------------------------------------------
# resumable stage-1 factor streaming
# ---------------------------------------------------------------------------

class Stage1Progress:
    """Append-only row-range log of completed stage-1 chunks.

    Each drained chunk calls `mark(s, e, flush)`: the G memmap is flushed
    FIRST, then the "s e" line is written and fsync'd — so every logged range
    is durably in the G file, and a killed stage 1 restarts at the first
    missing chunk.  The log header pins (n, rank); a mismatch (different
    data/kernel/budget) invalidates the log and streaming restarts clean.
    """

    def __init__(self, path: str, n: int, rank: int, resume: bool = True):
        self.path = path
        self.n, self.rank = n, rank
        self._ranges: List = []
        header = f"{n} {rank}"
        if os.path.exists(path):
            keep = False
            if resume:
                with open(path, "r") as f:
                    lines = [ln.strip() for ln in f if ln.strip()]
                if lines and lines[0] == header:
                    keep = True
                    for ln in lines[1:]:
                        s, e = ln.split()
                        self._ranges.append((int(s), int(e)))
            if not keep:
                os.remove(path)
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        fresh = not os.path.exists(self.path)
        self._f = open(self.path, "a")
        if fresh:
            self._f.write(header + "\n")
            self._f.flush()
            os.fsync(self._f.fileno())

    @property
    def rows_done(self) -> int:
        return sum(e - s for s, e in self._ranges)

    def covered(self, s: int, e: int) -> bool:
        return any(rs <= s and e <= re for rs, re in self._ranges)

    def mark(self, s: int, e: int, flush=None) -> None:
        if flush is not None:
            flush()
        self._f.write(f"{s} {e}\n")
        self._f.flush()
        os.fsync(self._f.fileno())
        self._ranges.append((s, e))

    def close(self) -> None:
        self._f.close()


def stage1_memmap(directory: str, n: int, rank: int,
                  resume: bool) -> np.ndarray:
    """The host-resident G as an on-disk memmap under the checkpoint dir, so
    completed chunk ranges survive a kill.  A shape/dtype mismatch (or
    ``resume=False``) recreates it."""
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, "stage1_G.npy")
    if resume and os.path.exists(path):
        try:
            out = np.lib.format.open_memmap(path, mode="r+")
            if out.shape == (n, rank) and out.dtype == np.float32:
                return out
        except (ValueError, OSError):
            pass
    return np.lib.format.open_memmap(path, mode="w+", dtype=np.float32,
                                     shape=(n, rank))
