"""One-versus-one multi-class handling (paper sec. 4, following LIBSVM).

"one-versus-one means that independent SVMs are trained to separate each pair
of classes ... creating independent sub-problems is a welcome opportunity for
parallelization."  Task construction is host-side numpy (it is index
bookkeeping, not compute); the resulting `TaskBatch` is solved by
`dual_solver.solve_batch` or the sharded task farm in `distributed.py`.

Convention (LIBSVM): for the pair (a, b) with a < b, class a maps to +1.
Prediction uses majority voting with ties broken towards the smaller class
index.
"""
from __future__ import annotations

import itertools
from typing import List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.dual_solver import TaskBatch


def class_pairs(n_classes: int) -> List[Tuple[int, int]]:
    return list(itertools.combinations(range(n_classes), 2))


def _pad_to(arr: np.ndarray, n_pad: int, fill) -> np.ndarray:
    out = np.full((n_pad,), fill, dtype=arr.dtype)
    out[: arr.shape[0]] = arr
    return out


def build_ovo_tasks(
    labels: np.ndarray,
    n_classes: int,
    C: float,
    *,
    include_mask: Optional[np.ndarray] = None,
    n_pad: Optional[int] = None,
    pad_multiple: int = 8,
    alpha0: Optional[Sequence[np.ndarray]] = None,
) -> Tuple[TaskBatch, List[Tuple[int, int]]]:
    """Build the padded one-vs-one task batch.

    labels:        (n,) integer class labels, referring to rows of the shared G
    include_mask:  optional (n,) bool — rows to use (CV training folds)
    n_pad:         pad every task to this many rows (default: max pair size,
                   rounded up to `pad_multiple`)
    alpha0:        optional warm starts, one (task_size,) array per pair
    """
    labels = np.asarray(labels)
    if include_mask is None:
        include_mask = np.ones(labels.shape[0], dtype=bool)
    pairs = class_pairs(n_classes)
    idx_list, y_list = [], []
    for a, b in pairs:
        sel = np.where(include_mask & ((labels == a) | (labels == b)))[0]
        idx_list.append(sel.astype(np.int32))
        y_list.append(np.where(labels[sel] == a, 1.0, -1.0).astype(np.float32))
    max_n = max((len(s) for s in idx_list), default=1)
    if n_pad is None:
        n_pad = -(-max_n // pad_multiple) * pad_multiple
    if max_n > n_pad:
        raise ValueError(f"n_pad={n_pad} smaller than largest pair ({max_n})")

    T = len(pairs)
    idx = np.zeros((T, n_pad), dtype=np.int32)
    y = np.ones((T, n_pad), dtype=np.float32)
    c = np.zeros((T, n_pad), dtype=np.float32)
    a0 = np.zeros((T, n_pad), dtype=np.float32)
    for t in range(T):
        m = len(idx_list[t])
        idx[t] = _pad_to(idx_list[t], n_pad, 0)
        y[t] = _pad_to(y_list[t], n_pad, 1.0)
        c[t, :m] = C
        if alpha0 is not None and alpha0[t] is not None:
            a0[t, :m] = np.clip(alpha0[t][:m], 0.0, C)

    return (
        TaskBatch(idx=jnp.asarray(idx), y=jnp.asarray(y), c=jnp.asarray(c),
                  alpha0=jnp.asarray(a0)),
        pairs,
    )


def ovo_decision_values(features: jnp.ndarray, W: jnp.ndarray) -> jnp.ndarray:
    """(m, B) features x (T, B) per-pair weights -> (m, T) decision values."""
    return features @ W.T


def ovo_vote(decisions: np.ndarray, pairs: List[Tuple[int, int]],
             n_classes: int) -> np.ndarray:
    """Majority vote over pairwise decisions -> (m,) class predictions.

    Vectorised over pairs: one scatter-add into the (m, n_classes) vote
    table instead of a Python loop — the grid farm scores |gammas| x |Cs|
    cells per search, so prediction is on the measured path now.
    """
    decisions = np.asarray(decisions)
    m = decisions.shape[0]
    pa = np.asarray([p[0] for p in pairs], np.int64)
    pb = np.asarray([p[1] for p in pairs], np.int64)
    winner = np.where(decisions > 0, pa[None, :], pb[None, :])   # (m, T)
    votes = np.zeros((m, n_classes), dtype=np.int32)
    np.add.at(votes, (np.repeat(np.arange(m), len(pairs)), winner.ravel()), 1)
    # np.argmax breaks ties towards the smaller index (LIBSVM behaviour)
    return np.argmax(votes, axis=1)
