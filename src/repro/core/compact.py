"""Shrinking with *bucket compaction* — the production stage-2 driver.

In the paper, shrinking is "a complete game-changer" (x220 / x350 on the SMO
phase) partly because "after removing many variables ... the memory demand for
the relevant sub-matrix of G reduces and the processor cache becomes more
effective".  A masked-out variable in a fixed-shape JAX loop costs as much as
an active one, so to realize the paper's win we physically COMPACT the active
rows into the smallest power-of-two bucket after every full pass:

  * epochs stream only `bucket >= n_active` rows of G (HBM traffic drops
    proportionally — the TPU version of "the cache becomes more effective");
  * bucket sizes halve from n, so at most log2(n / tile) distinct kernel
    shapes ever compile;
  * every `full_pass_period`-th epoch runs un-compacted over ALL rows, which
    re-activates violating variables and provides the convergence check — the
    paper's eta ~ 5% re-check budget.

The epoch itself is the Pallas SMO kernel (kernels/smo.py) or its jnp oracle.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.dual_solver import SolverConfig


@dataclasses.dataclass
class CompactStats:
    epochs: int = 0
    full_passes: int = 0
    final_violation: float = float("inf")
    active_history: List[int] = dataclasses.field(default_factory=list)
    rows_streamed: int = 0           # sum of bucket sizes over epochs
    seconds: float = 0.0


def _bucket(n_active: int, n: int, tile: int) -> int:
    """Smallest power-of-two multiple of `tile` covering n_active (<= n)."""
    b = tile
    while b < n_active:
        b *= 2
    return min(b, n)


def solve_compact(
    G_rows: jnp.ndarray,
    y: jnp.ndarray,
    c: jnp.ndarray,
    config: SolverConfig = SolverConfig(),
    *,
    epoch_fn: Optional[Callable] = None,
    alpha0: Optional[jnp.ndarray] = None,
    tile: int = 256,
):
    """Solve one binary task on its dense row matrix (n, B).

    Returns (alpha, w, CompactStats).  `epoch_fn` defaults to the Pallas SMO
    kernel wrapper (interpret mode off-TPU); pass `kernels.ref`-based callables
    to run the oracle.
    """
    if epoch_fn is None:
        from repro.kernels.ops import smo_epoch as epoch_fn  # lazy import
    t0 = time.perf_counter()
    n, B = G_rows.shape
    tile = min(tile, n)
    y = jnp.asarray(y, jnp.float32)
    c = jnp.asarray(c, jnp.float32)
    q = jnp.sum(G_rows * G_rows, axis=1)
    alpha = (jnp.zeros((n,), jnp.float32) if alpha0 is None
             else jnp.clip(jnp.asarray(alpha0, jnp.float32), 0.0, c))
    w = (alpha * y) @ G_rows
    unchanged = jnp.zeros((n,), jnp.int32)

    period = config.full_pass_period if config.shrink else 1
    shrink_k = config.shrink_k if config.shrink else 1 << 30
    stats = CompactStats()
    cur: Optional[np.ndarray] = None          # active row indices (host)
    sub = None                                # compacted device arrays

    for epoch in range(config.max_epochs):
        full = (epoch % period == 0) or not config.shrink
        if full:
            if cur is not None and sub is not None:
                # scatter compacted state back before the full pass
                a_s, u_s = sub
                alpha = alpha.at[cur].set(a_s[: len(cur)])
                unchanged = unchanged.at[cur].set(u_s[: len(cur)])
                cur, sub = None, None
            alpha, unchanged, w, viol = epoch_fn(
                G_rows, y, c, q, alpha, unchanged, w,
                full_pass=True, shrink_k=shrink_k)
            stats.full_passes += 1
            stats.rows_streamed += n
            viol = float(viol)
            stats.final_violation = viol
            stats.active_history.append(n)
            if viol < config.tol:
                stats.epochs = epoch + 1
                break
            # compact for the cheap epochs
            u_host = np.asarray(unchanged)
            act = np.where((u_host < shrink_k) & (np.asarray(c) > 0))[0]
            if config.shrink and len(act) > 0:
                b = _bucket(len(act), n, tile)
                if b < n:
                    pad = np.zeros(b - len(act), dtype=np.int64)
                    cur_full = np.concatenate([act, pad])  # pad rows inert via c
                    cmask = np.zeros(b, np.float32)
                    cmask[: len(act)] = np.asarray(c)[act]
                    cur = act
                    sub = (alpha[cur_full].at[len(act):].set(0.0),
                           unchanged[cur_full])
                    G_sub = G_rows[cur_full]
                    y_sub = y[cur_full]
                    q_sub = q[cur_full]
                    c_sub = jnp.asarray(cmask)
        else:
            if cur is not None and sub is not None:
                a_s, u_s = sub
                a_s, u_s, w, viol = epoch_fn(
                    G_sub, y_sub, c_sub, q_sub, a_s, u_s, w,
                    full_pass=False, shrink_k=shrink_k)
                sub = (a_s, u_s)
                stats.rows_streamed += int(G_sub.shape[0])
                stats.active_history.append(int(G_sub.shape[0]))
            else:
                alpha, unchanged, w, viol = epoch_fn(
                    G_rows, y, c, q, alpha, unchanged, w,
                    full_pass=False, shrink_k=shrink_k)
                stats.rows_streamed += n
                stats.active_history.append(n)
        stats.epochs = epoch + 1

    if cur is not None and sub is not None:
        a_s, u_s = sub
        alpha = alpha.at[cur].set(a_s[: len(cur)])
    stats.seconds = time.perf_counter() - t0
    return alpha, w, stats
