"""Distribution layer for LPD-SVM on a TPU mesh.

Two parallelism patterns, mirroring the paper's hardware mapping (sec. 4):

1. **Stage 1 is dense-linear-algebra parallel** — the paper runs it on GPUs
   with cuBLAS/cuSOLVER.  Here the gram rows are sharded over the mesh
   ("data" x optionally "pod"), the budget axis over "model", and the B x B
   eigendecomposition is replicated (B <= 10^4, same as the paper's single-GPU
   eig).  `stage1_steps` exposes the jit-able pieces with shardings for the
   dry-run.

2. **Stage 2 is a task farm** — one binary problem is sequential, but OVO
   pairs x CV folds x grid cells give thousands of independent tasks ("far
   more parallelism than we need to fully exploit even multiple GPUs").
   `solve_tasks_sharded` shards the task axis over every mesh device via
   shard_map; each device vmaps its local chunk.  G is replicated (it is the
   shared read-only factor; per-chip HBM plays the paper's 512 GB RAM role).
   When G must stay in HOST RAM, `solve_tasks_streamed` is the out-of-core
   farm: the task axis is split over local devices balanced by active-row
   count, and one shared host reader streams each G row-block ONCE per pass,
   fanning it out to per-device worker queues so H2D/compute/D2H overlap
   across devices — the paper's "many cores driving multiple GPUs out of a
   large-RAM host" hardware mapping.

Both work unchanged on a single-device mesh (tests) and the production
16x16 / 2x16x16 meshes (dry-run).
"""
from __future__ import annotations

import math
import queue
import threading
import time
from functools import partial
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map

from repro.core.dual_solver import SolveResult, SolverConfig, TaskBatch, solve_batch
from repro.core.faults import classify_error
from repro.core.kernel_fn import KernelParams, apply_epilogue
from repro.core.resilience import WatchdogTimeout, WorkerStuckError
from repro.core.trace import resolve as resolve_tracer


def _mesh_size(mesh: Mesh, axes: Sequence[str]) -> int:
    return math.prod(mesh.shape[a] for a in axes)


def pad_tasks(tasks: TaskBatch, multiple: int) -> Tuple[TaskBatch, int]:
    """Pad the task axis to a device-count multiple with inert (c=0) tasks."""
    T = tasks.n_tasks
    T_pad = -(-T // multiple) * multiple
    if T_pad == T:
        return tasks, T
    pad = T_pad - T

    def padT(a):
        return jnp.concatenate([a, jnp.zeros((pad,) + a.shape[1:], a.dtype)])

    return TaskBatch(padT(tasks.idx), padT(tasks.y), padT(tasks.c),
                     padT(tasks.alpha0)), T


def solve_tasks_sharded(
    G: jnp.ndarray,
    tasks: TaskBatch,
    config: SolverConfig,
    mesh: Mesh,
    task_axes: Optional[Sequence[str]] = None,
) -> SolveResult:
    """Solve a TaskBatch with the task axis sharded over the whole mesh."""
    if task_axes is None:
        task_axes = tuple(mesh.axis_names)
    task_axes = tuple(task_axes)
    n_dev = _mesh_size(mesh, task_axes)
    tasks, T = pad_tasks(tasks, n_dev)

    tspec = P(task_axes)

    @partial(
        shard_map, mesh=mesh,
        in_specs=(P(None, None), tspec, tspec, tspec, tspec),
        out_specs=SolveResult(tspec, tspec, P(task_axes), P(task_axes),
                              P(task_axes), P(task_axes)),
        check_vma=False,   # solver carries mix invariant consts with varying data
    )
    def farm(G, idx, y, c, a0):
        return solve_batch(G, TaskBatch(idx, y, c, a0), config)

    res = farm(G, tasks.idx, tasks.y, tasks.c, tasks.alpha0)
    # strip task padding
    return SolveResult(*(r[:T] for r in res))


def balance_task_split(row_counts: Sequence[int],
                       n_parts: int) -> List[np.ndarray]:
    """Partition tasks over ``n_parts`` devices balanced by ACTIVE-ROW count.

    The old ``np.linspace`` split balanced task COUNT, so one fat OVO pair
    (two majority classes) serialised the whole farm behind its device.  LPT
    greedy instead: tasks sorted by row count descending, each assigned to
    the currently lightest part — a classic 4/3-approximation of the optimal
    makespan, deterministic for a given count vector.  Empty parts are
    dropped; each part is returned as a sorted task-index array.
    """
    counts = np.asarray(row_counts, np.int64)
    order = np.argsort(-counts, kind="stable")
    loads = np.zeros(max(1, n_parts), np.int64)
    parts: List[List[int]] = [[] for _ in range(max(1, n_parts))]
    for t in order:
        k = int(np.argmin(loads))
        parts[k].append(int(t))
        loads[k] += max(int(counts[t]), 1)   # inert tasks still spread
    return [np.sort(np.asarray(p, np.int64)) for p in parts if p]


def balance_chain_split(row_counts: Sequence[int], chain_next,
                        n_parts: int) -> List[np.ndarray]:
    """`balance_task_split` over C-ladder CHAINS instead of single tasks.

    A chain (task t, its `chain_next[t]` successor, and so on) must stay on
    ONE device: the successor is seeded from the predecessor's alphas inside
    the engine at convergence time.  Chains are therefore the atomic unit of
    the LPT split, weighted by the sum of their members' row counts — a
    chain runs its levels sequentially, so its load is the whole ladder's.
    Returns sorted task-index arrays like `balance_task_split`.
    """
    counts = np.asarray(row_counts, np.int64)
    nxt = np.asarray(chain_next, np.int64)
    has_pred = np.zeros(len(counts), bool)
    for s in nxt:
        if s >= 0:
            has_pred[s] = True
    chains: List[List[int]] = []
    for t in range(len(counts)):
        if has_pred[t]:
            continue
        chain, u = [], t
        while u >= 0:
            chain.append(u)
            u = int(nxt[u])
        chains.append(chain)
    weights = [sum(max(int(counts[t]), 1) for t in ch) for ch in chains]
    groups = balance_task_split(weights, n_parts)
    return [np.sort(np.concatenate([np.asarray(chains[int(ci)], np.int64)
                                    for ci in g])) for g in groups]


def _local_chain(chain_next, part: np.ndarray) -> Optional[np.ndarray]:
    """Remap global `chain_next` onto one shard's local task indices."""
    if chain_next is None:
        return None
    nxt = np.asarray(chain_next, np.int64)
    local = {int(g): i for i, g in enumerate(part)}
    return np.array([local.get(int(nxt[int(g)]), -1) for g in part],
                    np.int64)


class _DeviceWorkers:
    """One lightweight host worker per device for the overlapped task farm.

    The shared reader pushes block-feed closures into per-device bounded
    queues; each worker drains its own queue in order, so the per-engine
    block sequence (and hence the SMO trajectory) is preserved while H2D,
    compute, and D2H overlap ACROSS devices.  The bound gives backpressure:
    the reader stalls instead of staging unboundedly many host buffers when
    one device falls behind.  Worker exceptions surface at the next barrier.

    With an enabled tracer the farm's two stall signals become spans: the
    reader's ``queue/backpressure`` (blocked pushing into a full device
    queue — that device is the bottleneck) and each worker's
    ``queue/worker_idle`` (blocked waiting for the reader — the shared
    reader is the bottleneck), plus a per-device queue-depth gauge.

    Fault tolerance: worker errors are recorded WITH the failing device's
    name (`failed()`), so the degradation loop in `solve_tasks_streamed` can
    quarantine exactly the lost devices; ``watchdog`` > 0 turns the barrier
    into a deadline wait that raises a `WatchdogTimeout` full of queue/thread
    diagnostics instead of hanging on a starved queue; `close` detects (and
    reports) workers still alive after the join timeout instead of silently
    leaking them.
    """

    def __init__(self, engines, depth: int, trace=None,
                 names: Optional[Sequence[str]] = None,
                 watchdog: float = 0.0, join_timeout: float = 60.0):
        self._tr = resolve_tracer(trace)
        if names is None:
            names = [f"dev{i}" for i in range(len(engines))]
        self._names = {id(e): nm for e, nm in zip(engines, names)}
        self._queues = {id(e): queue.Queue(maxsize=max(2, depth))
                        for e in engines}
        self._errors: List[Tuple[str, BaseException]] = []
        self._watchdog = watchdog
        self._join_timeout = join_timeout
        # Per-worker last-activity stamp (monotonic seconds + what it was):
        # the watchdog's "who is stuck" diagnostic.
        self._last = {nm: ("spawned", time.monotonic()) for nm in names}
        self._threads = []
        for e in engines:
            nm = self._names[id(e)]
            th = threading.Thread(target=self._loop,
                                  args=(self._queues[id(e)], nm),
                                  name=f"worker/{nm}", daemon=True)
            th.start()
            self._threads.append(th)

    def _loop(self, q, name):
        tr = self._tr
        while True:
            t0 = tr.begin()
            fn = q.get()
            try:
                if fn is None:
                    self._last[name] = ("exited", time.monotonic())
                    return
                if tr.enabled:
                    tr.end("queue", "worker_idle", t0, device=name)
                    tr.counter(f"queue_depth/{name}", q.qsize())
                self._last[name] = ("running", time.monotonic())
                if not self._errors:     # fail fast: drain the rest as no-ops
                    fn()
                self._last[name] = ("idle", time.monotonic())
            except BaseException as exc:   # noqa: BLE001 — re-raised at barrier
                self._errors.append((name, exc))
                self._last[name] = (f"error:{type(exc).__name__}",
                                    time.monotonic())
                # A fault instant (not a span) so a failed run's exported
                # trace shows WHERE the farm broke.
                tr.instant("fault", "worker_error", device=name,
                           error=type(exc).__name__)
            finally:
                q.task_done()

    def submit(self, engine, fn):
        q = self._queues[id(engine)]
        tr = self._tr
        if tr.enabled and q.full():
            # Reader blocked on a full device queue — measured backpressure.
            t0 = tr.begin()
            q.put(fn)
            tr.end("queue", "backpressure", t0,
                   device=self._names[id(engine)])
        else:
            q.put(fn)

    def failed(self):
        """Map of worker name -> first recorded exception (degradation input)."""
        out = {}
        for nm, exc in self._errors:
            out.setdefault(nm, exc)
        return out

    def _diagnose(self) -> str:
        now = time.monotonic()
        lines = []
        for (eid, q), th in zip(self._queues.items(), self._threads):
            nm = th.name.split("/", 1)[-1]
            state, when = self._last.get(nm, ("unknown", now))
            lines.append(f"  {th.name}: alive={th.is_alive()} "
                         f"queued={q.qsize()} unfinished={q.unfinished_tasks} "
                         f"last={state} {now - when:.1f}s ago")
        return "\n".join(lines)

    def barrier(self):
        if self._watchdog > 0:
            deadline = time.monotonic() + self._watchdog
            for q in self._queues.values():
                starved = False
                with q.all_tasks_done:
                    while q.unfinished_tasks:
                        remaining = deadline - time.monotonic()
                        if remaining <= 0:
                            starved = True
                            break
                        q.all_tasks_done.wait(remaining)
                if starved:
                    # raised OUTSIDE the queue lock: _diagnose reads qsize(),
                    # which needs the same (non-reentrant) mutex
                    raise WatchdogTimeout(
                        f"farm barrier starved past {self._watchdog:.1f}s; "
                        "worker states:\n" + self._diagnose())
        else:
            for q in self._queues.values():
                q.join()
        if self._errors:
            raise self._errors[0][1]

    def close(self, suppress: bool = False):
        for q in self._queues.values():
            q.put(None)
        stuck = []
        for th in self._threads:
            th.join(timeout=self._join_timeout)
            if th.is_alive():
                stuck.append(th.name)
        if stuck:
            msg = (f"worker threads still alive after "
                   f"{self._join_timeout:.0f}s join: {', '.join(stuck)}\n"
                   + self._diagnose())
            self._tr.instant("fault", "worker_leak", threads=len(stuck))
            if suppress:
                # Called while an exception propagates (the driver's
                # finally): raising here would REPLACE it — degrade to a
                # warning, the farm is already failing for the real reason.
                import warnings
                warnings.warn(msg, RuntimeWarning, stacklevel=2)
            else:
                raise WorkerStuckError(msg)


def _scatter_results(parts: Sequence[np.ndarray], results, T: int,
                     n_pad: int, rank: int) -> SolveResult:
    """Reassemble per-shard SolveResults into the original task order."""
    alpha = np.zeros((T, n_pad), np.float32)
    w = np.zeros((T, rank), np.float32)
    epochs = np.zeros((T,), np.int32)
    violation = np.zeros((T,), np.float32)
    dual = np.zeros((T,), np.float32)
    n_sv = np.zeros((T,), np.int32)
    for p, r in zip(parts, results):
        alpha[p] = np.asarray(r.alpha)
        w[p] = np.asarray(r.w)
        epochs[p] = np.asarray(r.epochs)
        violation[p] = np.asarray(r.violation)
        dual[p] = np.asarray(r.dual_obj)
        n_sv[p] = np.asarray(r.n_sv)
    return SolveResult(alpha=alpha, w=w, epochs=epochs, violation=violation,
                       dual_obj=dual, n_sv=n_sv)


def solve_tasks_streamed(
    G,
    tasks: TaskBatch,
    config: SolverConfig,
    *,
    devices: Sequence,
    stream_config=None,
    overlap: bool = True,
    return_stats: bool = False,
    epoch_fn=None,
    chain_next=None,
):
    """Out-of-core stage-2 task farm over ``devices`` (host-resident G).

    ``overlap=True`` (default) runs the single-pass shared block broadcast:
    one host reader stages each (tile, B) row-block of G ONCE per shared
    pass and fans it out to every device's bounded in-flight queue
    (`_DeviceWorkers`), so D devices cost one G read per pass — not D — and
    their H2D/compute/D2H pipelines overlap.  ``overlap=False`` keeps the
    legacy serial farm (each device's stream driven to completion in turn,
    re-reading G once per device) as the benchmark baseline.

    The task axis is split by per-task active-row count (`balance_task_split`)
    so one fat OVO pair cannot serialise the farm.  Like
    `stream_factor_over_mesh` this is per-host — a multi-host mesh runs one
    call per process on its local task share (ROADMAP item).

    Each engine owns a PER-DEVICE hot-row block cache (`core/block_cache.py`)
    over its shard's compacted active-row union — unions are shard-local, so
    pinning is too, and warm compacted cheap epochs run with ~zero G H2D on
    every device at once.  Shared full passes never consult the caches: the
    one-read-per-pass reader invariant (per-pass `bytes_h2d` independent of
    device count) is untouched by caching.
    """
    from repro.core.solver_stream import (StreamConfig, _Stage2Engine,
                                          auto_tile_rows, default_epoch_fn,
                                          drive_streamed_engines,
                                          merge_stream_stats,
                                          solve_batch_streamed)

    t0 = time.perf_counter()
    cfg = stream_config or StreamConfig()
    devices = list(devices)
    T = tasks.n_tasks
    if len(devices) <= 1 or T <= 1:
        return solve_batch_streamed(G, tasks, config, stream_config=cfg,
                                    epoch_fn=epoch_fn,
                                    device=devices[0] if devices else None,
                                    chain_next=chain_next,
                                    return_stats=return_stats)

    if not getattr(G, "is_shard_view", False):
        # Keep a shards.GShardView disk-resident — the shared reader slices
        # row blocks from it like any ndarray.
        G = np.asarray(G, np.float32)
    n, rank = G.shape
    idx = np.asarray(tasks.idx)
    y = np.asarray(tasks.y, np.float32)
    c = np.asarray(tasks.c, np.float32)
    a0 = np.asarray(tasks.alpha0, np.float32)
    row_counts = (c > 0.0).sum(axis=1)
    parts = (balance_chain_split(row_counts, chain_next, len(devices))
             if chain_next is not None
             else balance_task_split(row_counts, len(devices)))
    subs = [TaskBatch(idx[p], y[p], c[p], a0[p]) for p in parts]
    sub_chains = [_local_chain(chain_next, p) for p in parts]

    if not overlap:
        results, per_dev = [], []
        for d, sub, ch in zip(devices, subs, sub_chains):
            r, s = solve_batch_streamed(G, sub, config, stream_config=cfg,
                                        epoch_fn=epoch_fn, device=d,
                                        chain_next=ch, return_stats=True)
            results.append(r)
            per_dev.append(s)
        res = _scatter_results(parts, results, T, idx.shape[1], rank)
        if not return_stats:
            return res
        # Serial aggregate: a zero reader record — every device paid its own
        # G stream, so mesh-level bytes sum to ~D x the single-device figure
        # (exactly the cost the overlapped farm removes).
        from repro.core.solver_stream import Stage2StreamStats
        reader0 = Stage2StreamStats(tile_rows=per_dev[0].tile_rows,
                                    block_dtype=cfg.block_dtype)
        return res, merge_stream_stats(
            reader0, per_dev, seconds=time.perf_counter() - t0,
            n_devices=len(subs))

    epoch_fn = epoch_fn or default_epoch_fn()
    # One int8 scale-table cache for the whole farm: every engine streams
    # the same G, so the global group scales are computed once, not once
    # per device.
    scale_cache: dict = {}
    tr = resolve_tracer(cfg.trace)

    # Fault tolerance: the guard snapshots the GLOBAL-task-keyed solver state
    # at every epoch boundary (in memory when fail_fast=False, to disk every
    # checkpoint_every full passes), so a lost device's shard can be re-split
    # onto the survivors and the farm re-entered from the last boundary.
    guard = None
    if cfg.checkpoint_dir or not cfg.fail_fast:
        from repro.core.resilience import StreamGuard, g_fingerprint
        guard = StreamGuard(cfg, n=n, rank=rank, sizes=row_counts,
                            g_fp=g_fingerprint(G), degrade=not cfg.fail_fast)
        if cfg.checkpoint_dir and cfg.resume:
            snap = guard.try_resume()
            if snap is not None:
                guard.adopt(snap)

    avail = list(devices)
    dev_ids = list(range(len(avail)))   # original indices — names stay
    #   stable across quarantines so per-device fault specs / traces line up
    while True:
        parts = (balance_chain_split(row_counts, chain_next, len(avail))
                 if chain_next is not None
                 else balance_task_split(row_counts, len(avail)))
        subs = [TaskBatch(idx[p], y[p], c[p], a0[p]) for p in parts]
        sub_chains = [_local_chain(chain_next, p) for p in parts]
        # One tile for ALL engines (the shared reader stages each block
        # once); sized by the fattest shard so every in-flight set fits.
        tile = auto_tile_rows(n, rank, max(len(p) for p in parts), cfg)
        names = [f"dev{dev_ids[j]}" for j in range(len(avail))]
        engines = [_Stage2Engine(G, sub, config, cfg, epoch_fn=epoch_fn,
                                 device=d, tile=tile,
                                 scale_cache=scale_cache, chain_next=ch,
                                 name=nm, task_ids=p)
                   for d, sub, ch, nm, p in zip(avail, subs, sub_chains,
                                                names, parts)]
        if guard is not None and guard.mem is not None:
            from repro.core.resilience import restore_engines
            restore_engines(engines, guard.mem)
        workers = _DeviceWorkers(engines, depth=max(2, cfg.prefetch),
                                 trace=cfg.trace, names=names,
                                 watchdog=cfg.watchdog_seconds)
        try:
            reader = drive_streamed_engines(engines, G, config, cfg,
                                            tile=tile, fanout=workers,
                                            guard=guard)
            break
        except Exception:
            failed = workers.failed()
            if (cfg.fail_fast or guard is None or not failed
                    or any(classify_error(e) != "persistent"
                           for e in failed.values())):
                raise
            keep = [j for j in range(len(avail)) if names[j] not in failed]
            if not keep:
                raise
            # Quarantine the lost devices; solver state rolls back to the
            # guard's last epoch-boundary snapshot and the next lap re-splits
            # every task over the survivors (chain-aware LPT, same as a
            # fresh solve at that device count — per-task trajectories are
            # placement-invariant, so the result is bit-equal to a clean
            # run on the surviving devices).
            tr.instant("recovery", "quarantine",
                       lost=len(avail) - len(keep), survivors=len(keep),
                       resume_epoch=int(guard.mem["meta"]["epoch_next"])
                       if guard.mem is not None else 0)
            avail = [avail[j] for j in keep]
            dev_ids = [dev_ids[j] for j in keep]
            guard.adopt_mem()
    pairs = [e.result() for e in engines]
    res = _scatter_results(parts, [p[0] for p in pairs], T, idx.shape[1],
                           rank)
    if not return_stats:
        return res
    return res, merge_stream_stats(
        reader, [p[1] for p in pairs], seconds=time.perf_counter() - t0,
        n_devices=len(engines), carry=guard.carry if guard else None)


def solve_tasks_streamed_mesh(
    mesh: Mesh,
    G,
    tasks: TaskBatch,
    config: SolverConfig,
    *,
    stream_config=None,
    overlap: bool = True,
    return_stats: bool = False,
    chain_next=None,
) -> SolveResult:
    """Out-of-core counterpart of `solve_tasks_sharded` over a mesh's LOCAL
    devices: the row-count-balanced task shards stream G row-blocks
    (core/solver_stream.py), overlapped behind one shared block reader by
    default (`solve_tasks_streamed`)."""
    return solve_tasks_streamed(G, tasks, config,
                                devices=list(mesh.local_devices),
                                stream_config=stream_config, overlap=overlap,
                                chain_next=chain_next,
                                return_stats=return_stats)


# ---------------------------------------------------------------------------
# Stage 1 with explicit shardings (used by launch/dryrun.py and train_svm.py)
# ---------------------------------------------------------------------------

def stage1_gram_sharded(mesh: Mesh, params: KernelParams,
                        row_axes: Sequence[str] = ("data",),
                        col_axis: str = "model"):
    """Return a jit'd K(x, z) with x rows sharded and z columns sharded."""
    row_spec = P(tuple(row_axes), None)
    col_spec = P(col_axis, None)

    @partial(jax.jit,
             in_shardings=(NamedSharding(mesh, row_spec),
                           NamedSharding(mesh, col_spec)),
             out_shardings=NamedSharding(mesh, P(tuple(row_axes), col_axis)))
    def gram_dist(x, z):
        dot = jnp.einsum("np,mp->nm", x, z, precision=jax.lax.Precision.HIGHEST)
        x_sq = jnp.sum(x * x, axis=-1)
        z_sq = jnp.sum(z * z, axis=-1)
        return apply_epilogue(dot, x_sq, z_sq, params)

    return gram_dist


def stage1_project_sharded(mesh: Mesh, row_axes: Sequence[str] = ("data",),
                           col_axis: str = "model"):
    """Return a jit'd (K_nm, projector) -> G with G rows kept data-sharded.

    K_nm arrives (rows x "data", cols x "model"); the projector (B, B') is
    replicated; the contraction over B induces one reduce-scatter/all-reduce
    over "model" — visible in the dry-run collective schedule.
    """
    @partial(jax.jit,
             in_shardings=(NamedSharding(mesh, P(tuple(row_axes), col_axis)),
                           NamedSharding(mesh, P(None, None))),
             out_shardings=NamedSharding(mesh, P(tuple(row_axes), col_axis)))
    def project(k_nm, projector):
        return jnp.einsum("nb,bk->nk", k_nm, projector,
                          precision=jax.lax.Precision.HIGHEST)

    return project


def stage1_project_sharded_v2(mesh: Mesh, row_axes: Sequence[str] = ("data",),
                              col_axis: str = "model"):
    """Beyond-paper §Perf fix for the stage-1 projection (hillclimb #3).

    The baseline keeps K_nm sharded (rows x "data", cols x "model") and lets
    GSPMD handle the contraction over the "model"-sharded budget axis — which
    it implements by ALL-GATHERING the full (n_loc, B) block on every device
    (25 GB/device at the paper's n=10^7, B=10^4 scale; temp 46.6 GiB).

    Hypothesis: resharding K_nm to rows x ("data","model") first makes the
    matmul fully local — the only collective is the reshard itself, which
    moves each element once (1.56 GB/device) instead of (M-1)x.
    """
    all_rows = tuple(row_axes) + (col_axis,)

    @partial(jax.jit,
             in_shardings=(NamedSharding(mesh, P(tuple(row_axes), col_axis)),
                           NamedSharding(mesh, P(None, None))),
             out_shardings=NamedSharding(mesh, P(all_rows, None)))
    def project(k_nm, projector):
        k_nm = jax.lax.with_sharding_constraint(k_nm, P(all_rows, None))
        return jnp.einsum("nb,bk->nk", k_nm, projector,
                          precision=jax.lax.Precision.HIGHEST)

    return project


def replicate(mesh: Mesh, x):
    return jax.device_put(x, NamedSharding(mesh, P(*((None,) * x.ndim))))


# ---------------------------------------------------------------------------
# Stage 1 out-of-core over a mesh: disjoint row-chunk streams per device
# ---------------------------------------------------------------------------

def stream_factor_over_mesh(
    mesh: Mesh,
    x,
    landmarks,
    projector,
    params: KernelParams,
    *,
    chunk_rows: int,
    prefetch: int = 2,
    gram_fn=None,
    out=None,
):
    """Chunked stage-1 G over every device of `mesh` (host-resident x and G).

    The complement of `stage1_gram_sharded`: that path assumes the full
    (n, p) x and (n, B) K_nm fit *sharded across* the mesh; this one assumes
    they only fit in host RAM.  Row chunks are handed round-robin to the
    flattened mesh devices, so each device owns a disjoint chunk stream with
    its own resident landmark/projector replica and its own double-buffered
    H2D/compute/D2H overlap — no collectives at all in stage 1, matching the
    paper's embarrassingly-row-parallel gram computation.  The replicated
    stage-2 task farm (`solve_tasks_sharded`) consumes the resulting G
    unchanged.
    """
    from repro.core.kernel_fn import gram as _gram_ref
    from repro.core.streaming import stream_factor_rows

    # Only this process's devices: device_put to another host's chip raises.
    # Multi-host meshes stream their own row range per host (ROADMAP item).
    devices = list(mesh.local_devices)
    return stream_factor_rows(
        x, landmarks, projector, params, chunk_rows=chunk_rows,
        prefetch=prefetch, gram_fn=gram_fn or _gram_ref, out=out,
        devices=devices)


def compute_factor_streamed_mesh(
    mesh: Mesh,
    x,
    params: KernelParams,
    budget: int,
    *,
    key=None,
    stream_config=None,
    gram_fn=None,
):
    """`streaming.compute_factor_streamed` with the chunk streams spread over
    `mesh` — the full two-stage entry point for a multi-device host."""
    from repro.core.kernel_fn import gram as _gram_ref
    from repro.core.streaming import StreamConfig, compute_factor_streamed

    devices = list(mesh.local_devices)
    return compute_factor_streamed(
        x, params, budget, key=key, config=stream_config or StreamConfig(),
        gram_fn=gram_fn or _gram_ref, devices=devices)
