"""Distribution layer for LPD-SVM on a TPU mesh.

Two parallelism patterns, mirroring the paper's hardware mapping (sec. 4):

1. **Stage 1 is dense-linear-algebra parallel** — the paper runs it on GPUs
   with cuBLAS/cuSOLVER.  Here the gram rows are sharded over the mesh
   ("data" x optionally "pod"), the budget axis over "model", and the B x B
   eigendecomposition is replicated (B <= 10^4, same as the paper's single-GPU
   eig).  `stage1_steps` exposes the jit-able pieces with shardings for the
   dry-run.

2. **Stage 2 is a task farm** — one binary problem is sequential, but OVO
   pairs x CV folds x grid cells give thousands of independent tasks ("far
   more parallelism than we need to fully exploit even multiple GPUs").
   `solve_tasks_sharded` shards the task axis over every mesh device via
   shard_map; each device vmaps its local chunk.  G is replicated (it is the
   shared read-only factor; per-chip HBM plays the paper's 512 GB RAM role).

Both work unchanged on a single-device mesh (tests) and the production
16x16 / 2x16x16 meshes (dry-run).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map

from repro.core.dual_solver import SolveResult, SolverConfig, TaskBatch, solve_batch
from repro.core.kernel_fn import KernelParams, apply_epilogue


def _mesh_size(mesh: Mesh, axes: Sequence[str]) -> int:
    return math.prod(mesh.shape[a] for a in axes)


def pad_tasks(tasks: TaskBatch, multiple: int) -> Tuple[TaskBatch, int]:
    """Pad the task axis to a device-count multiple with inert (c=0) tasks."""
    T = tasks.n_tasks
    T_pad = -(-T // multiple) * multiple
    if T_pad == T:
        return tasks, T
    pad = T_pad - T

    def padT(a):
        return jnp.concatenate([a, jnp.zeros((pad,) + a.shape[1:], a.dtype)])

    return TaskBatch(padT(tasks.idx), padT(tasks.y), padT(tasks.c),
                     padT(tasks.alpha0)), T


def solve_tasks_sharded(
    G: jnp.ndarray,
    tasks: TaskBatch,
    config: SolverConfig,
    mesh: Mesh,
    task_axes: Optional[Sequence[str]] = None,
) -> SolveResult:
    """Solve a TaskBatch with the task axis sharded over the whole mesh."""
    if task_axes is None:
        task_axes = tuple(mesh.axis_names)
    task_axes = tuple(task_axes)
    n_dev = _mesh_size(mesh, task_axes)
    tasks, T = pad_tasks(tasks, n_dev)

    tspec = P(task_axes)

    @partial(
        shard_map, mesh=mesh,
        in_specs=(P(None, None), tspec, tspec, tspec, tspec),
        out_specs=SolveResult(tspec, tspec, P(task_axes), P(task_axes),
                              P(task_axes), P(task_axes)),
        check_vma=False,   # solver carries mix invariant consts with varying data
    )
    def farm(G, idx, y, c, a0):
        return solve_batch(G, TaskBatch(idx, y, c, a0), config)

    res = farm(G, tasks.idx, tasks.y, tasks.c, tasks.alpha0)
    # strip task padding
    return SolveResult(*(r[:T] for r in res))


def solve_tasks_streamed_mesh(
    mesh: Mesh,
    G,
    tasks: TaskBatch,
    config: SolverConfig,
    *,
    stream_config=None,
) -> SolveResult:
    """Out-of-core counterpart of `solve_tasks_sharded`: G stays a host
    numpy buffer and each local device solves a contiguous slice of the task
    axis by streaming G row-blocks (core/solver_stream.py) with its own
    device-resident w state.

    The host drives the devices' block streams in turn; each device's H2D /
    compute overlap comes from the solver's own prefetch queue.  Like
    `stream_factor_over_mesh` this is per-host — a multi-host mesh runs one
    call per process on its local task share (ROADMAP item).
    """
    from repro.core.solver_stream import solve_batch_streamed

    devices = list(mesh.local_devices)
    T = tasks.n_tasks
    if len(devices) <= 1:
        return solve_batch_streamed(G, tasks, config,
                                    stream_config=stream_config,
                                    device=devices[0] if devices else None)
    bounds = np.linspace(0, T, len(devices) + 1).astype(int)
    parts = []
    for d, lo, hi in zip(devices, bounds[:-1], bounds[1:]):
        if lo == hi:
            continue
        sub = TaskBatch(tasks.idx[lo:hi], tasks.y[lo:hi],
                        tasks.c[lo:hi], tasks.alpha0[lo:hi])
        parts.append(solve_batch_streamed(G, sub, config,
                                          stream_config=stream_config,
                                          device=d))
    return SolveResult(*(np.concatenate(f) for f in zip(*parts)))


# ---------------------------------------------------------------------------
# Stage 1 with explicit shardings (used by launch/dryrun.py and train_svm.py)
# ---------------------------------------------------------------------------

def stage1_gram_sharded(mesh: Mesh, params: KernelParams,
                        row_axes: Sequence[str] = ("data",),
                        col_axis: str = "model"):
    """Return a jit'd K(x, z) with x rows sharded and z columns sharded."""
    row_spec = P(tuple(row_axes), None)
    col_spec = P(col_axis, None)

    @partial(jax.jit,
             in_shardings=(NamedSharding(mesh, row_spec),
                           NamedSharding(mesh, col_spec)),
             out_shardings=NamedSharding(mesh, P(tuple(row_axes), col_axis)))
    def gram_dist(x, z):
        dot = jnp.einsum("np,mp->nm", x, z, precision=jax.lax.Precision.HIGHEST)
        x_sq = jnp.sum(x * x, axis=-1)
        z_sq = jnp.sum(z * z, axis=-1)
        return apply_epilogue(dot, x_sq, z_sq, params)

    return gram_dist


def stage1_project_sharded(mesh: Mesh, row_axes: Sequence[str] = ("data",),
                           col_axis: str = "model"):
    """Return a jit'd (K_nm, projector) -> G with G rows kept data-sharded.

    K_nm arrives (rows x "data", cols x "model"); the projector (B, B') is
    replicated; the contraction over B induces one reduce-scatter/all-reduce
    over "model" — visible in the dry-run collective schedule.
    """
    @partial(jax.jit,
             in_shardings=(NamedSharding(mesh, P(tuple(row_axes), col_axis)),
                           NamedSharding(mesh, P(None, None))),
             out_shardings=NamedSharding(mesh, P(tuple(row_axes), col_axis)))
    def project(k_nm, projector):
        return jnp.einsum("nb,bk->nk", k_nm, projector,
                          precision=jax.lax.Precision.HIGHEST)

    return project


def stage1_project_sharded_v2(mesh: Mesh, row_axes: Sequence[str] = ("data",),
                              col_axis: str = "model"):
    """Beyond-paper §Perf fix for the stage-1 projection (hillclimb #3).

    The baseline keeps K_nm sharded (rows x "data", cols x "model") and lets
    GSPMD handle the contraction over the "model"-sharded budget axis — which
    it implements by ALL-GATHERING the full (n_loc, B) block on every device
    (25 GB/device at the paper's n=10^7, B=10^4 scale; temp 46.6 GiB).

    Hypothesis: resharding K_nm to rows x ("data","model") first makes the
    matmul fully local — the only collective is the reshard itself, which
    moves each element once (1.56 GB/device) instead of (M-1)x.
    """
    all_rows = tuple(row_axes) + (col_axis,)

    @partial(jax.jit,
             in_shardings=(NamedSharding(mesh, P(tuple(row_axes), col_axis)),
                           NamedSharding(mesh, P(None, None))),
             out_shardings=NamedSharding(mesh, P(all_rows, None)))
    def project(k_nm, projector):
        k_nm = jax.lax.with_sharding_constraint(k_nm, P(all_rows, None))
        return jnp.einsum("nb,bk->nk", k_nm, projector,
                          precision=jax.lax.Precision.HIGHEST)

    return project


def replicate(mesh: Mesh, x):
    return jax.device_put(x, NamedSharding(mesh, P(*((None,) * x.ndim))))


# ---------------------------------------------------------------------------
# Stage 1 out-of-core over a mesh: disjoint row-chunk streams per device
# ---------------------------------------------------------------------------

def stream_factor_over_mesh(
    mesh: Mesh,
    x,
    landmarks,
    projector,
    params: KernelParams,
    *,
    chunk_rows: int,
    prefetch: int = 2,
    gram_fn=None,
    out=None,
):
    """Chunked stage-1 G over every device of `mesh` (host-resident x and G).

    The complement of `stage1_gram_sharded`: that path assumes the full
    (n, p) x and (n, B) K_nm fit *sharded across* the mesh; this one assumes
    they only fit in host RAM.  Row chunks are handed round-robin to the
    flattened mesh devices, so each device owns a disjoint chunk stream with
    its own resident landmark/projector replica and its own double-buffered
    H2D/compute/D2H overlap — no collectives at all in stage 1, matching the
    paper's embarrassingly-row-parallel gram computation.  The replicated
    stage-2 task farm (`solve_tasks_sharded`) consumes the resulting G
    unchanged.
    """
    from repro.core.kernel_fn import gram as _gram_ref
    from repro.core.streaming import stream_factor_rows

    # Only this process's devices: device_put to another host's chip raises.
    # Multi-host meshes stream their own row range per host (ROADMAP item).
    devices = list(mesh.local_devices)
    return stream_factor_rows(
        x, landmarks, projector, params, chunk_rows=chunk_rows,
        prefetch=prefetch, gram_fn=gram_fn or _gram_ref, out=out,
        devices=devices)


def compute_factor_streamed_mesh(
    mesh: Mesh,
    x,
    params: KernelParams,
    budget: int,
    *,
    key=None,
    stream_config=None,
    gram_fn=None,
):
    """`streaming.compute_factor_streamed` with the chunk streams spread over
    `mesh` — the full two-stage entry point for a multi-device host."""
    from repro.core.kernel_fn import gram as _gram_ref
    from repro.core.streaming import StreamConfig, compute_factor_streamed

    devices = list(mesh.local_devices)
    return compute_factor_streamed(
        x, params, budget, key=key, config=stream_config or StreamConfig(),
        gram_fn=gram_fn or _gram_ref, devices=devices)
