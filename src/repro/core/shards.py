"""Durable disk tier: the checksummed binary shard store.

The paper's "more RAM!" argument is a memory hierarchy; this module extends
it one tier below host RAM, so LIBSVM text is parsed ONCE into binary shards
and datasets (or a spilled stage-1 factor G) larger than host memory
re-stream per epoch from NVMe through the existing
`stream_factor_blocks` / `iter_shared_blocks` pipelines.  A disk tier that
training trusts blindly is a liability on day-long runs, so the store is
built robustness-first:

  * **Every write is atomic** — shard files and the manifest are written to
    a temp file, fsynced, then `os.replace`d into place, and the manifest is
    written LAST.  A kill -9 at ANY point leaves either a fully valid store
    or no manifest (never a readable-but-wrong shard behind a valid
    manifest).
  * **Every read is verified** — each shard carries an xxhash64 (CRC32
    fallback) digest over its header+payload in a fixed footer, and the
    manifest pins every shard's expected digest plus a whole-store
    fingerprint.  Torn writes, bit rot, and stale files are all detected on
    the first read, not silently trained on.
  * **Corruption is recoverable** — a checksum mismatch quarantines the bad
    file under ``quarantine/`` and, when a ``rebuilder`` is attached,
    regenerates the shard from source (re-parse that LIBSVM row range, or
    recompute the G rows) and verifies the rebuild reproduces the
    manifest's digest bit-exactly.  Transient IO errors retry with the
    same bounded-backoff taxonomy as the H2D path (`faults.classify_error`).
  * **Everything is injectable** — deterministic `FaultSpec` sites
    (``shard_write``, ``shard_read``, ``shard_corrupt`` — an in-place
    bit-flip) make the whole recovery surface testable with zero wall-clock
    randomness (`tests/test_shards.py`).

Shard file layout (fixed offsets, so a verified file is memory-mappable)::

    [0:64)    header: magic "LPDSHRD1", version, dtype code, rows, cols,
              group, section byte counts (values / scales / labels)
    [64:...)  values   rows*cols of f32 or int8
              scales   (ng, 2) f32 per-group (scale, zero), int8 shards only
              labels   (rows,) f64, dataset shards only
    [-8:]     footer: u64 digest of header+payload

int8 shards use the symmetric `core/quant.py` codec with scale groups
aligned to the shard start; because ``shard_rows`` is a multiple of
`GROUP_ROWS`, every group boundary is GLOBAL-row-aligned — the same
alignment contract the streamed stage-2 wire relies on, so a shard-resident
G serves `group_scales` tables identical to a host-resident G's.
"""
from __future__ import annotations

import dataclasses
import json
import os
import struct
import threading
import time
from collections import OrderedDict
from typing import Callable, Dict, Iterator, List, Optional, Tuple, Union

import numpy as np

from repro.core.faults import check as _fault_check
from repro.core.faults import classify_error
from repro.core.quant import (GROUP_ROWS, QuantBlock, dequantize_rows,
                              dequantize_rows_range,
                              group_scales as quant_group_scales,
                              quantize_rows)
from repro.core.trace import resolve as resolve_tracer

try:
    import xxhash as _xxhash
    HASH_NAME = "xxh64"
except ImportError:                                   # pragma: no cover
    _xxhash = None
    HASH_NAME = "crc32"

MAGIC = b"LPDSHRD1"
VERSION = 1
MANIFEST_NAME = "manifest.json"
QUARANTINE_DIR = "quarantine"
#: magic(8) version(u32) dtype(u32) rows cols group values scales labels (u64)
_HEADER = struct.Struct("<8sIIQQQQQQ")
_FOOTER = struct.Struct("<Q")
HEADER_BYTES = _HEADER.size
FOOTER_BYTES = _FOOTER.size
_DTYPE_CODES = {"f32": 0, "int8": 1}
_DTYPE_NAMES = {v: k for k, v in _DTYPE_CODES.items()}
SHARD_DTYPES = tuple(_DTYPE_CODES)


class ShardError(Exception):
    """Structural problem with a shard store (missing manifest, bad layout,
    a rebuild that failed to reproduce the manifest digest, ...)."""


class ShardCorruptionError(ShardError):
    """A shard's bytes do not match its recorded digest (bit rot, torn or
    foreign file) and no rebuilder could restore it."""


@dataclasses.dataclass
class ShardStoreStats:
    """Counters of one store's disk traffic and recovery activity."""

    shards_written: int = 0
    shards_read: int = 0
    bytes_written: int = 0
    bytes_read: int = 0
    verifications: int = 0        # checksum computations on read
    checksum_failures: int = 0    # reads whose digest did not match
    quarantined: int = 0          # corrupt files moved to quarantine/
    rebuilt: int = 0              # shards regenerated from source
    retries: int = 0              # transient-IO read retries
    read_seconds: float = 0.0
    write_seconds: float = 0.0

    @property
    def read_gbps(self) -> float:
        return self.bytes_read / max(self.read_seconds, 1e-12) / 1e9


def shard_name(i: int) -> str:
    return f"shard_{i:05d}.bin"


class _Crc32Hasher:
    """8-byte-digest stand-in when xxhash is absent (stdlib zlib.crc32)."""

    def __init__(self):
        import zlib
        self._crc32 = zlib.crc32
        self._state = 0
        self._length = 0

    def update(self, buf) -> None:
        self._state = self._crc32(buf, self._state)
        self._length = (self._length + len(buf)) & 0xFFFFFFFF

    def intdigest(self) -> int:
        return (self._state << 32) | self._length


def _hasher():
    return _xxhash.xxh64() if _xxhash is not None else _Crc32Hasher()


def _digest(buffers) -> int:
    h = _hasher()
    for b in buffers:
        h.update(b)
    return h.intdigest()


def _fsync_write(path: str, buffers) -> int:
    """Temp-file + fsync + atomic-rename write; returns bytes written."""
    tmp = f"{path}.tmp.{os.getpid()}"
    nbytes = 0
    with open(tmp, "wb") as f:
        for b in buffers:
            f.write(b)
            nbytes += len(b)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    return nbytes


def _pack_shard(values: np.ndarray, scales: Optional[np.ndarray],
                labels: Optional[np.ndarray], dtype: str,
                group: int) -> Tuple[List[bytes], int]:
    """Serialise one shard to (buffers, digest); buffers end with the footer."""
    vb = np.ascontiguousarray(values).tobytes()
    sb = (np.ascontiguousarray(scales, np.float32).tobytes()
          if scales is not None else b"")
    lb = (np.ascontiguousarray(labels, np.float64).tobytes()
          if labels is not None else b"")
    header = _HEADER.pack(MAGIC, VERSION, _DTYPE_CODES[dtype],
                          values.shape[0], values.shape[1], group,
                          len(vb), len(sb), len(lb))
    digest = _digest((header, vb, sb, lb))
    return [header, vb, sb, lb, _FOOTER.pack(digest)], digest


def _parse_shard(buf: bytes, path: str, *, verify: bool) -> Dict[str, object]:
    """Decode one shard file's bytes; raise `ShardCorruptionError` on any
    structural or digest mismatch (never return partially-trusted data)."""
    if len(buf) < HEADER_BYTES + FOOTER_BYTES:
        raise ShardCorruptionError(f"{path}: truncated ({len(buf)} bytes)")
    magic, version, code, rows, cols, group, nv, ns, nl = \
        _HEADER.unpack_from(buf)
    if magic != MAGIC or version != VERSION or code not in _DTYPE_NAMES:
        raise ShardCorruptionError(f"{path}: bad shard header")
    if len(buf) != HEADER_BYTES + nv + ns + nl + FOOTER_BYTES:
        raise ShardCorruptionError(
            f"{path}: size {len(buf)} does not match header sections")
    payload_end = HEADER_BYTES + nv + ns + nl
    if verify:
        (expect,) = _FOOTER.unpack_from(buf, payload_end)
        if _digest((buf[:payload_end],)) != expect:
            raise ShardCorruptionError(f"{path}: checksum mismatch")
    dtype = _DTYPE_NAMES[code]
    o = HEADER_BYTES
    values = np.frombuffer(buf, np.int8 if dtype == "int8" else np.float32,
                           count=rows * cols, offset=o).reshape(rows, cols)
    o += nv
    scales = (np.frombuffer(buf, np.float32, count=ns // 4, offset=o)
              .reshape(-1, 2) if ns else None)
    o += ns
    labels = (np.frombuffer(buf, np.float64, count=nl // 8, offset=o)
              if nl else None)
    return dict(values=values, scales=scales, labels=labels, rows=int(rows),
                cols=int(cols), dtype=dtype, group=int(group))


def source_fingerprint(path: str) -> Dict[str, object]:
    """Cheap content identity of an ingest source: size + head/tail digest.

    Deliberately mtime-free so copying the file around does not invalidate
    the shard store; a content edit anywhere near either end (LIBSVM appends
    and truncations included) changes it."""
    size = os.path.getsize(path)
    h = _hasher()
    with open(path, "rb") as f:
        h.update(f.read(1 << 20))
        if size > (1 << 20):
            f.seek(max(size - (1 << 20), 1 << 20))
            h.update(f.read(1 << 20))
    return {"size": int(size), "digest": f"{h.intdigest():016x}"}


class ShardWriter:
    """Buffers rows and emits fixed-size, checksummed shard files.

    All shards except the last hold exactly ``shard_rows`` rows, so shard i
    covers global rows [i*shard_rows, (i+1)*shard_rows) — the fixed
    row-block layout the (tile, B) staging paths rely on.  `finish` writes
    the manifest LAST (atomically): until it lands, the store does not exist
    as far as readers are concerned.
    """

    def __init__(self, directory: str, cols: int, *, shard_rows: int = 4096,
                 dtype: str = "f32", group: int = GROUP_ROWS,
                 kind: str = "dataset", with_labels: bool = False,
                 source: Optional[Dict[str, object]] = None,
                 extra: Optional[Dict[str, object]] = None,
                 stats: Optional[ShardStoreStats] = None, trace=None):
        if dtype not in _DTYPE_CODES:
            raise ValueError(f"shard dtype must be one of {SHARD_DTYPES}, "
                             f"got {dtype!r}")
        if shard_rows < 1 or shard_rows % GROUP_ROWS:
            # multiples of GROUP_ROWS keep int8 scale groups (and any future
            # re-encode of the same rows) global-row-aligned at shard starts
            raise ValueError(f"shard_rows must be a positive multiple of "
                             f"{GROUP_ROWS}, got {shard_rows}")
        self.directory = directory
        self.cols = int(cols)
        self.shard_rows = int(shard_rows)
        self.dtype = dtype
        self.group = int(group)
        self.kind = kind
        self.with_labels = with_labels
        self.source = source
        self.extra = dict(extra or {})
        self.stats = stats if stats is not None else ShardStoreStats()
        self.trace = resolve_tracer(trace)
        self._pending: List[np.ndarray] = []
        self._pending_labels: List[np.ndarray] = []
        self._buffered = 0
        self._shards: List[Dict[str, object]] = []
        self._n = 0
        self._finished = False
        os.makedirs(directory, exist_ok=True)
        # a re-ingest must never leave the OLD manifest validating NEW
        # shards: drop it before the first byte is rewritten
        try:
            os.remove(os.path.join(directory, MANIFEST_NAME))
        except FileNotFoundError:
            pass

    def append(self, rows: np.ndarray,
               labels: Optional[np.ndarray] = None) -> None:
        rows = np.asarray(rows, np.float32)
        if rows.ndim != 2 or rows.shape[1] != self.cols:
            raise ValueError(f"expected (r, {self.cols}) rows, "
                             f"got {rows.shape}")
        if self.with_labels:
            if labels is None or len(labels) != rows.shape[0]:
                raise ValueError("labels must accompany every row")
            self._pending_labels.append(np.asarray(labels, np.float64))
        self._pending.append(rows)
        self._buffered += rows.shape[0]
        while self._buffered >= self.shard_rows:
            self._emit(self.shard_rows)

    def _take(self, count: int) -> Tuple[np.ndarray, Optional[np.ndarray]]:
        rows = np.concatenate(self._pending) if len(self._pending) > 1 \
            else self._pending[0]
        labels = None
        if self.with_labels:
            labels = (np.concatenate(self._pending_labels)
                      if len(self._pending_labels) > 1
                      else self._pending_labels[0])
            self._pending_labels = ([labels[count:]]
                                    if count < len(labels) else [])
            labels = labels[:count]
        self._pending = [rows[count:]] if count < rows.shape[0] else []
        self._buffered -= count
        return rows[:count], labels

    def _emit(self, count: int) -> None:
        block, labels = self._take(count)
        i = len(self._shards)
        _fault_check("shard_write", shard=i)
        if self.dtype == "int8":
            values, scales = quantize_rows(block, self.group, symmetric=True)
        else:
            values, scales = block, None
        buffers, digest = _pack_shard(values, scales, labels, self.dtype,
                                      self.group)
        path = os.path.join(self.directory, shard_name(i))
        t0 = self.trace.begin()
        nbytes = _fsync_write(path, buffers)
        self.stats.write_seconds += self.trace.end(
            "disk", "shard_write", t0, shard=i, bytes=nbytes)
        self.stats.shards_written += 1
        self.stats.bytes_written += nbytes
        self._shards.append({"name": shard_name(i), "rows": int(count),
                             "digest": f"{digest:016x}",
                             "nbytes": int(nbytes)})
        self._n += count

    def finish(self) -> Dict[str, object]:
        """Flush the tail shard and atomically publish the manifest."""
        if self._finished:
            raise ShardError("ShardWriter.finish called twice")
        if self._buffered:
            self._emit(self._buffered)
        self._finished = True
        manifest = {
            "version": VERSION, "kind": self.kind, "hash": HASH_NAME,
            "n": int(self._n), "cols": self.cols,
            "shard_rows": self.shard_rows, "dtype": self.dtype,
            "group": self.group, "labels": self.with_labels,
            "shards": self._shards,
            "fingerprint": store_fingerprint(
                self._n, self.cols, self.dtype, self._shards),
        }
        if self.source is not None:
            manifest["source"] = self.source
        manifest.update(self.extra)
        _fsync_write(os.path.join(self.directory, MANIFEST_NAME),
                     [json.dumps(manifest, indent=1).encode()])
        # drop stale shard files from a previous, larger store in the same
        # directory (they are unreachable once the new manifest landed)
        for f in os.listdir(self.directory):
            if f.startswith("shard_") and f.endswith(".bin") \
                    and f not in {s["name"] for s in self._shards}:
                try:
                    os.remove(os.path.join(self.directory, f))
                except OSError:
                    pass
        return manifest


def store_fingerprint(n: int, cols: int, dtype: str,
                      shards: List[Dict[str, object]]) -> str:
    """Whole-store identity: digest of the dims + every shard's digest.

    Any mutation — different data, re-ingest with other params, a rebuilt
    store — changes it; `resilience.validate_snapshot` compares it (through
    `GShardView.g_fingerprint`) so ``--resume`` refuses a mutated store."""
    h = _hasher()
    h.update(f"{n}:{cols}:{dtype}".encode())
    for s in shards:
        h.update(str(s["digest"]).encode())
    return f"{h.intdigest():016x}"


class ShardStore:
    """Verified reader over a shard directory written by `ShardWriter`.

    Every disk read recomputes the footer digest (``verify=True``), retries
    transient IO errors with bounded exponential backoff (``retries`` /
    ``retry_backoff``; fail-fast callers pass ``retries=0``), and routes
    digest mismatches through quarantine + rebuild when a ``rebuilder`` —
    ``(lo, hi) -> (rows f32[, labels])`` over global row range — is
    attached.  Thread-safe: stage-2 farm engines gather rows concurrently.
    """

    def __init__(self, directory: str, *, verify: bool = True,
                 retries: int = 0, retry_backoff: float = 0.05,
                 rebuilder: Optional[Callable] = None,
                 cache_shards: int = 2,
                 stats: Optional[ShardStoreStats] = None, trace=None):
        self.directory = directory
        self.verify = verify
        self.retries = int(retries)
        self.retry_backoff = float(retry_backoff)
        self.rebuilder = rebuilder
        self.stats = stats if stats is not None else ShardStoreStats()
        self.trace = resolve_tracer(trace)
        self._lock = threading.RLock()
        self._cache: "OrderedDict[int, np.ndarray]" = OrderedDict()
        self._cache_shards = max(0, int(cache_shards))
        self._labels: Optional[np.ndarray] = None
        mpath = os.path.join(directory, MANIFEST_NAME)
        try:
            with open(mpath) as f:
                manifest = json.load(f)
        except FileNotFoundError:
            raise ShardError(
                f"no shard manifest at {mpath} — the store was never "
                f"completed (interrupted ingest?); re-ingest to rebuild it")
        except (OSError, json.JSONDecodeError) as exc:
            raise ShardError(f"unreadable shard manifest at {mpath}: {exc}")
        for key in ("n", "cols", "shard_rows", "dtype", "shards",
                    "fingerprint"):
            if key not in manifest:
                raise ShardError(f"{mpath}: manifest missing {key!r}")
        self.manifest = manifest
        missing = [s["name"] for s in manifest["shards"]
                   if not os.path.exists(os.path.join(directory, s["name"]))]
        if missing and rebuilder is None:
            raise ShardError(
                f"store at {directory} is missing {len(missing)} shard(s) "
                f"to rebuild: {', '.join(missing)}")

    # -- identity ------------------------------------------------------------
    @property
    def n(self) -> int:
        return int(self.manifest["n"])

    @property
    def cols(self) -> int:
        return int(self.manifest["cols"])

    @property
    def dtype(self) -> str:
        return str(self.manifest["dtype"])

    @property
    def group(self) -> int:
        return int(self.manifest.get("group", GROUP_ROWS))

    @property
    def shard_rows(self) -> int:
        return int(self.manifest["shard_rows"])

    @property
    def n_shards(self) -> int:
        return len(self.manifest["shards"])

    @property
    def fingerprint(self) -> str:
        return str(self.manifest["fingerprint"])

    def shard_range(self, i: int) -> Tuple[int, int]:
        lo = i * self.shard_rows
        return lo, min(lo + self.shard_rows, self.n)

    # -- verified read path --------------------------------------------------
    def _read_bytes(self, i: int, path: str) -> bytes:
        attempt = 0
        while True:
            try:
                _fault_check("shard_read", shard=i)
                _fault_check("shard_corrupt", shard=i, path=path)
                t0 = self.trace.begin()
                with open(path, "rb") as f:
                    buf = f.read()
                self.stats.read_seconds += self.trace.end(
                    "disk", "shard_read", t0, shard=i, bytes=len(buf))
                self.stats.shards_read += 1
                self.stats.bytes_read += len(buf)
                if attempt:
                    self.trace.instant("recovery", "shard_read_ok", shard=i,
                                       attempts=attempt + 1)
                return buf
            except FileNotFoundError:
                raise                       # not transient: route to rebuild
            except Exception as exc:
                retryable = (isinstance(exc, OSError)
                             or classify_error(exc) == "transient")
                if not retryable or attempt >= self.retries:
                    raise
                self.stats.retries += 1
                self.trace.instant("fault", "shard_read_retry", shard=i,
                                   error=type(exc).__name__)
                time.sleep(self.retry_backoff * (2 ** attempt))
                attempt += 1

    def _read_verified(self, i: int, entry: Dict[str, object],
                       path: str) -> Dict[str, object]:
        buf = self._read_bytes(i, path)
        if self.verify:
            self.stats.verifications += 1
        try:
            parsed = _parse_shard(buf, path, verify=self.verify)
        except ShardCorruptionError:
            if self.verify:
                self.stats.checksum_failures += 1
            raise
        lo, hi = self.shard_range(i)
        ok = (parsed["rows"] == hi - lo and parsed["cols"] == self.cols
              and parsed["dtype"] == self.dtype)
        if self.verify:
            ok = ok and f"{_digest((buf[:len(buf) - FOOTER_BYTES],)):016x}" \
                == entry["digest"]
        if not ok:
            # internally consistent but NOT the shard the manifest promised
            # (stale or foreign file swapped in) — same recovery as bit rot
            self.stats.checksum_failures += 1
            raise ShardCorruptionError(
                f"{path}: contents do not match the manifest entry")
        return parsed

    def _quarantine(self, i: int, path: str) -> None:
        qdir = os.path.join(self.directory, QUARANTINE_DIR)
        os.makedirs(qdir, exist_ok=True)
        try:
            os.replace(path, os.path.join(qdir, os.path.basename(path)))
        except FileNotFoundError:
            pass
        self.stats.quarantined += 1

    def _rebuild(self, i: int, entry: Dict[str, object], path: str) -> None:
        lo, hi = self.shard_range(i)
        out = self.rebuilder(lo, hi)
        rows, labels = out if isinstance(out, tuple) else (out, None)
        rows = np.asarray(rows, np.float32)
        if rows.shape != (hi - lo, self.cols):
            raise ShardError(f"rebuilder returned {rows.shape} for shard {i}"
                             f" (rows [{lo}, {hi}) of {self.cols} cols)")
        if self.dtype == "int8":
            values, scales = quantize_rows(rows, self.group, symmetric=True)
        else:
            values, scales = rows, None
        if self.manifest.get("labels") and labels is None:
            raise ShardError(f"rebuilder returned no labels for shard {i} "
                             f"of a labelled store")
        buffers, digest = _pack_shard(
            values, scales,
            np.asarray(labels, np.float64) if labels is not None else None,
            self.dtype, self.group)
        if f"{digest:016x}" != entry["digest"]:
            raise ShardError(
                f"rebuild of shard {i} does not reproduce the manifest "
                f"digest — the source changed since ingest; re-ingest "
                f"instead of resuming")
        nbytes = _fsync_write(path, buffers)
        self.stats.shards_written += 1
        self.stats.bytes_written += nbytes
        self.stats.rebuilt += 1
        self.trace.instant("recovery", "shard_rebuilt", shard=i)

    def _load(self, i: int) -> Dict[str, object]:
        """Parsed payload of shard i after verify / retry / rebuild."""
        entry = self.manifest["shards"][i]
        path = os.path.join(self.directory, str(entry["name"]))
        last: Optional[BaseException] = None
        for attempt in range(2):   # original read + one post-rebuild read
            try:
                return self._read_verified(i, entry, path)
            except FileNotFoundError as exc:
                last, reason = exc, "missing"
            except ShardCorruptionError as exc:
                last, reason = exc, "corrupt"
                self.trace.instant("fault", "shard_corrupt", shard=i,
                                   path=path)
                self._quarantine(i, path)
            if attempt or self.rebuilder is None:
                break
            self._rebuild(i, entry, path)
        raise ShardCorruptionError(
            f"shard {entry['name']} of {self.directory} is {reason}"
            + ("" if self.rebuilder is not None
               else " and no rebuilder is attached; rebuild it from source"
                    " or re-ingest")) from last

    # -- decoded access ------------------------------------------------------
    def _decoded(self, i: int) -> np.ndarray:
        """f32 rows of shard i, through a small LRU of decoded shards."""
        with self._lock:
            hit = self._cache.get(i)
            if hit is not None:
                self._cache.move_to_end(i)
                return hit
            parsed = self._load(i)
            if parsed["dtype"] == "int8":
                rows = dequantize_rows(parsed["values"], parsed["scales"],
                                       parsed["group"])
            else:
                rows = np.array(parsed["values"], np.float32)  # own the bytes
            if self._cache_shards:
                self._cache[i] = rows
                while len(self._cache) > self._cache_shards:
                    self._cache.popitem(last=False)
            return rows

    def _decoded_slice(self, i: int, a: int, b: int) -> np.ndarray:
        """f32 rows [a, b) local to shard i.  With the decoded cache off
        (``cache_shards=0``, the pure re-stream mode) only the requested
        range is dequantised (`quant.dequantize_rows_range`)."""
        with self._lock:
            hit = self._cache.get(i)
            if hit is not None:
                self._cache.move_to_end(i)
                return hit[a:b]
            if self._cache_shards:
                return self._decoded(i)[a:b]
            parsed = self._load(i)
            if parsed["dtype"] == "int8":
                return dequantize_rows_range(parsed["values"],
                                             parsed["scales"], a, b,
                                             parsed["group"])
            return np.array(parsed["values"][a:b], np.float32)

    def read_shard(self, i: int, *, wire: bool = False
                   ) -> Union[np.ndarray, QuantBlock]:
        """One shard's rows: decoded f32, or the stored `QuantBlock` codes
        (``wire=True``, int8 stores) for zero-recode streaming."""
        if wire:
            if self.dtype != "int8":
                raise ShardError("wire=True requires an int8 store")
            with self._lock:
                parsed = self._load(i)
            return QuantBlock(values=np.ascontiguousarray(parsed["values"]),
                              scales=np.ascontiguousarray(parsed["scales"],
                                                          np.float32),
                              group=parsed["group"])
        return self._decoded(i)

    def iter_blocks(self, *, wire: bool = False
                    ) -> Iterator[Union[np.ndarray, QuantBlock]]:
        """Per-shard blocks in row order — the epoch re-stream entry point
        for `stream_factor_blocks`."""
        for i in range(self.n_shards):
            yield self.read_shard(i, wire=wire)

    def read_rows(self, lo: int, hi: int) -> np.ndarray:
        """Contiguous f32 rows [lo, hi) across shard boundaries."""
        lo = max(0, lo)
        hi = min(self.n, hi)
        if hi <= lo:
            return np.empty((0, self.cols), np.float32)
        first, last = lo // self.shard_rows, (hi - 1) // self.shard_rows
        if first == last:
            base = first * self.shard_rows
            return self._decoded_slice(first, lo - base, hi - base)
        out = np.empty((hi - lo, self.cols), np.float32)
        for i in range(first, last + 1):
            s, e = self.shard_range(i)
            a, b = max(s, lo), min(e, hi)
            out[a - lo:b - lo] = self._decoded_slice(i, a - s, b - s)
        return out

    def gather_rows(self, rows) -> np.ndarray:
        """f32 gather of arbitrary global rows (landmark selection, the
        stage-2 active-set recompaction, fold validation sets)."""
        rows = np.asarray(rows)
        if rows.ndim == 0:
            rows = rows[None]
        rows = np.where(rows < 0, rows + self.n, rows).astype(np.int64)
        if rows.size and (rows.min() < 0 or rows.max() >= self.n):
            raise IndexError(f"row index out of range for n={self.n}")
        out = np.empty((len(rows), self.cols), np.float32)
        for i in np.unique(rows // self.shard_rows):
            lo, _ = self.shard_range(int(i))
            mask = (rows // self.shard_rows) == i
            out[mask] = self._decoded(int(i))[rows[mask] - lo]
        return out

    def labels(self) -> np.ndarray:
        """Concatenated per-shard label vectors (dataset stores)."""
        if not self.manifest.get("labels"):
            raise ShardError(f"store at {self.directory} carries no labels")
        with self._lock:
            if self._labels is None:
                parts = []
                for i in range(self.n_shards):
                    parsed = self._load(i)
                    if parsed["labels"] is None:
                        raise ShardCorruptionError(
                            f"shard {i} is missing its label section")
                    parts.append(parsed["labels"])
                self._labels = np.concatenate(parts)
            return self._labels

    def verify_all(self) -> List[int]:
        """Force-read every shard; returns the indices that needed rebuild
        (or raises naming the first unrecoverable one)."""
        before = self.stats.rebuilt
        for i in range(self.n_shards):
            with self._lock:
                self._load(i)
        return list(range(before, self.stats.rebuilt))


class GShardView:
    """Read-only 2-D array facade over an f32 G shard store.

    Quacks enough like the host-resident ``np.ndarray`` G that the streamed
    stage-2 stack — `iter_shared_blocks` tile slices, `_recompact` fancy
    gathers, `group_scales` wire tables, `predict_from_factor` matmuls —
    runs unchanged while every row served crosses a verified checksum.
    `resilience.g_fingerprint` picks up `g_fingerprint` (derived from the
    store manifest) so a `--resume` against a mutated store is refused.
    """

    is_shard_view = True

    def __init__(self, store: ShardStore):
        if store.dtype != "f32":
            raise ShardError("G spill shards must be f32 — stage-2 wire "
                             "parity across dtypes re-encodes from f32")
        self.store = store
        self.shape = (store.n, store.cols)
        self.dtype = np.dtype(np.float32)
        self.ndim = 2

    @property
    def nbytes(self) -> int:
        return self.shape[0] * self.shape[1] * 4

    @property
    def g_fingerprint(self) -> float:
        # top 52 bits of the manifest fingerprint: exact as a float64, and
        # any store mutation (different shard digests) changes it
        return float(int(self.store.fingerprint[:13], 16))

    @property
    def rebuilder(self):
        return self.store.rebuilder

    @rebuilder.setter
    def rebuilder(self, fn) -> None:
        self.store.rebuilder = fn

    def __len__(self) -> int:
        return self.shape[0]

    def __getitem__(self, key) -> np.ndarray:
        if isinstance(key, slice):
            lo, hi, step = key.indices(self.shape[0])
            if step != 1:
                return self.store.gather_rows(np.arange(lo, hi, step))
            return self.store.read_rows(lo, hi)
        if isinstance(key, (int, np.integer)):
            return self.store.gather_rows([int(key)])[0]
        return self.store.gather_rows(key)

    def __array__(self, dtype=None, copy=None) -> np.ndarray:
        # escape hatch for incidental consumers (prediction matmuls, the
        # monolithic route); the streamed paths never materialise the view
        out = self.store.read_rows(0, self.shape[0])
        return out if dtype is None else out.astype(dtype)

    def __matmul__(self, other):
        return np.asarray(self) @ other

    def group_scales(self, group: int = GROUP_ROWS, *,
                     symmetric: bool = False) -> np.ndarray:
        """Global-row-aligned (scale, zero) table, computed shard-wise —
        identical to `quant.group_scales` over the materialised G because
        shard boundaries are multiples of GROUP_ROWS (writer invariant)."""
        if group < 1 or self.store.shard_rows % group:
            return quant_group_scales(np.asarray(self), group,
                                      symmetric=symmetric)
        parts = [quant_group_scales(self.store.read_shard(i), group,
                                    symmetric=symmetric)
                 for i in range(self.store.n_shards)]
        return np.concatenate(parts) if parts else \
            np.zeros((0, 2), np.float32)


class ShardSpillSink:
    """Stage-1 ``out=`` target that spills streamed G row-chunks to shards.

    `stream_factor_blocks` drains chunks FIFO, so writes arrive as
    contiguous in-order slices; the sink re-blocks them into shard-sized
    pieces and `finish` returns the `GShardView` stage 2 reads back.
    """

    def __init__(self, directory: str, n: int, rank: int, *,
                 shard_rows: int = 4096,
                 stats: Optional[ShardStoreStats] = None, trace=None):
        self.shape = (n, rank)
        self.trace = trace
        self.stats = stats if stats is not None else ShardStoreStats()
        self._writer = ShardWriter(directory, rank, shard_rows=shard_rows,
                                   dtype="f32", kind="g", stats=self.stats,
                                   trace=trace)
        self.directory = directory
        self._next = 0

    def __setitem__(self, key, value) -> None:
        if not isinstance(key, slice) or key.step not in (None, 1):
            raise TypeError("spill sink only accepts contiguous row slices")
        lo, hi, _ = key.indices(self.shape[0])
        if lo != self._next:
            raise ShardError(f"spill writes must be in-order: got rows "
                             f"[{lo}, {hi}) after {self._next}")
        self._writer.append(np.asarray(value, np.float32))
        self._next = hi

    def finish(self, *, rebuilder: Optional[Callable] = None,
               verify: bool = True, retries: int = 0,
               retry_backoff: float = 0.05) -> GShardView:
        if self._next != self.shape[0]:
            raise ShardError(f"spill received {self._next} of "
                             f"{self.shape[0]} rows")
        self._writer.finish()
        store = ShardStore(self.directory, verify=verify, retries=retries,
                           retry_backoff=retry_backoff, rebuilder=rebuilder,
                           stats=self.stats, trace=self.trace)
        return GShardView(store)


# -- LIBSVM ingest (one parse, ever) ----------------------------------------

def ingest_libsvm_shards(path: str, directory: str, *,
                         n_features: Optional[int] = None,
                         shard_rows: int = 4096, dtype: str = "f32",
                         group: int = GROUP_ROWS, on_bad_row: str = "raise",
                         stats: Optional[ShardStoreStats] = None,
                         trace=None) -> ShardStore:
    """Parse a LIBSVM text file ONCE into a labelled shard store.

    With ``n_features`` given the parse is fully streaming
    (`read_libsvm_blocks` — the dense matrix never materialises); without
    it, one `read_libsvm` pass infers the width (still a single parse).
    The manifest records the row counts and the source fingerprint, so
    `open_or_ingest` re-runs skip the text entirely — closing the old
    double-parse (`count_libsvm_rows` + block reader) of text re-runs.
    """
    from repro.data.libsvm_format import (IngestStats, read_libsvm,
                                          read_libsvm_blocks)
    ing = IngestStats()
    src = source_fingerprint(path)
    extra = {"on_bad_row": on_bad_row, "source_path": os.path.abspath(path)}

    def _writer(cols):
        return ShardWriter(directory, cols, shard_rows=shard_rows,
                           dtype=dtype, group=group, kind="dataset",
                           with_labels=True, source=src, extra=extra,
                           stats=stats, trace=trace)

    if n_features:
        w = _writer(n_features)
        for dense, labels in read_libsvm_blocks(
                path, rows=shard_rows, n_features=n_features,
                on_bad_row=on_bad_row, stats=ing):
            w.append(dense, labels)
    else:
        data = read_libsvm(path, on_bad_row=on_bad_row, stats=ing)
        w = _writer(data.n_features)
        for dense, labels in data.iter_dense_blocks(shard_rows):
            w.append(dense, labels)
    w.extra = extra   # ensure counts below land in the manifest
    extra["rows_read"] = ing.rows_read
    extra["rows_skipped"] = ing.rows_skipped
    w.finish()
    store = ShardStore(directory, stats=stats, trace=trace)
    attach_source_rebuilder(store, path, on_bad_row=on_bad_row)
    return store


def attach_source_rebuilder(store: ShardStore, path: str, *,
                            on_bad_row: str = "raise") -> ShardStore:
    """Arm a dataset store to regenerate any shard by re-parsing its row
    range from the original LIBSVM text (bit-equal codes by construction:
    the codec is deterministic and scale groups are shard-aligned)."""
    from repro.data.libsvm_format import read_libsvm_rows_range

    cols = store.cols

    def rebuild(lo: int, hi: int):
        return read_libsvm_rows_range(path, lo, hi, cols,
                                      on_bad_row=on_bad_row)

    store.rebuilder = rebuild
    return store


def open_or_ingest(path: str, directory: str, *,
                   n_features: Optional[int] = None, shard_rows: int = 4096,
                   dtype: str = "f32", group: int = GROUP_ROWS,
                   on_bad_row: str = "raise", verify: bool = True,
                   retries: int = 0, retry_backoff: float = 0.05,
                   stats: Optional[ShardStoreStats] = None,
                   trace=None) -> Tuple[ShardStore, bool]:
    """Reuse a matching shard store, or ingest the text once to build it.

    Returns ``(store, ingested)``.  Reuse requires the manifest's recorded
    source fingerprint AND ingest parameters to match — anything else
    (edited text, different shard_rows/dtype/width) re-ingests, so a reused
    store is never silently wrong.  A reused run performs ZERO text parses:
    n, width, labels, and row counts all come from the manifest/shards.
    """
    try:
        store = ShardStore(directory, verify=verify, retries=retries,
                           retry_backoff=retry_backoff, stats=stats,
                           trace=trace)
        m = store.manifest
        if (m.get("kind") == "dataset" and m.get("labels")
                and m.get("source") == source_fingerprint(path)
                and store.shard_rows == shard_rows
                and store.dtype == dtype
                and (not n_features or store.cols == n_features)):
            attach_source_rebuilder(store, path, on_bad_row=on_bad_row)
            return store, False
    except ShardError:
        pass
    store = ingest_libsvm_shards(
        path, directory, n_features=n_features, shard_rows=shard_rows,
        dtype=dtype, group=group, on_bad_row=on_bad_row, stats=stats,
        trace=trace)
    store.verify = verify
    store.retries = int(retries)
    store.retry_backoff = float(retry_backoff)
    return store, True
