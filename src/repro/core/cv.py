"""Cross-validation, grid search, and warm starts (paper sec. 4 + Table 3).

The paper's point: parameter tuning is where the two-stage design pays off —
  * the factor G depends only on the kernel (gamma), NOT on C or the fold
    split, so one stage-1 run serves folds x C-grid x OVO-pairs solves;
  * "we simply fix the feature space representation once for the whole data
    set, pre-compute G, and only then sub-divide the data into folds";
  * "when searching a grid of growing values of C, we warm-start the solver
    from the optimal solution of the nearest value of C already completed".

All (pair x fold) tasks for one (gamma, C) cell are solved as ONE TaskBatch,
which is also what the sharded task farm consumes — the paper's "11,250 binary
SVMs ... far more parallelism than we need".
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dual_solver import SolverConfig, TaskBatch, solve_batch
from repro.core.kernel_fn import KernelParams, gram
from repro.core.nystrom import LowRankFactor, compute_factor, wait_for_factor
from repro.core.ovo import build_ovo_tasks, class_pairs, ovo_vote
from repro.core.polish import PolishSchedule, make_schedule, solve_polished
from repro.core.solver_stream import route_stage2, solve_streamed_auto
from repro.core.streaming import StreamConfig


def _solve_routed(factor: LowRankFactor, tasks: TaskBatch,
                  config: SolverConfig, solve_fn: Callable,
                  stream, stream_config: Optional[StreamConfig],
                  polish_schedule: Optional[PolishSchedule] = None):
    """Stage-2 dispatch (see `solver_stream.route_stage2`, shared with
    `LPDSVM._solve_stage2`); with a `polish_schedule` the cell runs the
    coarse-to-fine ladder (`core/polish.py`), composing with the C-grid warm
    start carried in `tasks.alpha0`."""
    if polish_schedule is not None:
        return solve_polished(factor, tasks, config, polish_schedule,
                              stream=stream, stream_config=stream_config,
                              solve_fn=solve_fn, gap_trace=False)
    if route_stage2(factor, tasks, stream, stream_config, solve_fn,
                    solve_batch):
        return solve_streamed_auto(factor.G, tasks, config,
                                   stream_config=stream_config)
    return solve_fn(factor.G, tasks, config)


def kfold_masks(n: int, k: int, seed: int = 0) -> List[np.ndarray]:
    """Return k boolean validation masks partitioning range(n)."""
    perm = np.random.default_rng(seed).permutation(n)
    masks = []
    for f in range(k):
        m = np.zeros(n, dtype=bool)
        m[perm[f::k]] = True
        masks.append(m)
    return masks


def build_cv_tasks(
    labels: np.ndarray,
    n_classes: int,
    C: float,
    val_masks: Sequence[np.ndarray],
    *,
    n_pad: Optional[int] = None,
    warm: Optional[jnp.ndarray] = None,
) -> Tuple[TaskBatch, list]:
    """Stack OVO tasks for every fold into one batch of T = folds * pairs.

    Task layout: fold-major (fold f, pair t) -> row f * n_pairs + t, so a warm
    start from a previous C value can be passed straight through as `warm`.
    """
    batches, pairs = [], None
    # Pad all folds to a common width so batches stack.
    if n_pad is None:
        counts = np.bincount(labels, minlength=n_classes)
        top2 = np.sort(counts)[-2:].sum()
        n_pad = -(-int(top2) // 8) * 8
    for vm in val_masks:
        tb, pairs = build_ovo_tasks(labels, n_classes, C,
                                    include_mask=~vm, n_pad=n_pad)
        batches.append(tb)
    tasks = TaskBatch(
        idx=jnp.concatenate([b.idx for b in batches]),
        y=jnp.concatenate([b.y for b in batches]),
        c=jnp.concatenate([b.c for b in batches]),
        alpha0=(jnp.clip(warm, 0.0, C) if warm is not None
                else jnp.concatenate([b.alpha0 for b in batches])),
    )
    return tasks, pairs


def _cv_error(factor: LowRankFactor, labels: np.ndarray, n_classes: int,
              W: jnp.ndarray, val_masks: Sequence[np.ndarray]) -> float:
    """Validation error using precomputed G rows as features (no kernel evals)."""
    pairs = class_pairs(n_classes)
    n_pairs = len(pairs)
    wrong = 0
    total = 0
    for f, vm in enumerate(val_masks):
        Wf = W[f * n_pairs:(f + 1) * n_pairs]
        dec = np.asarray(factor.G[np.where(vm)[0]] @ Wf.T)
        pred = (ovo_vote(dec, pairs, n_classes) if n_pairs > 1
                else np.where(dec[:, 0] > 0, 0, 1))
        wrong += int(np.sum(pred != labels[vm]))
        total += int(vm.sum())
    return wrong / max(total, 1)


@dataclasses.dataclass
class GridResult:
    errors: np.ndarray            # (n_gamma, n_C) CV error
    best_gamma: float
    best_C: float
    best_error: float
    stage1_seconds: float
    stage2_seconds: float
    n_binary_solved: int
    per_cell_seconds: np.ndarray  # (n_gamma, n_C)


def grid_search(
    x: np.ndarray,
    y: np.ndarray,
    gammas: Sequence[float],
    Cs: Sequence[float],
    *,
    budget: int = 500,
    folds: int = 5,
    kernel_kind: str = "rbf",
    config: SolverConfig = SolverConfig(),
    seed: int = 0,
    gram_fn: Callable = gram,
    solve_fn: Callable = solve_batch,
    warm_start: bool = True,
    warm_start_gamma: bool = False,
    stream: Optional[bool] = None,
    stream_config: Optional[StreamConfig] = None,
    polish: bool = False,
    polish_levels: int = 3,
    polish_schedule: Optional[PolishSchedule] = None,
) -> GridResult:
    """Full grid search with k-fold CV, G reuse per gamma, warm starts over C.

    Cs are solved in ascending order so each cell warm-starts from its
    predecessor (alphas clipped into the new box).

    ``warm_start_gamma`` (beyond-paper): also seed the first C of each new
    gamma from the previous gamma's alphas at the same C.  The dual variables
    stay feasible (same box, same task layout); only the geometry changed, so
    nearby gammas start close to optimal.  The paper warm-starts only across
    C (sec. 4).

    ``polish`` runs every cell through the coarse-to-fine ladder
    (`core/polish.py`); it composes with both warm-start axes — the carried
    alphas seed the ladder's coarse levels too — and selects the same cell
    (the error surface is unchanged, only the trajectory is cheaper).
    """
    x = np.asarray(x, np.float32)
    classes, labels = np.unique(np.asarray(y), return_inverse=True)
    n_classes = len(classes)
    val_masks = kfold_masks(x.shape[0], folds, seed)
    Cs = sorted(float(c) for c in Cs)
    if polish and polish_schedule is None:
        polish_schedule = make_schedule(levels=polish_levels)

    errors = np.zeros((len(gammas), len(Cs)))
    cell_sec = np.zeros_like(errors)
    t_stage1 = 0.0
    t_stage2 = 0.0
    n_solved = 0
    best = (np.inf, None, None)

    warm_first_c = None       # cross-gamma seed (beyond-paper)
    for gi, gamma in enumerate(gammas):
        kp = KernelParams(kind=kernel_kind, gamma=float(gamma))
        t0 = time.perf_counter()
        factor = compute_factor(x, kp, budget,
                                key=jax.random.PRNGKey(seed), gram_fn=gram_fn,
                                stream=stream, stream_config=stream_config)
        wait_for_factor(factor.G)
        t_stage1 += time.perf_counter() - t0

        warm = warm_first_c if warm_start_gamma else None
        for ci, C in enumerate(Cs):
            t0 = time.perf_counter()
            tasks, _ = build_cv_tasks(labels, n_classes, C, val_masks,
                                      warm=warm if warm_start else None)
            res = _solve_routed(factor, tasks, config, solve_fn,
                                stream, stream_config, polish_schedule)
            wait_for_factor(res.w)
            dt = time.perf_counter() - t0
            t_stage2 += dt
            cell_sec[gi, ci] = dt
            n_solved += tasks.n_tasks
            warm = res.alpha
            if ci == 0:
                warm_first_c = res.alpha
            err = _cv_error(factor, labels, n_classes, res.w, val_masks)
            errors[gi, ci] = err
            if err < best[0]:
                best = (err, float(gamma), C)

    return GridResult(
        errors=errors, best_gamma=best[1], best_C=best[2], best_error=best[0],
        stage1_seconds=t_stage1, stage2_seconds=t_stage2,
        n_binary_solved=n_solved, per_cell_seconds=cell_sec,
    )


def cross_validate(
    x: np.ndarray, y: np.ndarray, kernel: KernelParams, C: float, *,
    budget: int = 500, folds: int = 5, config: SolverConfig = SolverConfig(),
    seed: int = 0, gram_fn: Callable = gram, solve_fn: Callable = solve_batch,
    factor: Optional[LowRankFactor] = None,
    stream: Optional[bool] = None,
    stream_config: Optional[StreamConfig] = None,
    polish_schedule: Optional[PolishSchedule] = None,
) -> Tuple[float, LowRankFactor]:
    """k-fold CV error for one (kernel, C); returns (error, reusable factor)."""
    x = np.asarray(x, np.float32)
    _, labels = np.unique(np.asarray(y), return_inverse=True)
    n_classes = int(labels.max()) + 1
    if factor is None:
        factor = compute_factor(x, kernel, budget,
                                key=jax.random.PRNGKey(seed), gram_fn=gram_fn,
                                stream=stream, stream_config=stream_config)
    val_masks = kfold_masks(x.shape[0], folds, seed)
    tasks, _ = build_cv_tasks(labels, n_classes, float(C), val_masks)
    res = _solve_routed(factor, tasks, config, solve_fn, stream, stream_config,
                        polish_schedule)
    err = _cv_error(factor, labels, n_classes, res.w, val_masks)
    return err, factor
