"""Cross-validation, grid search, and warm starts (paper sec. 4 + Table 3).

The paper's point: parameter tuning is where the two-stage design pays off —
  * the factor G depends only on the kernel (gamma), NOT on C or the fold
    split, so one stage-1 run serves folds x C-grid x OVO-pairs solves;
  * "we simply fix the feature space representation once for the whole data
    set, pre-compute G, and only then sub-divide the data into folds";
  * "when searching a grid of growing values of C, we warm-start the solver
    from the optimal solution of the nearest value of C already completed".

All (pair x fold) tasks for one (gamma, C) cell are solved as ONE TaskBatch,
which is also what the sharded task farm consumes — the paper's "11,250 binary
SVMs ... far more parallelism than we need".
"""
from __future__ import annotations

import dataclasses
import os
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dual_solver import SolverConfig, TaskBatch, solve_batch
from repro.core.kernel_fn import KernelParams, gram
from repro.core.nystrom import LowRankFactor, compute_factor, wait_for_factor
from repro.core.ovo import build_ovo_tasks, class_pairs, ovo_vote
from repro.core.polish import PolishSchedule, make_schedule, solve_polished
from repro.core.solver_stream import route_stage2, solve_streamed_auto
from repro.core.streaming import StreamConfig
from repro.core.trace import resolve as resolve_tracer


def _solve_routed(factor: LowRankFactor, tasks: TaskBatch,
                  config: SolverConfig, solve_fn: Callable,
                  stream, stream_config: Optional[StreamConfig],
                  polish_schedule: Optional[PolishSchedule] = None):
    """Stage-2 dispatch (see `solver_stream.route_stage2`, shared with
    `LPDSVM._solve_stage2`); with a `polish_schedule` the cell runs the
    coarse-to-fine ladder (`core/polish.py`), composing with the C-grid warm
    start carried in `tasks.alpha0`."""
    if polish_schedule is not None:
        return solve_polished(factor, tasks, config, polish_schedule,
                              stream=stream, stream_config=stream_config,
                              solve_fn=solve_fn, gap_trace=False)
    if route_stage2(factor, tasks, stream, stream_config, solve_fn,
                    solve_batch):
        return solve_streamed_auto(factor.G, tasks, config,
                                   stream_config=stream_config)
    return solve_fn(factor.G, tasks, config)


def kfold_masks(n: int, k: int, seed: int = 0) -> List[np.ndarray]:
    """Return k boolean validation masks partitioning range(n)."""
    perm = np.random.default_rng(seed).permutation(n)
    masks = []
    for f in range(k):
        m = np.zeros(n, dtype=bool)
        m[perm[f::k]] = True
        masks.append(m)
    return masks


def build_cv_tasks(
    labels: np.ndarray,
    n_classes: int,
    C: float,
    val_masks: Sequence[np.ndarray],
    *,
    n_pad: Optional[int] = None,
    warm: Optional[jnp.ndarray] = None,
) -> Tuple[TaskBatch, list]:
    """Stack OVO tasks for every fold into one batch of T = folds * pairs.

    Task layout: fold-major (fold f, pair t) -> row f * n_pairs + t, so a warm
    start from a previous C value can be passed straight through as `warm`.
    """
    batches, pairs = [], None
    # Pad all folds to a common width so batches stack.
    if n_pad is None:
        counts = np.bincount(labels, minlength=n_classes)
        top2 = np.sort(counts)[-2:].sum()
        n_pad = -(-int(top2) // 8) * 8
    for vm in val_masks:
        tb, pairs = build_ovo_tasks(labels, n_classes, C,
                                    include_mask=~vm, n_pad=n_pad)
        batches.append(tb)
    tasks = TaskBatch(
        idx=jnp.concatenate([b.idx for b in batches]),
        y=jnp.concatenate([b.y for b in batches]),
        c=jnp.concatenate([b.c for b in batches]),
        alpha0=(jnp.clip(warm, 0.0, C) if warm is not None
                else jnp.concatenate([b.alpha0 for b in batches])),
    )
    return tasks, pairs


def _fold_val_sets(factor: LowRankFactor, labels: np.ndarray,
                   val_masks: Sequence[np.ndarray]) -> List[tuple]:
    """Hoisted per-fold validation features: the `np.where(vm)[0]` index and
    the G validation-row gather are computed ONCE per gamma here instead of
    once per (gamma, C) cell inside the C loop."""
    return [(factor.G[np.where(vm)[0]], labels[vm]) for vm in val_masks]


def _cv_error_from(val_sets: Sequence[tuple], n_classes: int,
                   W: jnp.ndarray) -> float:
    """Validation error of one (gamma, C) cell from pre-gathered fold sets."""
    pairs = class_pairs(n_classes)
    n_pairs = len(pairs)
    wrong = 0
    total = 0
    for f, (Gv, yv) in enumerate(val_sets):
        Wf = W[f * n_pairs:(f + 1) * n_pairs]
        dec = np.asarray(Gv @ Wf.T)
        pred = (ovo_vote(dec, pairs, n_classes) if n_pairs > 1
                else np.where(dec[:, 0] > 0, 0, 1))
        wrong += int(np.sum(pred != yv))
        total += len(yv)
    return wrong / max(total, 1)


def _cv_error(factor: LowRankFactor, labels: np.ndarray, n_classes: int,
              W: jnp.ndarray, val_masks: Sequence[np.ndarray]) -> float:
    """Validation error using precomputed G rows as features (no kernel evals)."""
    return _cv_error_from(_fold_val_sets(factor, labels, val_masks),
                          n_classes, W)


def build_cv_grid_tasks(
    labels: np.ndarray,
    n_classes: int,
    Cs: Sequence[float],
    val_masks: Sequence[np.ndarray],
    *,
    n_pad: Optional[int] = None,
    warm: Optional[jnp.ndarray] = None,
    ladder: bool = True,
) -> Tuple[TaskBatch, list, Optional[np.ndarray]]:
    """One TaskBatch carrying EVERY (C, fold, pair) cell of a gamma.

    Level-major layout on top of `build_cv_tasks`' fold-major one: cell
    (ci, f, t) is task  u = ci * folds * n_pairs + f * n_pairs + t,  so
    slicing ``ci * FP:(ci + 1) * FP`` (FP = folds * n_pairs) recovers one
    C value's batch in exactly the per-cell layout.

    ``Cs`` must be ascending.  With ``ladder=True`` the returned
    ``chain_next`` declares each cell the warm-start predecessor of the same
    (fold, pair) cell at the next C — the paper's C-ladder warm start,
    executed inside the streamed engine (`solver_stream`) so the whole grid
    trains in one G stream.  ``warm`` seeds level 0 (cross-gamma warm
    start), clipped into the first C box by `build_cv_tasks`.
    """
    Cs = [float(C) for C in Cs]
    if sorted(Cs) != Cs:
        raise ValueError("build_cv_grid_tasks requires ascending Cs")
    if n_pad is None:
        counts = np.bincount(labels, minlength=n_classes)
        top2 = np.sort(counts)[-2:].sum()
        n_pad = -(-int(top2) // 8) * 8
    levels, pairs = [], None
    for ci, C in enumerate(Cs):
        tb, pairs = build_cv_tasks(labels, n_classes, C, val_masks,
                                   n_pad=n_pad,
                                   warm=warm if ci == 0 else None)
        levels.append(tb)
    tasks = TaskBatch(
        idx=jnp.concatenate([b.idx for b in levels]),
        y=jnp.concatenate([b.y for b in levels]),
        c=jnp.concatenate([b.c for b in levels]),
        alpha0=jnp.concatenate([b.alpha0 for b in levels]),
    )
    chain = None
    FP = len(val_masks) * len(pairs)
    if ladder and len(Cs) > 1:
        chain = np.full((len(Cs) * FP,), -1, np.int64)
        chain[:(len(Cs) - 1) * FP] = np.arange((len(Cs) - 1) * FP) + FP
    return tasks, pairs, chain


@dataclasses.dataclass
class GridResult:
    errors: np.ndarray            # (n_gamma, n_C) CV error
    best_gamma: float
    best_C: float
    best_error: float
    stage1_seconds: float
    stage2_seconds: float
    n_binary_solved: int
    per_cell_seconds: np.ndarray  # (n_gamma, n_C)
    stream_stats: Optional[list] = None
    # ^ farm path: one Stage2StreamStats per gamma — the whole (C x folds)
    #   grid of that gamma trained in the one stream it records, so "one
    #   pass set per grid" is assertable, not just timed
    bytes_h2d: Optional[np.ndarray] = None   # (n_gamma,) farm H2D bytes


def grid_search(
    x: np.ndarray,
    y: np.ndarray,
    gammas: Sequence[float],
    Cs: Sequence[float],
    *,
    budget: int = 500,
    folds: int = 5,
    kernel_kind: str = "rbf",
    config: SolverConfig = SolverConfig(),
    seed: int = 0,
    gram_fn: Callable = gram,
    solve_fn: Callable = solve_batch,
    warm_start: bool = True,
    warm_start_gamma: bool = False,
    stream: Optional[bool] = None,
    stream_config: Optional[StreamConfig] = None,
    polish: bool = False,
    polish_levels: int = 3,
    polish_schedule: Optional[PolishSchedule] = None,
    farm: Optional[bool] = None,
) -> GridResult:
    """Full grid search with k-fold CV, G reuse per gamma, warm starts over C.

    Cs are solved in ascending order so each cell warm-starts from its
    predecessor (alphas clipped into the new box).

    ``farm`` selects the grid TASK FARM: every (C, fold, pair) cell of a
    gamma rides ONE streamed TaskBatch (`build_cv_grid_tasks`) with the
    C-ladder warm starts executed inside the engine (`chain_next`), so each
    streamed G block updates every live grid cell before eviction and the
    grid costs ~one training pass of H2D instead of |Cs| pass sets.  The
    default (``None``) routes onto the farm exactly when the cells would
    stream anyway (`route_stage2`) and no polish ladder is requested;
    ``True`` forces it, ``False`` pins the per-cell serial loop.

    ``warm_start_gamma`` (beyond-paper): also seed the first C of each new
    gamma from the previous gamma's alphas at the same C.  The dual variables
    stay feasible (same box, same task layout); only the geometry changed, so
    nearby gammas start close to optimal.  The paper warm-starts only across
    C (sec. 4).

    ``polish`` runs every cell through the coarse-to-fine ladder
    (`core/polish.py`); it composes with both warm-start axes — the carried
    alphas seed the ladder's coarse levels too — and selects the same cell
    (the error surface is unchanged, only the trajectory is cheaper).
    """
    x = np.asarray(x, np.float32)
    classes, labels = np.unique(np.asarray(y), return_inverse=True)
    n_classes = len(classes)
    val_masks = kfold_masks(x.shape[0], folds, seed)
    Cs = sorted(float(c) for c in Cs)
    if polish and polish_schedule is None:
        polish_schedule = make_schedule(levels=polish_levels)

    errors = np.zeros((len(gammas), len(Cs)))
    cell_sec = np.zeros_like(errors)
    t_stage1 = 0.0
    t_stage2 = 0.0
    n_solved = 0
    best = (np.inf, None, None)
    gamma_stats: List = [None] * len(gammas)
    gamma_bytes = np.zeros((len(gammas),), np.int64)

    tr = resolve_tracer(getattr(stream_config, "trace", None))
    warm_first_c = None       # cross-gamma seed (beyond-paper)
    for gi, gamma in enumerate(gammas):
        kp = KernelParams(kind=kernel_kind, gamma=float(gamma))
        # Each gamma is its own resumable unit: G and the solver state both
        # depend on gamma, so checkpoints — and spilled-G shard stores,
        # whose contents are a function of gamma — live in per-gamma
        # subdirs (the snapshot's G fingerprint rejects any cross-gamma
        # mixup anyway).
        g_cfg = stream_config
        ck = getattr(stream_config, "checkpoint_dir", None)
        sd = getattr(stream_config, "shard_dir", None)
        if ck or sd:
            g_cfg = dataclasses.replace(
                stream_config,
                checkpoint_dir=os.path.join(ck, f"gamma{gi}") if ck else None,
                shard_dir=os.path.join(sd, f"gamma{gi}") if sd else None)
        t0 = tr.begin()
        factor = compute_factor(x, kp, budget,
                                key=jax.random.PRNGKey(seed), gram_fn=gram_fn,
                                stream=stream, stream_config=g_cfg)
        wait_for_factor(factor.G)
        t_stage1 += tr.end("cv", "stage1_factor", t0, gamma=float(gamma))

        warm = warm_first_c if warm_start_gamma else None
        use_farm = False
        if farm is not False and polish_schedule is None and len(Cs) > 1:
            gtasks, pairs, chain = build_cv_grid_tasks(
                labels, n_classes, Cs, val_masks,
                warm=warm if warm_start else None,
                ladder=warm_start)
            use_farm = (farm is True
                        or route_stage2(factor, gtasks, stream, stream_config,
                                        solve_fn, solve_batch))
        if use_farm:
            # Grid task farm: one streamed solve trains every (C, fold,
            # pair) cell of this gamma — the C-ladder runs inside the
            # engine, so the epoch budget covers the whole ladder (the +1
            # per level pays each seeded cell's w0-accumulation pass).
            t0 = tr.begin()
            FP = folds * len(pairs)
            farm_cfg = dataclasses.replace(
                config, max_epochs=config.max_epochs * len(Cs) + len(Cs))
            res, sstats = solve_streamed_auto(
                factor.G, gtasks, farm_cfg, stream_config=g_cfg,
                chain_next=chain, return_stats=True)
            wait_for_factor(res.w)
            dt = tr.end("cv", "grid_farm", t0, gamma=float(gamma),
                        cells=gtasks.n_tasks)
            t_stage2 += dt
            cell_sec[gi, :] = dt / len(Cs)
            n_solved += gtasks.n_tasks
            gamma_stats[gi] = sstats
            gamma_bytes[gi] = sstats.bytes_h2d
            val_sets = _fold_val_sets(factor, labels, val_masks)
            W = np.asarray(res.w)
            for ci, C in enumerate(Cs):
                err = _cv_error_from(val_sets, n_classes,
                                     W[ci * FP:(ci + 1) * FP])
                errors[gi, ci] = err
                if err < best[0]:
                    best = (err, float(gamma), C)
            warm_first_c = np.asarray(res.alpha)[:FP]
            continue

        val_sets = _fold_val_sets(factor, labels, val_masks)
        for ci, C in enumerate(Cs):
            t0 = tr.begin()
            tasks, _ = build_cv_tasks(labels, n_classes, C, val_masks,
                                      warm=warm if warm_start else None)
            c_cfg = g_cfg
            if getattr(g_cfg, "checkpoint_dir", None):  # checkpointing: each C
                c_cfg = dataclasses.replace(  # cell is its own resumable unit
                    g_cfg, checkpoint_dir=os.path.join(g_cfg.checkpoint_dir,
                                                       f"c{ci}"))
            res = _solve_routed(factor, tasks, config, solve_fn,
                                stream, c_cfg, polish_schedule)
            wait_for_factor(res.w)
            dt = tr.end("cv", "grid_cell", t0, gamma=float(gamma),
                        C=float(C))
            t_stage2 += dt
            cell_sec[gi, ci] = dt
            n_solved += tasks.n_tasks
            warm = res.alpha
            if ci == 0:
                warm_first_c = res.alpha
            err = _cv_error_from(val_sets, n_classes, res.w)
            errors[gi, ci] = err
            if err < best[0]:
                best = (err, float(gamma), C)

    farmed = any(s is not None for s in gamma_stats)
    return GridResult(
        errors=errors, best_gamma=best[1], best_C=best[2], best_error=best[0],
        stage1_seconds=t_stage1, stage2_seconds=t_stage2,
        n_binary_solved=n_solved, per_cell_seconds=cell_sec,
        stream_stats=gamma_stats if farmed else None,
        bytes_h2d=gamma_bytes if farmed else None,
    )


def cross_validate(
    x: np.ndarray, y: np.ndarray, kernel: KernelParams, C: float, *,
    budget: int = 500, folds: int = 5, config: SolverConfig = SolverConfig(),
    seed: int = 0, gram_fn: Callable = gram, solve_fn: Callable = solve_batch,
    factor: Optional[LowRankFactor] = None,
    stream: Optional[bool] = None,
    stream_config: Optional[StreamConfig] = None,
    polish_schedule: Optional[PolishSchedule] = None,
) -> Tuple[float, LowRankFactor]:
    """k-fold CV error for one (kernel, C); returns (error, reusable factor)."""
    x = np.asarray(x, np.float32)
    _, labels = np.unique(np.asarray(y), return_inverse=True)
    n_classes = int(labels.max()) + 1
    if factor is None:
        factor = compute_factor(x, kernel, budget,
                                key=jax.random.PRNGKey(seed), gram_fn=gram_fn,
                                stream=stream, stream_config=stream_config)
    val_masks = kfold_masks(x.shape[0], folds, seed)
    tasks, _ = build_cv_tasks(labels, n_classes, float(C), val_masks)
    res = _solve_routed(factor, tasks, config, solve_fn, stream, stream_config,
                        polish_schedule)
    err = _cv_error(factor, labels, n_classes, res.w, val_masks)
    return err, factor
