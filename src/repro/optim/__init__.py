"""Optimizers implemented in pure JAX (no optax dependency).

AdamW for the standard archs; Adafactor (factored second moments) for the
trillion-parameter MoE configs where fp32 Adam states would not fit per-chip
HBM on the production mesh (see DESIGN.md §Distribution); SGD+momentum for
smoke tests.  All follow the (init_fn, update_fn) pytree convention and are
scan/jit/shard-transparent (states inherit the parameter shardings).
"""
from repro.optim.optimizers import (OptState, adamw, adafactor, sgd,
                                    cosine_schedule, get_optimizer)

__all__ = ["OptState", "adamw", "adafactor", "sgd", "cosine_schedule",
           "get_optimizer"]
