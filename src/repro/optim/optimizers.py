"""(init, update) optimizer pairs over arbitrary parameter pytrees."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jnp.ndarray
    inner: Any


class Optimizer(NamedTuple):
    init: Callable[[Any], OptState]
    update: Callable[[Any, OptState, Any], Tuple[Any, OptState]]
    name: str


def cosine_schedule(base_lr: float, warmup: int, total: int) -> Callable:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)
    return lr


def adamw(lr=1e-3, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1,
          schedule: Callable = None) -> Optimizer:
    lr_fn = schedule if schedule is not None else (lambda s: jnp.float32(lr))

    def init(params):
        zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
        return OptState(jnp.zeros((), jnp.int32), (zeros, jax.tree.map(jnp.copy, zeros)))

    def update(grads, state, params):
        step = state.step + 1
        m, v = state.inner
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32), m, grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)), v, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr_t = lr_fn(step)

        def upd(p, m_, v_):
            mhat = m_ / bc1
            vhat = v_ / bc2
            return (p - lr_t * (mhat / (jnp.sqrt(vhat) + eps)
                                + weight_decay * p.astype(jnp.float32))).astype(p.dtype)

        return jax.tree.map(upd, params, m, v), OptState(step, (m, v))

    return Optimizer(init, update, "adamw")


def adafactor(lr=1e-3, decay=0.8, eps=1e-30, clip_threshold=1.0,
              weight_decay=0.0, schedule: Callable = None) -> Optimizer:
    """Factored second moments: O(r + c) state for (r, c) matrices.

    Used for the >=200B MoE configs: fp32 Adam m+v for kimi-k2 (1T params)
    would need ~16 GB/chip on the 512-chip mesh — adafactor's factored state
    is ~1/10^3 of that for the expert matrices.
    """
    lr_fn = schedule if schedule is not None else (lambda s: jnp.float32(lr))

    def _factored(p):
        return p.ndim >= 2

    def init(params):
        def leaf(p):
            if _factored(p):
                return (jnp.zeros(p.shape[:-1], jnp.float32),       # row
                        jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32))  # col
            return jnp.zeros_like(p, dtype=jnp.float32)
        return OptState(jnp.zeros((), jnp.int32), jax.tree.map(leaf, params))

    def update(grads, state, params):
        step = state.step + 1
        beta = 1.0 - step.astype(jnp.float32) ** (-decay)
        lr_t = lr_fn(step)

        def upd(p, g, s):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if _factored(p):
                vr, vc = s
                vr = beta * vr + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * vc + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = jnp.sqrt(
                    vr[..., :, None] * vc[..., None, :]
                    / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True)[..., None], eps))
                u = g / jnp.maximum(denom, eps)
                new_s = (vr, vc)
            else:
                v = beta * s + (1 - beta) * g2
                u = g / jnp.sqrt(v + eps)
                new_s = v
            # update clipping (RMS <= clip_threshold)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + eps)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            newp = p - lr_t * u - lr_t * weight_decay * p.astype(jnp.float32)
            return newp.astype(p.dtype), new_s

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_s = treedef.flatten_up_to(state.inner)
        out = [upd(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
        new_params = treedef.unflatten([o[0] for o in out])
        new_inner = treedef.unflatten([o[1] for o in out])
        return new_params, OptState(step, new_inner)

    return Optimizer(init, update, "adafactor")


def sgd(lr=1e-2, momentum=0.9, schedule: Callable = None) -> Optimizer:
    lr_fn = schedule if schedule is not None else (lambda s: jnp.float32(lr))

    def init(params):
        return OptState(jnp.zeros((), jnp.int32),
                        jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params))

    def update(grads, state, params):
        step = state.step + 1
        vel = jax.tree.map(lambda v, g: momentum * v + g.astype(jnp.float32),
                           state.inner, grads)
        lr_t = lr_fn(step)
        params = jax.tree.map(lambda p, v: (p - lr_t * v).astype(p.dtype), params, vel)
        return params, OptState(step, vel)

    return Optimizer(init, update, "sgd")


def get_optimizer(name: str, lr: float = 1e-3, **kw) -> Optimizer:
    if name == "adamw":
        return adamw(lr=lr, **kw)
    if name == "adafactor":
        return adafactor(lr=lr, **kw)
    if name == "sgd":
        return sgd(lr=lr, **kw)
    raise ValueError(f"unknown optimizer {name!r}")
