"""Pallas TPU kernel: causal flash attention (backbone hot spot).

The jnp two-level-chunked attention in `models/attention.py` is the
memory-correct formulation the dry-run lowers; THIS kernel is its TPU-native
form: one (bq, D) query tile stays resident while (bk, D) key/value tiles
stream HBM -> VMEM, with the online-softmax running max / normalizer / output
accumulator in VMEM scratch across the sequential kv grid dimension.

Layout: inputs are (BH, S, D) — batch x heads flattened into the first grid
axis (fully parallel), query blocks on the second (parallel), kv blocks on
the third (sequential/"arbitrary" so scratch carries state).  The causal mask
is computed from program ids; fully-masked kv tiles still execute (masked) —
the MXU cost of skipped tiles is the documented gap vs a production kernel
with block-sparse grid pruning.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.compat import tpu_compiler_params

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s, *,
                  bq: int, bk: int, n_kv: int, scale: float, causal: bool):
    j = pl.program_id(2)
    i = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q = q_ref[0]                                    # (bq, D)
    k = k_ref[0]                                    # (bk, D)
    v = v_ref[0]                                    # (bk, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if causal:
        rows = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(rows >= cols, s, NEG_INF)

    m_prev = m_s[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_s[...] = l_s[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_s[...] = acc_s[...] * alpha + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_s[...] = m_new

    @pl.when(j == n_kv - 1)
    def _fini():
        o_ref[0] = (acc_s[...] / jnp.maximum(l_s[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention_pallas(q, k, v, *, causal: bool = True, bq: int = 256,
                           bk: int = 256, interpret: bool = False):
    """q/k/v (BH, S, D), S divisible by bq and bk.  Returns (BH, S, D)."""
    BH, S, D = q.shape
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    n_q, n_kv = S // bq, S // bk
    scale = 1.0 / float(D) ** 0.5
    kernel = functools.partial(_flash_kernel, bq=bq, bk=bk, n_kv=n_kv,
                               scale=scale, causal=causal)
    return pl.pallas_call(
        kernel,
        grid=(BH, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, D), lambda h, i, j: (h, j, 0)),
            pl.BlockSpec((1, bk, D), lambda h, i, j: (h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),       # running max
            pltpu.VMEM((bq, 1), jnp.float32),       # normalizer
            pltpu.VMEM((bq, D), jnp.float32),       # output accumulator
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
