"""Pure-jnp oracles for the Pallas kernels (allclose targets in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.dual_solver import epoch_ref
from repro.core.kernel_fn import KernelParams, gram as _gram_ref


def gram_ref(x: jnp.ndarray, z: jnp.ndarray, params: KernelParams) -> jnp.ndarray:
    """Oracle for kernels/gram.py — the stage-1 batch kernel matrix."""
    return _gram_ref(x, z, params)


def gram_q8_ref(values: jnp.ndarray, scales: jnp.ndarray, z: jnp.ndarray,
                params: KernelParams, *, group: int = 32) -> jnp.ndarray:
    """Oracle for the int8-wire gram path (`gram_pallas_q8` /
    `kernels.ops.gram_q8`): dequantise the (n, p) int8 values with the
    compact (ng, 2) scale table (`core/quant.py` codec), then the fp32
    reference kernel.  Off-TPU this IS the streamed q8 gram (interpret-mode
    Pallas is pure overhead on CPU); the wire savings are identical — only
    the int8 values + scales cross the host->device boundary."""
    from repro.core.quant import dequant_rows
    return _gram_ref(dequant_rows(values, scales, group), z, params)


def flash_attention_ref(q, k, v, *, causal: bool = True):
    """Oracle for kernels/flash_attention.py.  q/k/v (BH, S, D)."""
    BH, S, D = q.shape
    s = jnp.einsum("hqd,hkd->hqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(D).astype(jnp.float32)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("hqk,hkd->hqd", p, v.astype(jnp.float32)).astype(q.dtype)


def smo_epoch_ref(G, y, c, q, alpha, unchanged, w, *, full_pass: bool,
                  shrink_k: int = 5):
    """Oracle for kernels/smo.py — identical sequential semantics.

    Same column-vector shapes as the kernel: y/c/q/alpha (n, 1), w (1, B).
    Returns (alpha (n,1), unchanged (n,1), w (1,B), viol (1,1)).
    """
    n = G.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    a, wv, u, viol = epoch_ref(
        G, idx, y[:, 0], c[:, 0], q[:, 0], alpha[:, 0], w[0], unchanged[:, 0],
        shrink_k, jnp.bool_(full_pass))
    return (a[:, None], u[:, None], wv[None, :],
            jnp.asarray(viol, jnp.float32).reshape(1, 1))
