"""Pallas TPU kernel: one SMO (dual coordinate ascent) epoch.

Stage-2 hot spot.  The paper's GPU design keeps the weight vector w in the
fast scratchpad memory of a SINGLE streaming multiprocessor, because "the SMO
loop is memory-bound, not compute-bound (it is dominated by computing inner
products of vectors of dimension B)" and cross-SM communication would kill the
multi-million-steps-per-second loop.  TPU adaptation of the same insight:

  * w (1, B) lives in a VMEM scratch buffer that persists across the
    sequential grid — the TPU analogue of the SM scratchpad;
  * G is streamed HBM -> VMEM one (tn, B) row tile per grid step; every row is
    visited once per epoch (round-robin order, as in the paper);
  * the truncated-Newton coordinate update runs in a lax.fori_loop INSIDE the
    kernel: dot(w, g_i) is a VPU reduction over B lanes; there is no MXU work,
    which is exactly why this kernel's roofline is memory-bound (see
    EXPERIMENTS.md §Roofline, SVM rows);
  * shrinking is carried in an int32 "unchanged-touch counter" per variable;
    full passes (every 20th epoch, the paper's eta ~ 5% re-check budget) are a
    separate compile of the same kernel with full_pass=True.

The epoch-level bucket compaction that turns shrinking into actual time
savings (paper: "the memory demand for the relevant sub-matrix of G reduces")
lives in `repro/core/compact.py` — it shrinks n_pad between epochs, which
shrinks this kernel's HBM traffic proportionally.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.compat import tpu_compiler_params

Q_FLOOR = 1e-12


def _smo_kernel(g_ref, y_ref, c_ref, q_ref, alpha_ref, unch_ref, w_ref,
                alpha_out, unch_out, w_out, viol_out,
                w_s, viol_s, *, tn: int, n_blocks: int,
                full_pass: bool, shrink_k: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        w_s[...] = w_ref[...]
        viol_s[0, 0] = 0.0

    # copy this tile's alpha / counters into the output block, update in place
    alpha_out[...] = alpha_ref[...]
    unch_out[...] = unch_ref[...]

    def body(r, viol):
        row = g_ref[pl.ds(r, 1), :]                    # (1, B)
        a = alpha_out[pl.ds(r, 1), :]                  # (1, 1)
        y = y_ref[pl.ds(r, 1), :]
        c = c_ref[pl.ds(r, 1), :]
        q = q_ref[pl.ds(r, 1), :]
        u = unch_out[pl.ds(r, 1), :]

        w = w_s[...]                                   # (1, B)
        margin = jnp.sum(w * row, axis=1, keepdims=True)   # (1, 1) VPU reduce
        g = 1.0 - y * margin
        real = c > 0.0
        if full_pass:
            active = real
        else:
            active = jnp.logical_and(real, u < shrink_k)

        at_lo = a <= 0.0
        at_hi = a >= c
        pg = jnp.where(at_lo, jnp.maximum(g, 0.0),
                       jnp.where(at_hi, jnp.minimum(g, 0.0), g))
        a_new = jnp.clip(a + g / jnp.maximum(q, Q_FLOOR), 0.0, c)
        a_new = jnp.where(active, a_new, a)
        delta = a_new - a

        w_s[...] = w + (delta * y) * row               # rank-1 w update
        alpha_out[pl.ds(r, 1), :] = a_new
        changed = jnp.abs(delta) > 0.0
        u_new = jnp.where(changed, 0, u + 1)
        unch_out[pl.ds(r, 1), :] = jnp.where(active, u_new, u)
        viol_i = jnp.where(active, jnp.abs(pg), 0.0)[0, 0]
        return jnp.maximum(viol, viol_i)

    viol = jax.lax.fori_loop(0, tn, body, viol_s[0, 0])
    viol_s[0, 0] = viol

    @pl.when(i == n_blocks - 1)
    def _fini():
        w_out[...] = w_s[...]
        viol_out[0, 0] = viol_s[0, 0]


@functools.partial(
    jax.jit,
    static_argnames=("full_pass", "shrink_k", "tn", "interpret"))
def smo_epoch_pallas(G, y, c, q, alpha, unchanged, w, *,
                     full_pass: bool, shrink_k: int = 5, tn: int = 256,
                     interpret: bool = False):
    """One epoch over pre-padded (n_pad % tn == 0) per-task data.

    Shapes: G (n, B); y/c/q/alpha (n, 1) f32; unchanged (n, 1) i32; w (1, B).
    Returns (alpha, unchanged, w, viol[1,1]).
    """
    n, B = G.shape
    assert n % tn == 0, (n, tn)
    n_blocks = n // tn
    kernel = functools.partial(_smo_kernel, tn=tn, n_blocks=n_blocks,
                               full_pass=full_pass, shrink_k=shrink_k)
    col = lambda i: (i, 0)
    rep = lambda i: (0, 0)
    return pl.pallas_call(
        kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((tn, B), col),      # G tile
            pl.BlockSpec((tn, 1), col),      # y
            pl.BlockSpec((tn, 1), col),      # c
            pl.BlockSpec((tn, 1), col),      # q
            pl.BlockSpec((tn, 1), col),      # alpha
            pl.BlockSpec((tn, 1), col),      # unchanged
            pl.BlockSpec((1, B), rep),       # w (read once)
        ],
        out_specs=[
            pl.BlockSpec((tn, 1), col),
            pl.BlockSpec((tn, 1), col),
            pl.BlockSpec((1, B), rep),
            pl.BlockSpec((1, 1), rep),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, 1), jnp.float32),
            jax.ShapeDtypeStruct((n, 1), jnp.int32),
            jax.ShapeDtypeStruct((1, B), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((1, B), jnp.float32),   # w scratchpad (the SM trick)
            pltpu.VMEM((1, 1), jnp.float32),   # running max violation
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(G, y, c, q, alpha, unchanged, w)
