"""Pallas TPU kernels for the paper's two compute hot spots:

  * gram.py — stage-1 batch kernel-matrix computation (paper: custom CUDA
    kernels + cuBLAS) — MXU-tiled, VMEM-accumulated;
  * smo.py  — stage-2 SMO epoch (paper: single-SM scratchpad loop) — w in a
    persistent VMEM scratch, G streamed tile-by-tile.

ops.py holds the jit'd padding/dispatch wrappers; ref.py the pure-jnp oracles.
"""
from repro.kernels.ops import flash_attention, gram, smo_epoch

__all__ = ["flash_attention", "gram", "smo_epoch"]
