"""Pallas TPU kernel: blocked batch kernel (gram) matrix computation.

Stage-1 hot spot of LPD-SVM ("batch kernel computation ... extremely efficient
on the GPU, using our own CUDA kernels").  TPU adaptation:

  * grid (n/tn, m/tm, p/tp); the contraction axis is the innermost grid
    dimension, so each (i, j) output tile accumulates partial X @ Z^T products
    in a float32 VMEM scratch across sequential k-steps (HBM->VMEM streaming of
    the p axis — the MXU sees hardware-aligned (tn, tp) x (tp, tm) tiles);
  * the squared row norms needed by the RBF epilogue are accumulated in VMEM
    alongside the dot products (one extra VPU rowsum per tile — negligible
    next to the MXU work), so the kernel makes a single pass over the inputs;
  * the kernel-function epilogue (exp / pow / tanh) is applied in-register on
    the final k-step before the tile is written back to HBM.

Block defaults are MXU-aligned: tn = tm = 128 lanes, tp = 512 floats.
VMEM footprint per step ~ (tn*tp + tm*tp + tn*tm) * 4B ~ 0.6 MB << 16 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.compat import tpu_compiler_params
from repro.core.kernel_fn import KernelParams


def _gram_kernel(x_ref, z_ref, o_ref, acc_ref, xsq_ref, zsq_ref, *,
                 params: KernelParams, k_steps: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        xsq_ref[...] = jnp.zeros_like(xsq_ref)
        zsq_ref[...] = jnp.zeros_like(zsq_ref)

    x = x_ref[...]  # (tn, tp)
    z = z_ref[...]  # (tm, tp)
    acc_ref[...] += jax.lax.dot_general(
        x, z, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    if params.kind == "rbf":  # norms only needed for the RBF epilogue
        xsq_ref[...] += jnp.sum(x * x, axis=1, keepdims=True)
        zsq_ref[...] += jnp.sum(z * z, axis=1, keepdims=True).T

    @pl.when(k == k_steps - 1)
    def _epilogue():
        dot = acc_ref[...]
        if params.kind == "linear":
            out = dot
        elif params.kind == "rbf":
            d2 = xsq_ref[...] + zsq_ref[...] - 2.0 * dot
            out = jnp.exp(-params.gamma * jnp.maximum(d2, 0.0))
        elif params.kind == "poly":
            out = (params.gamma * dot + params.coef0) ** params.degree
        elif params.kind == "tanh":
            out = jnp.tanh(params.gamma * dot + params.coef0)
        else:
            raise ValueError(params.kind)
        o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("params", "tn", "tm", "tp", "interpret"))
def gram_pallas(x: jnp.ndarray, z: jnp.ndarray, params: KernelParams,
                *, tn: int = 128, tm: int = 128, tp: int = 512,
                interpret: bool = False) -> jnp.ndarray:
    """K[i, j] = k(x_i, z_j) for pre-padded inputs (shapes divisible by tiles).

    Use `repro.kernels.ops.gram` for the padding/dispatch wrapper.
    """
    n, p = x.shape
    m, _ = z.shape
    assert n % tn == 0 and m % tm == 0 and p % tp == 0, (n, m, p, tn, tm, tp)
    k_steps = p // tp
    grid = (n // tn, m // tm, k_steps)

    kernel = functools.partial(_gram_kernel, params=params, k_steps=k_steps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tn, tp), lambda i, j, k: (i, k)),
            pl.BlockSpec((tm, tp), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((tn, tm), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((tn, tm), jnp.float32),   # dot accumulator
            pltpu.VMEM((tn, 1), jnp.float32),    # ||x_i||^2
            pltpu.VMEM((1, tm), jnp.float32),    # ||z_j||^2
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, z)


# ---------------------------------------------------------------------------
# int8 wire variant: fused dequantisation of the streamed x operand
# ---------------------------------------------------------------------------

def _gram_kernel_q8(x_ref, sx_ref, zx_ref, z_ref, o_ref, acc_ref, xsq_ref,
                    zsq_ref, *, params: KernelParams, k_steps: int):
    """`_gram_kernel` with the x operand arriving as int8 wire data.

    The H2D copy moved one byte per element; the dequantisation
    x = q * scale + zero (per-row scale/zero from the host codec,
    `core/quant.py`) happens HERE, in VMEM registers on the (tn, tp) tile the
    MXU is about to consume — no fp32 copy of the chunk ever exists in HBM.
    The norms epilogue accumulates from the same dequantised registers, so
    one pass over the int8 input still produces exact-fp32-path semantics up
    to the codec's rounding.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        xsq_ref[...] = jnp.zeros_like(xsq_ref)
        zsq_ref[...] = jnp.zeros_like(zsq_ref)

    # Fused dequant: int8 tile -> fp32 registers (sx/zx broadcast per row).
    x = x_ref[...].astype(jnp.float32) * sx_ref[...] + zx_ref[...]
    z = z_ref[...]  # (tm, tp), fp32 (landmarks stay device-resident)
    acc_ref[...] += jax.lax.dot_general(
        x, z, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    if params.kind == "rbf":
        xsq_ref[...] += jnp.sum(x * x, axis=1, keepdims=True)
        zsq_ref[...] += jnp.sum(z * z, axis=1, keepdims=True).T

    @pl.when(k == k_steps - 1)
    def _epilogue():
        dot = acc_ref[...]
        if params.kind == "linear":
            out = dot
        elif params.kind == "rbf":
            d2 = xsq_ref[...] + zsq_ref[...] - 2.0 * dot
            out = jnp.exp(-params.gamma * jnp.maximum(d2, 0.0))
        elif params.kind == "poly":
            out = (params.gamma * dot + params.coef0) ** params.degree
        elif params.kind == "tanh":
            out = jnp.tanh(params.gamma * dot + params.coef0)
        else:
            raise ValueError(params.kind)
        o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("params", "tn", "tm", "tp", "interpret"))
def gram_pallas_q8(x_q8: jnp.ndarray, sx: jnp.ndarray, zx: jnp.ndarray,
                   z: jnp.ndarray, params: KernelParams,
                   *, tn: int = 128, tm: int = 128, tp: int = 512,
                   interpret: bool = False) -> jnp.ndarray:
    """K[i, j] = k(x_i, z_j) from a quantised x: int8 values (n, p) plus
    per-ROW fp32 scale/zero columns sx/zx of shape (n, 1).

    Pre-padded shapes (divisible by tiles), like `gram_pallas`.  Feature-axis
    zero padding of the int8 values is exact only when the padded rows carry
    zx = 0 (symmetric codec) — `repro.kernels.ops.gram_q8` checks that
    contract where the scale table is concrete (the streaming pipeline
    always quantises symmetrically).
    """
    n, p = x_q8.shape
    m, _ = z.shape
    assert n % tn == 0 and m % tm == 0 and p % tp == 0, (n, m, p, tn, tm, tp)
    assert sx.shape == (n, 1) and zx.shape == (n, 1), (sx.shape, zx.shape)
    k_steps = p // tp
    grid = (n // tn, m // tm, k_steps)

    kernel = functools.partial(_gram_kernel_q8, params=params, k_steps=k_steps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tn, tp), lambda i, j, k: (i, k)),   # int8 values
            pl.BlockSpec((tn, 1), lambda i, j, k: (i, 0)),    # row scales
            pl.BlockSpec((tn, 1), lambda i, j, k: (i, 0)),    # row zeros
            pl.BlockSpec((tm, tp), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((tn, tm), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((tn, tm), jnp.float32),   # dot accumulator
            pltpu.VMEM((tn, 1), jnp.float32),    # ||x_i||^2
            pltpu.VMEM((1, tm), jnp.float32),    # ||z_j||^2
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x_q8, sx, zx, z)
