"""Jit'd public wrappers around the Pallas kernels: padding, tiling, dispatch.

On the CPU container the kernels execute with interpret=True (Python-level
execution of the kernel body); on TPU they compile to Mosaic.  The wrappers
make either path a drop-in replacement for the pure-jnp reference functions
(`core.kernel_fn.gram`, `core.dual_solver.epoch_ref`).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kernel_fn import KernelParams
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.gram import gram_pallas, gram_pallas_q8
from repro.kernels.smo import smo_epoch_pallas


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_axis(a: jnp.ndarray, axis: int, multiple: int) -> jnp.ndarray:
    size = a.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


def gram(x: jnp.ndarray, z: jnp.ndarray, params: KernelParams, *,
         tn: int = 128, tm: int = 128, tp: int = 512,
         interpret: Optional[bool] = None) -> jnp.ndarray:
    """Batch kernel matrix K(x, z) via the Pallas kernel, any shapes.

    Zero-padding the feature axis is exact for every supported kernel (it adds
    zero to the dot products and squared norms); padded rows/cols are sliced
    off the output.
    """
    if interpret is None:
        interpret = _default_interpret()
    n, m = x.shape[0], z.shape[0]
    x = _pad_axis(_pad_axis(jnp.asarray(x, jnp.float32), 1, tp), 0, tn)
    z = _pad_axis(_pad_axis(jnp.asarray(z, jnp.float32), 1, tp), 0, tm)
    out = gram_pallas(x, z, params, tn=tn, tm=tm, tp=tp, interpret=interpret)
    return out[:n, :m]


def gram_q8(values: jnp.ndarray, scales: jnp.ndarray, z: jnp.ndarray,
            params: KernelParams, *, group: int = 32,
            tn: int = 128, tm: int = 128, tp: int = 512,
            interpret: Optional[bool] = None) -> jnp.ndarray:
    """Batch kernel matrix from a quantised x operand, any shapes.

    ``values`` is the (n, p) int8 wire block and ``scales`` the compact
    (ng, 2) per-row-group scale/zero table (`core/quant.py`); z stays fp32
    (device-resident landmarks).  The compact table is expanded to per-row
    (n, 1) scale/zero columns on device — 8 bytes per GROUP cross the bus,
    not 8 per row — and dequantisation is fused into the Pallas kernel's
    tile loads (`gram_pallas_q8`), so no fp32 copy of x is ever
    materialised in HBM.

    Padding contract: padded ROWS get scale 0 / zero 0 (dequantise to exact
    zeros, sliced off the output anyway).  Feature-axis zero padding of the
    int8 values dequantises to the row's zero-point, which cancels in the
    dot (z's padded columns are fp32 zeros) but NOT in the RBF row norms —
    so RBF with a ragged feature axis requires the symmetric codec
    (zero = 0), which is what the stage-1 streaming pipeline emits.  The
    contract is checked here when the scale table is concrete; under jit
    (traced scales) the caller must guarantee it.
    """
    if interpret is None:
        interpret = _default_interpret()
    n, p = values.shape
    m = z.shape[0]
    if params.kind == "rbf" and p % tp:
        try:
            zero_points = np.asarray(scales)[:, 1]
        except Exception:        # traced under jit: contract is the caller's
            zero_points = None
        if zero_points is not None and np.any(zero_points != 0.0):
            raise ValueError(
                "gram_q8: RBF with a feature axis padded to the tile "
                f"(p={p}, tp={tp}) requires the symmetric codec — affine "
                "zero-points would leak into the row norms; quantise with "
                "quantize_rows(..., symmetric=True)")
    ng = scales.shape[0]
    sx = jnp.repeat(scales[:, 0], group, total_repeat_length=ng * group)[:n]
    zx = jnp.repeat(scales[:, 1], group, total_repeat_length=ng * group)[:n]
    vq = _pad_axis(_pad_axis(jnp.asarray(values, jnp.int8), 1, tp), 0, tn)
    sx = _pad_axis(sx.reshape(-1, 1).astype(jnp.float32), 0, tn)
    zx = _pad_axis(zx.reshape(-1, 1).astype(jnp.float32), 0, tn)
    zp = _pad_axis(_pad_axis(jnp.asarray(z, jnp.float32), 1, tp), 0, tm)
    out = gram_pallas_q8(vq, sx, zx, zp, params, tn=tn, tm=tm, tp=tp,
                         interpret=interpret)
    return out[:n, :m]


def flash_attention(q, k, v, *, causal: bool = True, bq: int = 256,
                    bk: int = 256, interpret: Optional[bool] = None):
    """Causal flash attention over (B, H, S, D) tensors (pads S to blocks).

    On TPU this is the Mosaic kernel; off-TPU it interprets.  The jnp
    two-level-chunked path in models/attention.py remains the default for
    dry-run lowering; this entry point is for TPU deployment + validation.
    """
    if interpret is None:
        interpret = _default_interpret()
    B, H, S, D = q.shape
    bq = min(bq, S)
    bk = min(bk, S)
    pad = (-S) % max(bq, bk)
    flat = lambda a: _pad_axis(a.reshape(B * H, S, D), 1, max(bq, bk))
    qf, kf, vf = flat(q), flat(k), flat(v)
    if pad:  # padded kv rows must never win the softmax: mask via causal rows
        assert causal, "padding currently supported for causal attention only"
    out = flash_attention_pallas(qf, kf, vf, causal=causal, bq=bq, bk=bk,
                                 interpret=interpret)
    return out[:, :S].reshape(B, H, S, D)


def smo_epoch(G, y, c, q, alpha, unchanged, w, *, full_pass: bool,
              shrink_k: int = 5, tn: int = 256,
              interpret: Optional[bool] = None):
    """One shrinking-aware coordinate-ascent epoch (flat 1-D vectors in/out).

    Row padding uses c = 0, which the kernel treats as inert, so results are
    exact for any n.  Returns (alpha, unchanged, w, viol_scalar).
    """
    if interpret is None:
        interpret = _default_interpret()
    n = G.shape[0]
    tn = min(tn, max(8, 1 << (n - 1).bit_length())) if n < tn else tn
    Gp = _pad_axis(jnp.asarray(G, jnp.float32), 0, tn)
    pad1 = lambda v, dt: _pad_axis(jnp.asarray(v, dt).reshape(-1, 1), 0, tn)
    a, u, wv, viol = smo_epoch_pallas(
        Gp, pad1(y, jnp.float32), pad1(c, jnp.float32), pad1(q, jnp.float32),
        pad1(alpha, jnp.float32), pad1(unchanged, jnp.int32),
        jnp.asarray(w, jnp.float32).reshape(1, -1),
        full_pass=full_pass, shrink_k=shrink_k, tn=tn, interpret=interpret)
    return a[:n, 0], u[:n, 0], wv[0], viol[0, 0]
