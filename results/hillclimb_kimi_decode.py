"""Hillclimb #2: kimi-k2 decode_32k — replicated vs replicated_psum MoE.

Lowers unrolled probes (1 and 2 groups) for both strategies and extrapolates
to 60 MoE layers; records temp of the full scanned lowering too.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json

import jax

from repro.analysis.hlo import collective_stats
from repro.configs import get_config
from repro.launch import specs as S
from repro.launch.dryrun import probe_config
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_serve_step
from repro.models import attention as attn_mod
from repro.models.model import _layout

cfg = get_config("kimi-k2-1t-a32b")
shape = S.SHAPES["decode_32k"]
mesh = make_production_mesh()
n_groups = _layout(cfg)[2]
out = {}

for strat in ("replicated", "replicated_psum"):
    rec = {}
    with jax.set_mesh(mesh):
        params_sds, _ = S.param_specs(cfg, mesh)
        ins = S.serve_input_specs(cfg, shape, mesh)
        # full lowering for memory
        step = make_serve_step(cfg, mesh, global_batch=shape.global_batch,
                               moe_decode=strat)
        c = jax.jit(step, donate_argnums=(2,)).lower(
            params_sds, ins["tokens"], ins["state"], ins["pos"]).compile()
        rec["temp_gib"] = c.memory_analysis().temp_size_in_bytes / 2**30
        # probes for exact per-layer costs
        attn_mod.FLASH_KV_CHUNK = 1 << 30
        probes = []
        for k in (1, 2):
            pc = probe_config(cfg, k)
            psds, _ = S.param_specs(pc, mesh)
            pins = S.serve_input_specs(pc, shape, mesh)
            pstep = make_serve_step(pc, mesh, global_batch=shape.global_batch,
                                    moe_decode=strat, unroll=True)
            comp = jax.jit(pstep).lower(psds, pins["tokens"], pins["state"],
                                        pins["pos"]).compile()
            probes.append({"cost": comp.cost_analysis(),
                           "coll": collective_stats(comp.as_text())})
        attn_mod.FLASH_KV_CHUNK = 1024

        def extra(sel):
            p1, p2 = sel(probes[0]), sel(probes[1])
            return p1 + (n_groups - 1) * max(0.0, p2 - p1)

        rec["flops"] = extra(lambda p: p["cost"].get("flops", 0.0))
        rec["bytes"] = extra(lambda p: p["cost"].get("bytes accessed", 0.0))
        rec["collective_bytes"] = extra(lambda p: p["coll"]["weighted_bytes"])
    out[strat] = rec
    print(strat, json.dumps(rec), flush=True)

with open(os.path.join(os.path.dirname(__file__), "hillclimb_kimi_decode.json"),
          "w") as f:
    json.dump(out, f, indent=1)
