"""Hillclimb #3 measurement: SVM stage1-project baseline vs v2 reshard."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis.hlo import collective_stats
from repro.core.distributed import (stage1_project_sharded,
                                    stage1_project_sharded_v2)
from repro.launch.mesh import make_production_mesh

n, budget = 10_002_432, 10_000  # 256-divisible rows
mesh = make_production_mesh()
out = {}
with jax.set_mesh(mesh):
    knm = jax.ShapeDtypeStruct((n, budget), jnp.float32,
                               sharding=NamedSharding(mesh, P(("data",), "model")))
    proj = jax.ShapeDtypeStruct((budget, budget), jnp.float32,
                                sharding=NamedSharding(mesh, P(None, None)))
    for name, fn in (("baseline", stage1_project_sharded(mesh)),
                     ("v2_reshard", stage1_project_sharded_v2(mesh))):
        c = fn.lower(knm, proj).compile()
        ma = c.memory_analysis()
        out[name] = {
            "temp_gib": ma.temp_size_in_bytes / 2**30,
            "flops": c.cost_analysis().get("flops", 0.0),
            "bytes": c.cost_analysis().get("bytes accessed", 0.0),
            "collective_bytes": collective_stats(c.as_text())["weighted_bytes"],
        }
        print(name, json.dumps(out[name]), flush=True)

with open(os.path.join(os.path.dirname(__file__),
                       "hillclimb_svm_project.json"), "w") as f:
    json.dump(out, f, indent=1)
