"""Bonus iteration: deepseek-v2 train_4k mesh reshape — most collective-bound pair.

Hypothesis (napkin): at (data=16, model=16) the dominant collective is the
per-layer FSDP all-gather of expert weights (E_loc = 24 experts x 7168 x
2048 x 3 x bf16 ~ 2.1 GiB/device/layer, x60 layers x fwd+remat+bwd).  The
gathered bytes per device scale as total_layer_params / model_size, so
widening the expert-parallel axis at constant chip count (256) should cut
the weight-gather term ~linearly, while the token-dispatch all_to_all stays
roughly constant.  Risk: the seq-parallel <-> TP activation all-gathers grow
with per-device batch (B_loc = 256/data).

Measures probe-extrapolated flops / HBM bytes / collective bytes on
256-chip meshes (16,16), (8,32), (4,64).
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json

import jax
from jax.sharding import AxisType

from repro.analysis.hlo import collective_stats
from repro.configs import get_config
from repro.launch import specs as S
from repro.launch.dryrun import probe_config
from repro.launch.steps import make_train_step
from repro.models import attention as attn_mod
from repro.models.model import _layout
from repro.optim import get_optimizer

cfg = get_config("deepseek-v2-236b")
shape = S.SHAPES["train_4k"]
n_groups = _layout(cfg)[2]
out = {}

for d_ax, m_ax in ((16, 16), (32, 8)):
    mesh = jax.make_mesh((d_ax, m_ax), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
    rec = {}
    with jax.set_mesh(mesh):
        attn_mod.FLASH_KV_CHUNK = 1 << 30
        probes = []
        for k in (1, 2):
            pc = probe_config(cfg, k)
            psds, _ = S.param_specs(pc, mesh)
            opt = get_optimizer(pc.optimizer)
            osds = S.opt_state_specs(opt, psds)
            step = make_train_step(pc, opt, mesh,
                                   global_batch=shape.global_batch,
                                   unroll=True)
            comp = jax.jit(step, donate_argnums=(0, 1)).lower(
                psds, osds, S.batch_specs(pc, shape, mesh)).compile()
            probes.append({"cost": comp.cost_analysis(),
                           "coll": collective_stats(comp.as_text()),
                           "temp": comp.memory_analysis().temp_size_in_bytes})
        attn_mod.FLASH_KV_CHUNK = 1024

        def extra(sel):
            p1, p2 = sel(probes[0]), sel(probes[1])
            return p1 + (n_groups - 1) * max(0.0, p2 - p1)

        rec["flops"] = extra(lambda p: p["cost"].get("flops", 0.0))
        rec["bytes"] = extra(lambda p: p["cost"].get("bytes accessed", 0.0))
        rec["collective_bytes"] = extra(lambda p: p["coll"]["weighted_bytes"])
        rec["by_kind_probe2"] = {
            k: v for k, v in probes[1]["coll"]["by_kind"].items()
            if v["count"]}
        rec["probe2_temp_gib"] = probes[1]["temp"] / 2**30
    out[f"mesh{d_ax}x{m_ax}"] = rec
    print(f"mesh {d_ax}x{m_ax}:", json.dumps(rec), flush=True)

with open(os.path.join(os.path.dirname(__file__),
                       "hillclimb_deepseek_train.json"), "w") as f:
    json.dump(out, f, indent=1)
