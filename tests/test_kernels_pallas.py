"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.kernel_fn import KernelParams
from repro.kernels import ops, ref


@pytest.mark.parametrize("n,m,p", [
    (128, 128, 512),      # exactly one tile
    (130, 70, 33),        # everything ragged
    (17, 300, 1100),      # tall/skinny + multi-k
    (256, 128, 512),
])
@pytest.mark.parametrize("kind", ["rbf", "linear", "poly", "tanh"])
def test_gram_kernel_allclose(rng, n, m, p, kind):
    x = jnp.asarray(rng.normal(size=(n, p)), jnp.float32)
    z = jnp.asarray(rng.normal(size=(m, p)), jnp.float32)
    kp = KernelParams(kind, gamma=0.11, coef0=0.3, degree=2)
    got = ops.gram(x, z, kp, interpret=True)
    want = ref.gram_ref(x, z, kp)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("tn,tm,tp", [(128, 128, 512), (8, 16, 32)])
def test_gram_kernel_tile_sweep(rng, tn, tm, tp):
    x = jnp.asarray(rng.normal(size=(40, 64)), jnp.float32)
    z = jnp.asarray(rng.normal(size=(24, 64)), jnp.float32)
    kp = KernelParams("rbf", gamma=0.25)
    got = ops.gram(x, z, kp, tn=tn, tm=tm, tp=tp, interpret=True)
    want = ref.gram_ref(x, z, kp)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def _smo_inputs(rng, n=96, B=64, frac_pad=0.1):
    G = jnp.asarray(rng.normal(size=(n, B)) / np.sqrt(B), jnp.float32)
    y = jnp.asarray(rng.choice([-1.0, 1.0], size=n), jnp.float32)
    c = np.full((n,), 2.0, np.float32)
    c[int(n * (1 - frac_pad)):] = 0.0
    c = jnp.asarray(c)
    q = jnp.sum(G ** 2, axis=1)
    alpha = jnp.asarray(rng.uniform(0, 2, size=n).astype(np.float32)) * (c > 0)
    w = (alpha * y) @ G
    unch = jnp.asarray(rng.integers(0, 8, size=n), jnp.int32)
    return G, y, c, q, alpha, unch, w


@pytest.mark.parametrize("full_pass", [True, False])
@pytest.mark.parametrize("n,B", [(96, 64), (200, 96), (64, 128)])
def test_smo_epoch_allclose(rng, full_pass, n, B):
    G, y, c, q, alpha, unch, w = _smo_inputs(rng, n, B)
    a1, u1, w1, v1 = ops.smo_epoch(G, y, c, q, alpha, unch, w,
                                   full_pass=full_pass, interpret=True)
    a2, u2, w2, v2 = ref.smo_epoch_ref(
        G, y[:, None], c[:, None], q[:, None], alpha[:, None],
        unch[:, None], w[None, :], full_pass=full_pass)
    np.testing.assert_allclose(np.asarray(a1), np.asarray(a2[:, 0]), atol=3e-6)
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w2[0]), atol=3e-5)
    assert np.mean(np.asarray(u1) == np.asarray(u2[:, 0])) > 0.98
    assert abs(float(v1) - float(v2[0, 0])) < 1e-4


def test_smo_epoch_monotone_dual(rng):
    """Coordinate ascent must not decrease the dual objective."""
    G, y, c, q, alpha, unch, w = _smo_inputs(rng, 128, 64, frac_pad=0.0)
    def dual(a, wv):
        return float(jnp.sum(a) - 0.5 * jnp.dot(wv, wv))
    d0 = dual(alpha, w)
    a, u, wv, _ = ops.smo_epoch(G, y, c, q, alpha, unch, w,
                                full_pass=True, interpret=True)
    d1 = dual(a, wv)
    a, u, wv, _ = ops.smo_epoch(G, y, c, q, a, u, wv,
                                full_pass=True, interpret=True)
    d2 = dual(a, wv)
    assert d1 >= d0 - 1e-4 and d2 >= d1 - 1e-4


def test_gram_accepts_bf16_inputs(rng):
    """Wrapper casts to f32 internally (SVM path is f32 by design)."""
    x = jnp.asarray(rng.normal(size=(40, 64)), jnp.bfloat16)
    z = jnp.asarray(rng.normal(size=(24, 64)), jnp.bfloat16)
    kp = KernelParams("rbf", gamma=0.25)
    got = ops.gram(x, z, kp, interpret=True)
    want = ref.gram_ref(x.astype(jnp.float32), z.astype(jnp.float32), kp)
    assert got.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("tn", [8, 64, 256])
def test_smo_epoch_tile_sweep(rng, tn):
    G, y, c, q, alpha, unch, w = _smo_inputs(rng, 96, 64)
    a1, u1, w1, v1 = ops.smo_epoch(G, y, c, q, alpha, unch, w,
                                   full_pass=True, tn=tn, interpret=True)
    a2, u2, w2, v2 = ref.smo_epoch_ref(
        G, y[:, None], c[:, None], q[:, None], alpha[:, None],
        unch[:, None], w[None, :], full_pass=True)
    np.testing.assert_allclose(np.asarray(a1), np.asarray(a2[:, 0]), atol=3e-6)
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w2[0]), atol=3e-5)
