"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.kernel_fn import KernelParams
from repro.kernels import ops, ref


@pytest.mark.parametrize("n,m,p", [
    (128, 128, 512),      # exactly one tile
    (130, 70, 33),        # everything ragged
    (17, 300, 1100),      # tall/skinny + multi-k
    (256, 128, 512),
])
@pytest.mark.parametrize("kind", ["rbf", "linear", "poly", "tanh"])
def test_gram_kernel_allclose(rng, n, m, p, kind):
    x = jnp.asarray(rng.normal(size=(n, p)), jnp.float32)
    z = jnp.asarray(rng.normal(size=(m, p)), jnp.float32)
    kp = KernelParams(kind, gamma=0.11, coef0=0.3, degree=2)
    got = ops.gram(x, z, kp, interpret=True)
    want = ref.gram_ref(x, z, kp)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("tn,tm,tp", [(128, 128, 512), (8, 16, 32)])
def test_gram_kernel_tile_sweep(rng, tn, tm, tp):
    x = jnp.asarray(rng.normal(size=(40, 64)), jnp.float32)
    z = jnp.asarray(rng.normal(size=(24, 64)), jnp.float32)
    kp = KernelParams("rbf", gamma=0.25)
    got = ops.gram(x, z, kp, tn=tn, tm=tm, tp=tp, interpret=True)
    want = ref.gram_ref(x, z, kp)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("n,m,p", [(64, 24, 32), (70, 9, 33), (33, 40, 100)])
@pytest.mark.parametrize("kind", ["rbf", "linear", "poly", "tanh"])
def test_gram_q8_fused_dequant_matches_ref(rng, n, m, p, kind):
    """The int8-wire gram kernel (fused in-register dequant) must agree with
    dequantise-then-gram to fp32 accumulation tolerance, ragged shapes
    included.  The symmetric codec keeps feature-axis zero padding exact for
    every kernel kind (RBF needs the true row norms)."""
    from repro.core.quant import quantize_rows
    x = rng.normal(size=(n, p)).astype(np.float32)
    z = jnp.asarray(rng.normal(size=(m, p)), jnp.float32)
    kp = KernelParams(kind, gamma=0.11, coef0=0.3, degree=2)
    v, s = quantize_rows(x, 32, symmetric=True)
    got = ops.gram_q8(jnp.asarray(v), jnp.asarray(s), z, kp, group=32,
                      tn=32, tm=8, tp=32, interpret=True)
    want = ref.gram_q8_ref(jnp.asarray(v), jnp.asarray(s), z, kp, group=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_gram_q8_rejects_affine_rbf_with_ragged_features(rng):
    """Affine zero-points would leak into the RBF row norms through the
    feature-axis padding — the wrapper rejects that combination when the
    scale table is concrete."""
    from repro.core.quant import quantize_rows
    x = (rng.normal(size=(32, 33)) + 5.0).astype(np.float32)
    z = jnp.asarray(rng.normal(size=(8, 33)), jnp.float32)
    v, s = quantize_rows(x, 32)                  # affine: nonzero zeros
    with pytest.raises(ValueError, match="symmetric"):
        ops.gram_q8(jnp.asarray(v), jnp.asarray(s), z,
                    KernelParams("rbf", gamma=0.1), group=32,
                    tn=32, tm=8, tp=32, interpret=True)
    # symmetric codec with the same shapes is fine
    vs, ss = quantize_rows(x, 32, symmetric=True)
    ops.gram_q8(jnp.asarray(vs), jnp.asarray(ss), z,
                KernelParams("rbf", gamma=0.1), group=32,
                tn=32, tm=8, tp=32, interpret=True)


def test_gram_q8_close_to_exact_gram(rng):
    """End-to-end codec error through the kernel stays at the scale/2 level:
    the quantised gram is a small perturbation of the exact one."""
    from repro.core.quant import quantize_rows
    x = rng.normal(size=(96, 48)).astype(np.float32)
    z = jnp.asarray(rng.normal(size=(32, 48)), jnp.float32)
    kp = KernelParams("rbf", gamma=0.1)
    v, s = quantize_rows(x, 32, symmetric=True)
    got = np.asarray(ops.gram_q8(jnp.asarray(v), jnp.asarray(s), z, kp,
                                 group=32, tn=32, tm=8, tp=16,
                                 interpret=True))
    exact = np.asarray(ref.gram_ref(jnp.asarray(x), z, kp))
    assert np.abs(got - exact).max() < 0.05
    assert np.abs(got - exact).mean() < 0.01


def _smo_inputs(rng, n=96, B=64, frac_pad=0.1):
    G = jnp.asarray(rng.normal(size=(n, B)) / np.sqrt(B), jnp.float32)
    y = jnp.asarray(rng.choice([-1.0, 1.0], size=n), jnp.float32)
    c = np.full((n,), 2.0, np.float32)
    c[int(n * (1 - frac_pad)):] = 0.0
    c = jnp.asarray(c)
    q = jnp.sum(G ** 2, axis=1)
    alpha = jnp.asarray(rng.uniform(0, 2, size=n).astype(np.float32)) * (c > 0)
    w = (alpha * y) @ G
    unch = jnp.asarray(rng.integers(0, 8, size=n), jnp.int32)
    return G, y, c, q, alpha, unch, w


@pytest.mark.parametrize("full_pass", [True, False])
@pytest.mark.parametrize("n,B", [(96, 64), (200, 96), (64, 128)])
def test_smo_epoch_allclose(rng, full_pass, n, B):
    G, y, c, q, alpha, unch, w = _smo_inputs(rng, n, B)
    a1, u1, w1, v1 = ops.smo_epoch(G, y, c, q, alpha, unch, w,
                                   full_pass=full_pass, interpret=True)
    a2, u2, w2, v2 = ref.smo_epoch_ref(
        G, y[:, None], c[:, None], q[:, None], alpha[:, None],
        unch[:, None], w[None, :], full_pass=full_pass)
    np.testing.assert_allclose(np.asarray(a1), np.asarray(a2[:, 0]), atol=3e-6)
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w2[0]), atol=3e-5)
    assert np.mean(np.asarray(u1) == np.asarray(u2[:, 0])) > 0.98
    assert abs(float(v1) - float(v2[0, 0])) < 1e-4


def test_smo_epoch_monotone_dual(rng):
    """Coordinate ascent must not decrease the dual objective."""
    G, y, c, q, alpha, unch, w = _smo_inputs(rng, 128, 64, frac_pad=0.0)
    def dual(a, wv):
        return float(jnp.sum(a) - 0.5 * jnp.dot(wv, wv))
    d0 = dual(alpha, w)
    a, u, wv, _ = ops.smo_epoch(G, y, c, q, alpha, unch, w,
                                full_pass=True, interpret=True)
    d1 = dual(a, wv)
    a, u, wv, _ = ops.smo_epoch(G, y, c, q, a, u, wv,
                                full_pass=True, interpret=True)
    d2 = dual(a, wv)
    assert d1 >= d0 - 1e-4 and d2 >= d1 - 1e-4


def test_gram_accepts_bf16_inputs(rng):
    """Wrapper casts to f32 internally (SVM path is f32 by design)."""
    x = jnp.asarray(rng.normal(size=(40, 64)), jnp.bfloat16)
    z = jnp.asarray(rng.normal(size=(24, 64)), jnp.bfloat16)
    kp = KernelParams("rbf", gamma=0.25)
    got = ops.gram(x, z, kp, interpret=True)
    want = ref.gram_ref(x.astype(jnp.float32), z.astype(jnp.float32), kp)
    assert got.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("tn", [8, 64, 256])
def test_smo_epoch_tile_sweep(rng, tn):
    G, y, c, q, alpha, unch, w = _smo_inputs(rng, 96, 64)
    a1, u1, w1, v1 = ops.smo_epoch(G, y, c, q, alpha, unch, w,
                                   full_pass=True, tn=tn, interpret=True)
    a2, u2, w2, v2 = ref.smo_epoch_ref(
        G, y[:, None], c[:, None], q[:, None], alpha[:, None],
        unch[:, None], w[None, :], full_pass=True)
    np.testing.assert_allclose(np.asarray(a1), np.asarray(a2[:, 0]), atol=3e-6)
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w2[0]), atol=3e-5)
