"""Kernel-function layer: values, symmetry, PSD-ness."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.kernel_fn import KernelParams, gram, kernel_diag


@pytest.mark.parametrize("kind,kw", [
    ("rbf", dict(gamma=0.7)),
    ("linear", {}),
    ("poly", dict(gamma=0.5, coef0=1.0, degree=3)),
    ("tanh", dict(gamma=0.05, coef0=0.1)),
])
def test_gram_matches_naive(rng, kind, kw):
    x = jnp.asarray(rng.normal(size=(20, 5)), jnp.float32)
    z = jnp.asarray(rng.normal(size=(15, 5)), jnp.float32)
    kp = KernelParams(kind, **kw)
    K = np.asarray(gram(x, z, kp))
    for i in [0, 7, 19]:
        for j in [0, 3, 14]:
            xi, zj = np.asarray(x[i]), np.asarray(z[j])
            dot = float(xi @ zj)
            if kind == "rbf":
                want = np.exp(-kw["gamma"] * ((xi - zj) ** 2).sum())
            elif kind == "linear":
                want = dot
            elif kind == "poly":
                want = (kw["gamma"] * dot + kw["coef0"]) ** kw["degree"]
            else:
                want = np.tanh(kw["gamma"] * dot + kw["coef0"])
            assert abs(K[i, j] - want) < 1e-4


def test_rbf_gram_psd_and_symmetric(rng):
    x = jnp.asarray(rng.normal(size=(40, 4)), jnp.float32)
    K = np.asarray(gram(x, x, KernelParams("rbf", gamma=0.5)))
    assert np.allclose(K, K.T, atol=1e-5)
    evals = np.linalg.eigvalsh((K + K.T) / 2)
    assert evals.min() > -1e-4
    assert np.allclose(np.diag(K), 1.0, atol=1e-5)


def test_kernel_diag_consistent(rng):
    x = jnp.asarray(rng.normal(size=(10, 6)), jnp.float32)
    for kind in ("rbf", "linear", "poly", "tanh"):
        kp = KernelParams(kind, gamma=0.3, coef0=0.5)
        d = np.asarray(kernel_diag(x, kp))
        K = np.asarray(gram(x, x, kp))
        assert np.allclose(d, np.diag(K), atol=1e-4)


def test_unknown_kernel_rejected():
    with pytest.raises(ValueError):
        KernelParams("cosine")
