"""The int8 per-row-group wire codec (`core/quant.py`).

Pins down (a) the reconstruction-error bound scale/2 in both codec modes,
(b) exactness guarantees the streaming pipelines lean on — constant groups,
zero values under the symmetric mode, zero padding through `pad_quant_block`
— (c) the byte model (values + 8 bytes per group) the BENCH invariants
assert against, and (d) host/device dequant agreement.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.quant import (GROUP_ROWS, QuantBlock, dequant_rows,
                              dequantize_rows, encode_rows, expand_scales,
                              group_scales, max_quant_error, n_groups,
                              quant_bytes, quant_scale_bytes, quantize_block,
                              quantize_rows)


@pytest.mark.parametrize("symmetric", [False, True])
@pytest.mark.parametrize("n,p,group", [(64, 16, 32), (70, 9, 32), (5, 3, 8),
                                       (31, 4, 1)])
def test_roundtrip_error_bound(symmetric, n, p, group):
    x = np.random.default_rng(7).normal(size=(n, p)).astype(np.float32) * 3.0
    v, s = quantize_rows(x, group, symmetric=symmetric)
    assert v.dtype == np.int8 and s.shape == (n_groups(n, group), 2)
    assert np.abs(v.astype(np.int32)).max() <= 127
    xh = dequantize_rows(v, s, group)
    per_row_bound = np.repeat(s[:, 0], group)[:n, None] * 0.5
    assert (np.abs(xh - x) <= per_row_bound + 1e-7).all()
    assert np.abs(xh - x).max() <= max_quant_error(s) + 1e-7


def test_constant_groups_and_zeros_are_exact():
    x = np.full((48, 6), 0.731, np.float32)
    for symmetric in (False, True):
        v, s = quantize_rows(x, 16, symmetric=symmetric)
        if not symmetric:
            np.testing.assert_array_equal(dequantize_rows(v, s, 16), x)
    z = np.zeros((40, 5), np.float32)
    v, s = quantize_rows(z, 32, symmetric=True)
    assert (v == 0).all()
    np.testing.assert_array_equal(dequantize_rows(v, s, 32), z)


def test_affine_outperforms_symmetric_on_shifted_data():
    """The affine zero-point is the reason stage 2 uses it: one-sided data
    (RBF-featureish, all positive) wastes half the symmetric range."""
    rng = np.random.default_rng(3)
    x = (10.0 + rng.random((64, 8))).astype(np.float32)
    va, sa = quantize_rows(x, 32)
    vs, ss = quantize_rows(x, 32, symmetric=True)
    err_a = np.abs(dequantize_rows(va, sa, 32) - x).max()
    err_s = np.abs(dequantize_rows(vs, ss, 32) - x).max()
    assert err_a < err_s / 4


def test_device_dequant_matches_host():
    """Host and device dequant agree to FMA rounding (XLA may fuse the
    multiply-add; 1-ulp differences are expected and harmless — the codec's
    own error is ~5 orders of magnitude larger)."""
    x = np.random.default_rng(1).normal(size=(50, 12)).astype(np.float32)
    v, s = quantize_rows(x, 8)
    host = dequantize_rows(v, s, 8)
    dev = np.asarray(dequant_rows(jnp.asarray(v), jnp.asarray(s), 8))
    np.testing.assert_allclose(host, dev, rtol=1e-6, atol=1e-6)
    # per-row tables (group=1): the compacted cheap-epoch wire layout
    v1, s1 = quantize_rows(x, 1)
    np.testing.assert_allclose(
        dequantize_rows(v1, s1, 1),
        np.asarray(dequant_rows(jnp.asarray(v1), jnp.asarray(s1), 1)),
        rtol=1e-6, atol=1e-6)


def test_encode_rows_with_gathered_global_scales():
    """A row encoded under its global group scale decodes identically no
    matter which block it travels in — the invariant the streamed solver's
    shrinking compaction relies on."""
    x = np.random.default_rng(2).normal(size=(96, 7)).astype(np.float32)
    group = 32
    gs = group_scales(x, group)
    full_v = encode_rows(x, expand_scales(gs, group, 96))
    rows = np.array([3, 37, 40, 65, 95])
    gathered_v = encode_rows(x[rows], gs[rows // group])
    np.testing.assert_array_equal(gathered_v, full_v[rows])
    np.testing.assert_array_equal(
        dequantize_rows(gathered_v, gs[rows // group], 1),
        dequantize_rows(full_v, expand_scales(gs, group, 96), 1)[rows])


def test_byte_model():
    assert quant_bytes(96, 64, 32) == 96 * 64 + 3 * 8
    assert quant_bytes(70, 9, 32) == 70 * 9 + 3 * 8
    assert quant_scale_bytes(70, 32) == 3 * 8
    qb = quantize_block(np.ones((70, 9), np.float32), 32)
    assert qb.nbytes == quant_bytes(70, 9, 32)
    assert qb.scale_bytes == quant_scale_bytes(70, 32)
    assert qb.shape == (70, 9)
    # the ~4x headline at the default group
    assert quant_bytes(128, 64, GROUP_ROWS) * 3 < 128 * 64 * 4


def test_pad_quant_block_pads_exact_zero_groups():
    from repro.core.solver_stream import pad_quant_block
    x = np.random.default_rng(5).normal(size=(40, 6)).astype(np.float32)
    qb = quantize_block(x, 8)                       # 5 groups, aligned
    padded = pad_quant_block(qb, 64)
    assert padded.values.shape == (64, 6)
    assert padded.scales.shape == (8, 2)
    out = dequantize_rows(padded.values, padded.scales, 8)
    np.testing.assert_array_equal(out[:40], dequantize_rows(qb.values,
                                                            qb.scales, 8))
    np.testing.assert_array_equal(out[40:], np.zeros((24, 6), np.float32))


def test_empty_block():
    v, s = quantize_rows(np.zeros((0, 4), np.float32), 32)
    assert v.shape == (0, 4) and s.shape == (0, 2)
    assert quantize_block(np.zeros((0, 4), np.float32)).nbytes == 0
