"""CV-grid task farm: the whole (C x folds x pair) grid in one G stream.

Pins (a) `grid_search(farm=True)` == the per-cell serial loop — bit-equal
errors matrix and the same selected (gamma, C) cell; (b) engine-level
C-ladder parity: with every epoch a full pass the chained farm reproduces
the serial warm-started C loop's per-cell alphas AND epoch counts bit-for-
bit; (c) concurrent-mode cells are bit-identical to their cold solo solves
under the DEFAULT shrink schedule while the whole grid's stage-2 G H2D
bytes stay within 1.3x of ONE cell's pass set — the farm's headline; (d)
chain-aware task splitting keeps warm-start ladders on one device and the
2-device farm keeps the shared-pass byte invariance on chained grid tasks;
(e) the engine's host coordinate state is O(sum task sizes), never
O(T * n) — the memory model that lets T = |Cs| x folds x pairs scale.
"""
import dataclasses
import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest

import repro.core.solver_stream as ss
from repro.core import (KernelParams, SolverConfig, StreamConfig, TaskBatch,
                        balance_chain_split, build_cv_grid_tasks,
                        compute_factor, grid_search, kfold_masks,
                        solve_batch_streamed)
from repro.core.cv import build_cv_tasks
from repro.data import make_multiclass

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
CS = [1.0, 4.0, 16.0]


def _problem(n=360, classes=3, budget=64, seed=11, folds=2):
    x, y = make_multiclass(n, p=6, n_classes=classes, seed=seed)
    _, labels = np.unique(y, return_inverse=True)
    fac = compute_factor(jnp.asarray(x, jnp.float32),
                         KernelParams("rbf", gamma=0.2), budget)
    return np.asarray(fac.G), labels, kfold_masks(n, folds, seed=0)


# ------------------------------------------------------- task construction

def test_build_cv_grid_tasks_layout_and_chain():
    """Level-major layout: cell (ci, f, t) at ci * FP + f * n_pairs + t;
    the ladder chain links every cell to the SAME cell at the next C."""
    _, labels, val_masks = _problem()
    tasks, pairs, chain = build_cv_grid_tasks(labels, 3, CS, val_masks)
    FP = len(val_masks) * len(pairs)
    assert tasks.n_tasks == len(CS) * FP
    for ci, C in enumerate(CS):
        lvl, _ = build_cv_tasks(labels, 3, C, val_masks,
                                n_pad=tasks.idx.shape[1])
        sl = slice(ci * FP, (ci + 1) * FP)
        np.testing.assert_array_equal(tasks.idx[sl], lvl.idx)
        np.testing.assert_array_equal(tasks.c[sl], lvl.c)
    np.testing.assert_array_equal(np.asarray(chain[:2 * FP]),
                                  np.arange(2 * FP) + FP)
    assert np.all(np.asarray(chain[2 * FP:]) == -1)
    with pytest.raises(ValueError):
        build_cv_grid_tasks(labels, 3, [4.0, 1.0], val_masks)
    # no ladder -> concurrent roots, no chain
    _, _, none_chain = build_cv_grid_tasks(labels, 3, CS, val_masks,
                                           ladder=False)
    assert none_chain is None


def test_balance_chain_split_keeps_ladders_whole():
    """Warm-start ladders must not cross device shards (the successor is
    seeded from its predecessor's host alphas), and the split still LPT-
    balances by CHAIN weight — one fat chain lands alone."""
    counts = [100, 100, 5, 5, 5, 5]
    chain = np.asarray([1, -1, 3, -1, 5, -1], np.int64)   # 0->1, 2->3, 4->5
    parts = balance_chain_split(counts, chain, 2)
    assert sorted(np.concatenate(parts).tolist()) == list(range(6))
    fat = [p for p in parts if 0 in p]
    assert len(fat) == 1 and sorted(fat[0].tolist()) == [0, 1]
    loads = sorted(sum(counts[t] for t in p) for p in parts)
    assert loads == [20, 200]


# ------------------------------------------------------ grid_search parity

def test_grid_search_farm_matches_serial():
    """Farm vs pinned-serial grid_search: bit-equal errors matrix, same
    selected cell — with every epoch a full pass the in-engine C ladder is
    the serial warm-start loop in a different schedule."""
    x, y = make_multiclass(360, p=6, n_classes=3, seed=3)
    cfg = SolverConfig(tol=1e-2, max_epochs=200, full_pass_period=1)
    scfg = StreamConfig(tile_rows=96)
    kw = dict(budget=64, folds=2, config=cfg, stream=True,
              stream_config=scfg)
    serial = grid_search(x, y, [0.05, 0.2], CS, farm=False, **kw)
    farm = grid_search(x, y, [0.05, 0.2], CS, farm=True, **kw)
    np.testing.assert_array_equal(farm.errors, serial.errors)
    assert (farm.best_gamma, farm.best_C) == (serial.best_gamma,
                                              serial.best_C)
    assert farm.n_binary_solved == serial.n_binary_solved
    # the farm reports its per-gamma one-stream stats; serial has none
    assert serial.stream_stats is None and serial.bytes_h2d is None
    assert farm.stream_stats is not None and len(farm.stream_stats) == 2
    assert all(st is not None and st.epochs > 0 for st in farm.stream_stats)
    assert farm.bytes_h2d is not None and np.all(farm.bytes_h2d > 0)


def test_ladder_epochs_and_alphas_match_serial_chain():
    """Engine-level ladder parity under full_pass_period=1: per-cell alphas
    AND epoch counts are bit-equal to the serial ascending-C loop that
    warm-starts each cell from its predecessor."""
    G, labels, val_masks = _problem()
    cfg = SolverConfig(tol=1e-2, max_epochs=200, full_pass_period=1)
    scfg = StreamConfig(tile_rows=96)
    warm = None
    ser_alpha, ser_epochs = [], []
    for C in CS:
        tasks, pairs = build_cv_tasks(labels, 3, C, val_masks, warm=warm)
        res = solve_batch_streamed(G, tasks, cfg, stream_config=scfg)
        warm = res.alpha
        ser_alpha.append(np.asarray(res.alpha))
        ser_epochs.append(np.asarray(res.epochs))
    gtasks, pairs, chain = build_cv_grid_tasks(labels, 3, CS, val_masks)
    farm_cfg = dataclasses.replace(
        cfg, max_epochs=cfg.max_epochs * len(CS) + len(CS))
    fres = solve_batch_streamed(G, gtasks, farm_cfg, stream_config=scfg,
                                chain_next=chain)
    FP = len(val_masks) * len(pairs)
    for ci in range(len(CS)):
        sl = slice(ci * FP, (ci + 1) * FP)
        np.testing.assert_array_equal(np.asarray(fres.alpha)[sl],
                                      ser_alpha[ci])
        np.testing.assert_array_equal(np.asarray(fres.epochs)[sl],
                                      ser_epochs[ci])


def test_concurrent_farm_bit_equal_and_one_pass_set_of_g_bytes():
    """Concurrent mode (no ladder) under the DEFAULT shrink schedule: every
    cell's trajectory is bit-identical to its cold solo solve — windows
    restrict each task to its own rows — and the WHOLE grid's stage-2 G
    H2D bytes stay within 1.3x of the largest single cell's pass set."""
    G, labels, val_masks = _problem()
    cfg = SolverConfig(tol=1e-2, max_epochs=300)
    scfg = StreamConfig(tile_rows=96)
    cell_alpha, cell_epochs, cell_g = [], [], []
    for C in CS:
        tasks, pairs = build_cv_tasks(labels, 3, C, val_masks)
        res, st = solve_batch_streamed(G, tasks, cfg, stream_config=scfg,
                                       return_stats=True)
        cell_alpha.append(np.asarray(res.alpha))
        cell_epochs.append(np.asarray(res.epochs))
        cell_g.append(st.bytes_g)
    gtasks, pairs, chain = build_cv_grid_tasks(labels, 3, CS, val_masks,
                                               ladder=False)
    fres, fst = solve_batch_streamed(G, gtasks, cfg, stream_config=scfg,
                                     chain_next=chain, return_stats=True)
    FP = len(val_masks) * len(pairs)
    for ci in range(len(CS)):
        sl = slice(ci * FP, (ci + 1) * FP)
        np.testing.assert_array_equal(np.asarray(fres.alpha)[sl],
                                      cell_alpha[ci])
        np.testing.assert_array_equal(np.asarray(fres.epochs)[sl],
                                      cell_epochs[ci])
    # the acceptance bound: one G stream serves the whole grid
    assert fst.bytes_g <= 1.3 * max(cell_g), (fst.bytes_g, cell_g)
    assert fst.bytes_g > 0


# ------------------------------------------------------------ memory model

def test_host_state_is_o_sum_task_sizes_not_t_times_n():
    """T >> pairs regime: many small tasks over a large G must cost the
    engine O(sum task sizes) host state, NOT O(T * n) — the old global-
    coordinate layout would allocate six (T, n) arrays here."""
    n, rank, T, size = 4096, 8, 128, 16
    G = np.zeros((n, rank), np.float32)
    rng = np.random.default_rng(0)
    idx = np.stack([np.sort(rng.choice(n, size, replace=False))
                    for _ in range(T)]).astype(np.int32)
    tasks = TaskBatch(idx=jnp.asarray(idx),
                      y=jnp.ones((T, size), jnp.float32),
                      c=jnp.full((T, size), 4.0, jnp.float32),
                      alpha0=jnp.zeros((T, size), jnp.float32))
    eng = ss._Stage2Engine(G, tasks, SolverConfig(), StreamConfig(),
                           epoch_fn=ss.default_epoch_fn,
                           device=jax.devices()[0], tile=512)
    # well under even ONE (T, n) f32 array (= 4 * T * n bytes)
    assert eng.host_state_bytes < T * n, (eng.host_state_bytes, T * n)
    # and dominated by the task-local arrays, i.e. linear in sum sizes
    assert eng.host_state_bytes < 64 * T * size + 16 * T * (eng.n_blocks + 1)


# ------------------------------------------------------ multi-device farm

def test_grid_farm_2dev_shared_bytes_invariant():
    """2-device subprocess on CHAINED grid tasks: per-task results match the
    single-device farm bit-for-bit (chains never cross shards) and the
    shared reader's first-full-pass bytes are device-count independent."""
    code = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import (KernelParams, SolverConfig, StreamConfig,
                        build_cv_grid_tasks, compute_factor, kfold_masks,
                        solve_batch_streamed, solve_tasks_streamed)
from repro.data import make_multiclass

x, y = make_multiclass(360, p=6, n_classes=3, seed=11)
_, labels = np.unique(y, return_inverse=True)
fac = compute_factor(jnp.asarray(x, jnp.float32),
                     KernelParams("rbf", gamma=0.2), 64)
G = np.asarray(fac.G)
val_masks = kfold_masks(360, 2, seed=0)
gtasks, pairs, chain = build_cv_grid_tasks(labels, 3, [1.0, 4.0, 16.0],
                                           val_masks)
cfg = SolverConfig(tol=1e-2, max_epochs=650, full_pass_period=1)
scfg = StreamConfig(tile_rows=96)
devs = jax.local_devices()
assert len(devs) == 2

one, st1 = solve_batch_streamed(G, gtasks, cfg, stream_config=scfg,
                                chain_next=chain, return_stats=True)
two, st2 = solve_tasks_streamed(G, gtasks, cfg, devices=devs,
                                stream_config=scfg, chain_next=chain,
                                return_stats=True)
np.testing.assert_array_equal(np.asarray(two.alpha), np.asarray(one.alpha))
np.testing.assert_array_equal(np.asarray(two.epochs),
                              np.asarray(one.epochs))
assert st2.epoch_bytes[0] == st1.epoch_bytes[0], \
    (st2.epoch_bytes[0], st1.epoch_bytes[0])
assert len(st2.per_device) == 2
print("GRID-MESH-OK")
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=900, env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "GRID-MESH-OK" in out.stdout
