"""Objective helpers backing the polish stopping evidence:
`dual_objective`, `primal_objective`, `duality_gap` (dual_solver.py).

Weak duality (gap >= 0 for any feasible alpha), monotone gap decrease over
the solver trajectory, and gap -> ~0 at convergence.
"""
import jax.numpy as jnp
import numpy as np

from repro.core.dual_solver import (SolverConfig, dual_objective, duality_gap,
                                    primal_objective, solve_one)
from repro.core.kernel_fn import KernelParams
from repro.core.nystrom import compute_factor


def _problem(rng, n=400, C=4.0, budget=128):
    x = rng.normal(size=(n, 5)).astype(np.float32)
    y = np.where(x[:, 0] * x[:, 1] + 0.3 * x[:, 2] > 0, 1.0, -1.0) \
        .astype(np.float32)
    fac = compute_factor(jnp.asarray(x), KernelParams("rbf", gamma=0.7),
                         budget)
    idx = jnp.arange(n, dtype=jnp.int32)
    c = jnp.full((n,), C, jnp.float32)
    return fac.G, idx, jnp.asarray(y), c


def test_gap_nonnegative_for_feasible_alpha(rng):
    """Weak duality: P(w(alpha)) - D(alpha) >= 0 for ANY alpha in the box."""
    G, idx, y, c = _problem(rng)
    for seed in range(3):
        a = jnp.asarray(np.random.default_rng(seed)
                        .uniform(0.0, 4.0, size=c.shape).astype(np.float32))
        gap = float(duality_gap(G, idx, y, c, a))
        assert gap >= -1e-3, gap
    # alpha = 0: D = 0, P = C * n (all margins violated by exactly 1)
    gap0 = float(duality_gap(G, idx, y, c, jnp.zeros_like(c)))
    assert abs(gap0 - 4.0 * c.shape[0]) < 1e-2 * 4.0 * c.shape[0]


def test_dual_objective_matches_solver(rng):
    G, idx, y, c = _problem(rng)
    res = solve_one(G, idx, y, c, jnp.zeros_like(c),
                    SolverConfig(tol=1e-3, max_epochs=2000))
    d = float(dual_objective(G, idx, y, res.alpha))
    assert abs(d - float(res.dual_obj)) < 1e-3 * (1.0 + abs(d))


def test_primal_objective_fields(rng):
    G, idx, y, c = _problem(rng, C=2.0)
    # padding rows (c = 0) must not count as real examples
    pad = 32
    idx_p = jnp.concatenate([idx, jnp.zeros((pad,), jnp.int32)])
    y_p = jnp.concatenate([y, jnp.ones((pad,))])
    c_p = jnp.concatenate([c, jnp.zeros((pad,))])
    w = jnp.zeros((G.shape[1],), jnp.float32)
    p, lam, n = primal_objective(G, idx_p, y_p, c_p, w)
    assert int(n) == c.shape[0]
    assert abs(float(lam) - 1.0 / (2.0 * c.shape[0])) < 1e-9
    # w = 0: every real margin is 0 -> hinge = 1 each -> P = C * n
    assert abs(float(p) - 2.0 * c.shape[0]) < 1e-3


def test_gap_monotone_decrease_over_epochs(rng):
    """The solver ascends the dual; the gap must (modulo float noise) shrink
    along the trajectory and end near zero."""
    G, idx, y, c = _problem(rng)
    checkpoints = [1, 4, 16, 64, 256]
    gaps, duals = [], []
    for e in checkpoints:
        res = solve_one(G, idx, y, c, jnp.zeros_like(c),
                        SolverConfig(tol=1e-9, max_epochs=e,
                                     full_pass_period=1))
        gaps.append(float(duality_gap(G, idx, y, c, res.alpha)))
        duals.append(float(res.dual_obj))
    # dual ascent is exactly monotone
    assert all(b >= a - 1e-4 * (1 + abs(a))
               for a, b in zip(duals, duals[1:])), duals
    # the gap decreases along the trajectory (small slack for the primal term)
    assert all(b <= a + 0.05 * gaps[0] for a, b in zip(gaps, gaps[1:])), gaps
    assert gaps[-1] < gaps[0] * 0.05


def test_gap_vanishes_at_convergence(rng):
    G, idx, y, c = _problem(rng)
    res = solve_one(G, idx, y, c, jnp.zeros_like(c),
                    SolverConfig(tol=1e-4, max_epochs=5000))
    assert float(res.violation) < 1e-4
    gap = float(duality_gap(G, idx, y, c, res.alpha))
    assert 0.0 <= gap + 1e-4
    assert gap < 1e-2 * abs(float(res.dual_obj))
