"""Property-based tests (hypothesis) on the system's invariants.

hypothesis is a DEV dependency (requirements-dev.txt, installed in CI) and
deliberately not a runtime one — the importorskip keeps the tier-1 suite
green on bare containers while CI runs the full property sweep.
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")  # dev dep; see requirements-dev.txt
import hypothesis.extra.numpy as hnp
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.core.block_cache import HotRowBlockCache, block_key
from repro.core.dual_solver import SolverConfig, solve_one
from repro.core.kernel_fn import KernelParams, gram
from repro.core.ovo import build_ovo_tasks, class_pairs, ovo_vote
from repro.core.quant import (GROUP_ROWS, dequantize_rows, encode_rows,
                              expand_scales, group_scales, max_quant_error,
                              quantize_rows)
from repro.core.solver_stream import block_windows
from repro.data import write_libsvm, read_libsvm

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=20,
    suppress_health_check=[hypothesis.HealthCheck.too_slow])
hypothesis.settings.load_profile("ci")

floats32 = st.floats(-3.0, 3.0, allow_nan=False, width=32)


@given(hnp.arrays(np.float32, hnp.array_shapes(min_dims=2, max_dims=2,
                                               min_side=2, max_side=12),
                  elements=floats32),
       st.floats(0.05, 3.0))
def test_rbf_gram_properties(x, gamma):
    """RBF gram: values in (0, 1], symmetric, unit diagonal."""
    K = np.asarray(gram(jnp.asarray(x), jnp.asarray(x),
                        KernelParams("rbf", gamma=gamma)))
    # exp can underflow to exactly 0 in float32 for far-apart points
    assert np.all(K <= 1.0 + 1e-5) and np.all(K >= 0.0)
    assert np.allclose(K, K.T, atol=1e-5)
    assert np.allclose(np.diag(K), 1.0, atol=1e-5)


@given(st.integers(8, 40), st.integers(2, 6), st.floats(0.1, 8.0),
       st.randoms(use_true_random=False))
def test_dual_solution_invariants(n, B, C, pyrng):
    """For any data: alpha stays in the box, dual never exceeds primal."""
    rng = np.random.default_rng(pyrng.randint(0, 2**31))
    G = jnp.asarray(rng.normal(size=(n, B)).astype(np.float32))
    y = jnp.asarray(rng.choice([-1.0, 1.0], size=n).astype(np.float32))
    c = jnp.full((n,), C, jnp.float32)
    res = solve_one(G, jnp.arange(n, dtype=jnp.int32), y, c,
                    jnp.zeros((n,), jnp.float32),
                    SolverConfig(tol=1e-2, max_epochs=300))
    a = np.asarray(res.alpha)
    assert a.min() >= -1e-6 and a.max() <= C + 1e-5
    from repro.core.dual_solver import duality_gap
    gap = float(duality_gap(G, jnp.arange(n, dtype=jnp.int32), y, c,
                            res.alpha))
    assert gap > -1e-2 * max(1.0, abs(float(res.dual_obj)))  # weak duality


@given(st.integers(2, 6), st.integers(10, 60),
       st.randoms(use_true_random=False))
def test_ovo_tasks_partition_pairs(n_classes, n, pyrng):
    """Every (pair, real row) has the right labels; padding is inert."""
    rng = np.random.default_rng(pyrng.randint(0, 2**31))
    labels = rng.integers(0, n_classes, size=n)
    # ensure every class appears
    labels[:n_classes] = np.arange(n_classes)
    tasks, pairs = build_ovo_tasks(labels, n_classes, C=1.0)
    assert len(pairs) == n_classes * (n_classes - 1) // 2
    for t, (a, b) in enumerate(pairs):
        c = np.asarray(tasks.c[t])
        idx = np.asarray(tasks.idx[t])
        y = np.asarray(tasks.y[t])
        real = c > 0
        assert real.sum() == np.isin(labels, [a, b]).sum()
        assert set(labels[idx[real]]) <= {a, b}
        np.testing.assert_array_equal(y[real] == 1.0, labels[idx[real]] == a)


@given(st.integers(2, 5), st.integers(1, 30),
       st.randoms(use_true_random=False))
def test_ovo_vote_in_range(n_classes, m, pyrng):
    rng = np.random.default_rng(pyrng.randint(0, 2**31))
    pairs = class_pairs(n_classes)
    d = rng.normal(size=(m, len(pairs)))
    pred = ovo_vote(d, pairs, n_classes)
    assert pred.shape == (m,)
    assert pred.min() >= 0 and pred.max() < n_classes


# ------------------------------------------------- int8 wire codec (quant)

@given(hnp.arrays(np.float32, hnp.array_shapes(min_dims=2, max_dims=2,
                                               min_side=1, max_side=64),
                  elements=st.floats(-50, 50, allow_nan=False, width=32)),
       st.sampled_from([1, 2, 8, GROUP_ROWS]),
       st.booleans())
def test_quant_roundtrip_error_bound(x, group, symmetric):
    """For ANY block: the decode error never exceeds the bound the scale
    table promises (half a quantisation step per group), and constant groups
    round-trip exactly."""
    vals, scales = quantize_rows(x, group, symmetric=symmetric)
    out = dequantize_rows(vals, scales, group)
    err = np.abs(out - x)
    bound = max_quant_error(scales)
    if symmetric:
        # symmetric mode spans absmax over 127 steps: one step of slack
        bound = 2 * bound
    assert err.max() <= bound + 1e-6 * max(1.0, np.abs(x).max())
    const = np.full((group, x.shape[1]), np.float32(x[0, 0]))
    v2, s2 = quantize_rows(const, group, symmetric=symmetric)
    if symmetric:
        np.testing.assert_allclose(dequantize_rows(v2, s2, group), const,
                                   atol=2 * max_quant_error(s2) + 1e-6)
    else:
        np.testing.assert_array_equal(dequantize_rows(v2, s2, group), const)


@given(st.integers(2, 40), st.integers(1, 16),
       st.sampled_from([1, 2, 4, 8]),
       st.randoms(use_true_random=False))
def test_quant_global_scale_gather_invariance(n, p, group, pyrng):
    """THE invariant the cached int8 tier rests on: encoding an ARBITRARY
    row gather under each row's GLOBAL group scale decodes bit-identically
    to the rows' in-place encoding — so a compacted (or cached) block and a
    shared-pass block carry the same decoded values for the same rows."""
    rng = np.random.default_rng(pyrng.randint(0, 2**31))
    G = rng.normal(size=(n, p)).astype(np.float32)
    gscales = group_scales(G, group)
    vals_full = encode_rows(G, expand_scales(gscales, group, n))
    full_dec = dequantize_rows(vals_full, gscales, group)
    rows = rng.choice(n, size=rng.integers(1, n + 1), replace=False)
    srow = gscales[rows // group]                   # per-row global entries
    vals_gather = encode_rows(G[rows], srow)
    gather_dec = vals_gather.astype(np.float32) * srow[:, 0:1] + srow[:, 1:2]
    np.testing.assert_array_equal(vals_gather, vals_full[rows])
    np.testing.assert_array_equal(gather_dec, full_dec[rows])


# ------------------------------------- task-local searchsorted windows

@given(st.integers(1, 400), st.integers(1, 64), st.floats(0.0, 1.0),
       st.randoms(use_true_random=False))
def test_block_windows_roundtrip(n, tile, density, pyrng):
    """For ANY (row count, tile size, task row subset) — ragged last tile,
    empty windows, empty tasks included: every window's rows belong to its
    block, block-local rows stay in [0, tile), and re-assembling
    b * tile + local over all blocks reproduces the task's sorted global
    ids exactly (the global <-> local coordinate roundtrip the streamed
    engine's O(sum task sizes) state rests on)."""
    rng = np.random.default_rng(pyrng.randint(0, 2**31))
    k = int(round(density * n))
    ids = np.sort(rng.choice(n, size=k, replace=False)).astype(np.int64)
    n_blocks = -(-n // tile)
    bounds = block_windows(ids, tile, n_blocks)
    assert bounds.shape == (n_blocks + 1,)
    assert bounds[0] == 0 and bounds[-1] == len(ids)
    assert np.all(np.diff(bounds) >= 0)           # windows partition ids
    rebuilt = []
    for b in range(n_blocks):
        lo, hi = int(bounds[b]), int(bounds[b + 1])
        win = ids[lo:hi]
        local = win - b * tile
        assert np.all((local >= 0) & (local < tile))
        # rows outside the window really are outside the block
        others = np.concatenate([ids[:lo], ids[hi:]])
        assert not np.any((others >= b * tile) & (others < (b + 1) * tile))
        rebuilt.append(b * tile + local)
    np.testing.assert_array_equal(np.concatenate(rebuilt) if rebuilt
                                  else np.empty(0, np.int64), ids)


# -------------------------------------------- hot-row block cache planning

_plan_strategy = st.lists(
    st.tuples(st.integers(0, 1 << 16),      # block nbytes
              st.floats(0, 1e6, allow_nan=False)),   # violation recency
    min_size=0, max_size=32)


@given(_plan_strategy, st.integers(0, 1 << 18),
       st.randoms(use_true_random=False))
def test_cache_never_exceeds_budget_and_hits_subset_of_plan(blocks, budget,
                                                            pyrng):
    """For ANY block list / budget / lookup order: resident bytes never
    exceed the budget, stored entries are always a subset of the planned pin
    set, and re-planning evicts exactly the fallen-out keys."""
    rng = np.random.default_rng(pyrng.randint(0, 2**31))
    cache = HotRowBlockCache(budget)
    keys = [block_key(np.asarray([i]), "f32") for i in range(len(blocks))]
    sizes = [b[0] for b in blocks]
    scores = [b[1] for b in blocks]
    planned = cache.plan(keys, sizes, scores)
    assert sum(nb for k, nb in zip(keys, sizes) if k in planned) <= budget
    for i in rng.permutation(len(blocks)):
        cache.put(keys[i], f"payload-{i}", sizes[i])
        assert cache.resident_bytes <= budget
    hit = {k for k in keys if cache.lookup(k) is not None}
    assert hit <= planned                       # hit set subset of pin set
    assert cache.resident_bytes <= cache.peak_resident_bytes <= budget
    # a planned block is never rejected for space: the plan pre-reserved it
    assert hit == planned
    # re-plan with half the blocks: survivors keep entries, the rest evict
    half = len(blocks) // 2
    planned2 = cache.plan(keys[:half], sizes[:half], scores[:half])
    for k in keys:
        if cache.lookup(k) is not None:
            assert k in planned2
    assert cache.resident_bytes <= budget
    frac = cache.planned_fraction(keys[:half], sizes[:half])
    assert 0.0 <= frac <= 1.0


@given(st.lists(st.integers(1, 100), min_size=1, max_size=16),
       st.randoms(use_true_random=False))
def test_cache_plan_prefers_hotter_blocks(sizes, pyrng):
    """With a budget that cannot hold everything, every pinned block is at
    least as hot (lower score) as every unpinned one of equal size-or-
    smaller feasibility — concretely: the pin set under equal sizes is a
    prefix of the score order."""
    rng = np.random.default_rng(pyrng.randint(0, 2**31))
    nb = len(sizes)
    size = 10                                    # equal sizes isolate order
    scores = rng.permutation(nb).astype(float)
    keys = [block_key(np.asarray([i]), "int8") for i in range(nb)]
    budget = size * max(1, nb // 2)
    cache = HotRowBlockCache(budget)
    planned = cache.plan(keys, [size] * nb, list(scores))
    k_fit = budget // size
    want = {keys[i] for i in np.argsort(scores, kind="stable")[:k_fit]}
    assert planned == want


@given(hnp.arrays(np.float32, hnp.array_shapes(min_dims=2, max_dims=2,
                                               min_side=1, max_side=8),
                  elements=st.floats(-100, 100, allow_nan=False, width=16)),
       st.randoms(use_true_random=False))
def test_libsvm_roundtrip(x, pyrng):
    import tempfile, os
    rng = np.random.default_rng(pyrng.randint(0, 2**31))
    y = rng.integers(0, 3, size=x.shape[0])
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "t.svm")
        write_libsvm(path, x, y)
        csr = read_libsvm(path, n_features=x.shape[1])
        np.testing.assert_allclose(csr.densify(), x, rtol=1e-3, atol=1e-4)
        np.testing.assert_array_equal(csr.labels.astype(int), y)
