"""Property-based tests (hypothesis) on the system's invariants."""
import pytest

hypothesis = pytest.importorskip("hypothesis")  # not baked into the container
import hypothesis.extra.numpy as hnp
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.core.dual_solver import SolverConfig, solve_one
from repro.core.kernel_fn import KernelParams, gram
from repro.core.ovo import build_ovo_tasks, class_pairs, ovo_vote
from repro.data import write_libsvm, read_libsvm

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=20,
    suppress_health_check=[hypothesis.HealthCheck.too_slow])
hypothesis.settings.load_profile("ci")

floats32 = st.floats(-3.0, 3.0, allow_nan=False, width=32)


@given(hnp.arrays(np.float32, hnp.array_shapes(min_dims=2, max_dims=2,
                                               min_side=2, max_side=12),
                  elements=floats32),
       st.floats(0.05, 3.0))
def test_rbf_gram_properties(x, gamma):
    """RBF gram: values in (0, 1], symmetric, unit diagonal."""
    K = np.asarray(gram(jnp.asarray(x), jnp.asarray(x),
                        KernelParams("rbf", gamma=gamma)))
    # exp can underflow to exactly 0 in float32 for far-apart points
    assert np.all(K <= 1.0 + 1e-5) and np.all(K >= 0.0)
    assert np.allclose(K, K.T, atol=1e-5)
    assert np.allclose(np.diag(K), 1.0, atol=1e-5)


@given(st.integers(8, 40), st.integers(2, 6), st.floats(0.1, 8.0),
       st.randoms(use_true_random=False))
def test_dual_solution_invariants(n, B, C, pyrng):
    """For any data: alpha stays in the box, dual never exceeds primal."""
    rng = np.random.default_rng(pyrng.randint(0, 2**31))
    G = jnp.asarray(rng.normal(size=(n, B)).astype(np.float32))
    y = jnp.asarray(rng.choice([-1.0, 1.0], size=n).astype(np.float32))
    c = jnp.full((n,), C, jnp.float32)
    res = solve_one(G, jnp.arange(n, dtype=jnp.int32), y, c,
                    jnp.zeros((n,), jnp.float32),
                    SolverConfig(tol=1e-2, max_epochs=300))
    a = np.asarray(res.alpha)
    assert a.min() >= -1e-6 and a.max() <= C + 1e-5
    from repro.core.dual_solver import duality_gap
    gap = float(duality_gap(G, jnp.arange(n, dtype=jnp.int32), y, c,
                            res.alpha))
    assert gap > -1e-2 * max(1.0, abs(float(res.dual_obj)))  # weak duality


@given(st.integers(2, 6), st.integers(10, 60),
       st.randoms(use_true_random=False))
def test_ovo_tasks_partition_pairs(n_classes, n, pyrng):
    """Every (pair, real row) has the right labels; padding is inert."""
    rng = np.random.default_rng(pyrng.randint(0, 2**31))
    labels = rng.integers(0, n_classes, size=n)
    # ensure every class appears
    labels[:n_classes] = np.arange(n_classes)
    tasks, pairs = build_ovo_tasks(labels, n_classes, C=1.0)
    assert len(pairs) == n_classes * (n_classes - 1) // 2
    for t, (a, b) in enumerate(pairs):
        c = np.asarray(tasks.c[t])
        idx = np.asarray(tasks.idx[t])
        y = np.asarray(tasks.y[t])
        real = c > 0
        assert real.sum() == np.isin(labels, [a, b]).sum()
        assert set(labels[idx[real]]) <= {a, b}
        np.testing.assert_array_equal(y[real] == 1.0, labels[idx[real]] == a)


@given(st.integers(2, 5), st.integers(1, 30),
       st.randoms(use_true_random=False))
def test_ovo_vote_in_range(n_classes, m, pyrng):
    rng = np.random.default_rng(pyrng.randint(0, 2**31))
    pairs = class_pairs(n_classes)
    d = rng.normal(size=(m, len(pairs)))
    pred = ovo_vote(d, pairs, n_classes)
    assert pred.shape == (m,)
    assert pred.min() >= 0 and pred.max() < n_classes


@given(hnp.arrays(np.float32, hnp.array_shapes(min_dims=2, max_dims=2,
                                               min_side=1, max_side=8),
                  elements=st.floats(-100, 100, allow_nan=False, width=16)),
       st.randoms(use_true_random=False))
def test_libsvm_roundtrip(x, pyrng):
    import tempfile, os
    rng = np.random.default_rng(pyrng.randint(0, 2**31))
    y = rng.integers(0, 3, size=x.shape[0])
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "t.svm")
        write_libsvm(path, x, y)
        csr = read_libsvm(path, n_features=x.shape[1])
        np.testing.assert_allclose(csr.densify(), x, rtol=1e-3, atol=1e-4)
        np.testing.assert_array_equal(csr.labels.astype(int), y)
