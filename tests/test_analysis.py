"""HLO collective parser + roofline math unit tests."""
import numpy as np

from repro.analysis.hlo import collective_stats, _shape_bytes
from repro.analysis.roofline import analyze_record, model_flops_per_device


def test_shape_bytes():
    assert _shape_bytes("bf16[8,128]{1,0}") == 8 * 128 * 2
    assert _shape_bytes("f32[100]") == 400
    assert _shape_bytes("(f32[4], s32[2])") == 16 + 8
    assert _shape_bytes("pred[]") == 1


def test_collective_stats_parses_and_weights():
    hlo = """
  %ag = bf16[16,1024]{1,0} all-gather(%x), replica_groups=...
  %ar = f32[256]{0} all-reduce(%y), to_apply=%add
  ROOT %t = (f32[8,8]{1,0}, f32[8,8]{1,0}) all-to-all(%a, %b)
  %cp = f32[64]{0} collective-permute(%z)
  %dot = f32[128,128]{1,0} dot(%p, %q)
"""
    st = collective_stats(hlo)
    assert st["by_kind"]["all-gather"]["count"] == 1
    assert st["by_kind"]["all-gather"]["bytes"] == 16 * 1024 * 2
    assert st["by_kind"]["all-reduce"]["bytes"] == 1024
    assert st["by_kind"]["all-to-all"]["bytes"] == 2 * 64 * 4
    assert st["total_count"] == 4
    # all-reduce weighted x2
    expect = 2 * 1024 + 16 * 1024 * 2 + 512 + 256
    assert st["weighted_bytes"] == expect


def test_collective_stats_ignores_start_done_double_count():
    hlo = "%ag = bf16[4,4]{1,0} all-gather-start(%x)\n"
    st = collective_stats(hlo)
    assert st["by_kind"]["all-gather"]["count"] == 1


def test_analyze_record_terms():
    rec = {
        "status": "ok", "arch": "qwen3-0.6b", "shape": "train_4k",
        "mesh": "pod16x16", "mode": "train",
        "flops": 197e12, "bytes_accessed": 819e9, "collective_bytes": 50e9,
        "memory": {"temp_bytes": 2**30, "argument_bytes": 2**30},
    }
    row = analyze_record(rec)
    assert abs(row["compute_s"] - 1.0) < 1e-9
    assert abs(row["memory_s"] - 1.0) < 1e-9
    assert abs(row["collective_s"] - 1.0) < 1e-9
    assert row["dominant"] in ("compute", "memory", "collective")


def test_model_flops_modes():
    t = model_flops_per_device("qwen3-0.6b", "train_4k", 256)
    p = model_flops_per_device("qwen3-0.6b", "prefill_32k", 256)
    d = model_flops_per_device("qwen3-0.6b", "decode_32k", 256)
    assert t > p > d > 0
    # MoE uses ACTIVE params: kimi 1T total but ~33B active
    moe = model_flops_per_device("kimi-k2-1t-a32b", "train_4k", 256)
    from repro.configs import get_config
    cfg = get_config("kimi-k2-1t-a32b")
    dense_equiv = 6 * cfg.param_count() * 256 * 4096 / 256
    assert moe < dense_equiv / 10


def test_analyze_skips_failures():
    assert analyze_record({"status": "FAIL"}) is None
    assert analyze_record({"status": "ok"}) is None  # no probe data
