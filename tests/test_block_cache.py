"""Device-resident hot-row block cache (core/block_cache.py).

Pins down the cache's two contracts byte-exactly:

(a) **Trajectory exactness** — caching is invisible to the optimiser:
    cached == uncached (bit-exact: the cached device arrays ARE the arrays
    the miss path would have put) == monolithic (existing float tolerances),
    including shrinking, warm starts, every wire dtype, and ragged tiles.
(b) **Byte accounting** — every compacted cheap-epoch G block lands in
    exactly one of hit/miss, so
        cached.bytes_hit + cached.bytes_miss == uncached.bytes_miss
        cached.bytes_h2d == uncached.bytes_h2d - cached.bytes_hit
    hold EXACTLY, warm cache-hit cheap epochs do ZERO G H2D (put-spy: only
    1-D task vectors cross the bus, under the transfer guard), eviction
    under a deliberately tiny budget never exceeds it, and the farm's
    device-count-independent shared-reader invariant survives caching.
"""
import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest

import repro.core.solver_stream as ss
from repro.core import (HotRowBlockCache, KernelParams, SolverConfig,
                        StreamConfig, compute_factor, solve_batch,
                        solve_batch_streamed, stage2_cache_budget)
from repro.core.block_cache import block_key, violation_recency_scores
from repro.core.ovo import build_ovo_tasks
from repro.data import make_multiclass

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
KP = KernelParams("rbf", gamma=0.25)

WIRES = ("f32", "bf16", "int8")


def _problem(n=360, classes=3, budget=64, C=4.0, seed=9):
    x, y = make_multiclass(n, p=6, n_classes=classes, seed=seed)
    _, labels = np.unique(y, return_inverse=True)
    fac = compute_factor(jnp.asarray(x, jnp.float32), KP, budget)
    tasks, _ = build_ovo_tasks(labels, classes, C)
    return np.asarray(fac.G), tasks, labels


def _pair(G, tasks, cfg, scfg_kw):
    """One solve with the cache on and one with it off, plus stats."""
    r_on, s_on = solve_batch_streamed(
        G, tasks, cfg, return_stats=True,
        stream_config=StreamConfig(**scfg_kw))
    r_off, s_off = solve_batch_streamed(
        G, tasks, cfg, return_stats=True,
        stream_config=StreamConfig(cache_blocks=False, **scfg_kw))
    return r_on, s_on, r_off, s_off


def _assert_identical(a, b):
    """Cached vs uncached is BIT-exact, not merely close: the hit path
    decodes the same device arrays the miss path would have shipped."""
    np.testing.assert_array_equal(a.alpha, b.alpha)
    np.testing.assert_array_equal(np.asarray(a.w), np.asarray(b.w))
    np.testing.assert_array_equal(a.epochs, b.epochs)
    np.testing.assert_array_equal(a.violation, b.violation)


# ------------------------------------------------- trajectory exactness

@pytest.mark.parametrize("wire", WIRES)
@pytest.mark.parametrize("tile", [64, 56])       # divisible and ragged
def test_cached_equals_uncached_equals_monolithic(wire, tile):
    G, tasks, _ = _problem()
    cfg = SolverConfig(tol=1e-3, max_epochs=300)
    r_on, s_on, r_off, s_off = _pair(G, tasks, cfg,
                                     dict(tile_rows=tile, block_dtype=wire))
    _assert_identical(r_on, r_off)
    assert s_on.bytes_hit > 0 and s_on.cache_hits > 0
    assert s_off.bytes_hit == 0 and s_off.cache_hits == 0
    if wire == "f32":
        mono = solve_batch(jnp.asarray(G), tasks, cfg)
        np.testing.assert_allclose(r_on.alpha, np.asarray(mono.alpha),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(r_on.w, np.asarray(mono.w),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_array_equal(r_on.epochs, np.asarray(mono.epochs))


def test_cached_warm_start_exactness():
    """The C-grid warm-start pattern hits the cache too: the init pass and
    full passes are shared (uncached) but the compacted cheap epochs of the
    warm solve still serve from HBM, with the trajectory unchanged."""
    G, tasks, labels = _problem(C=1.0)
    cfg = SolverConfig(tol=1e-3, max_epochs=300)
    first = solve_batch_streamed(G, tasks, cfg,
                                 stream_config=StreamConfig(tile_rows=64))
    warm = [np.asarray(a) for a in np.asarray(first.alpha)]
    tasks4, _ = build_ovo_tasks(labels, 3, 4.0, alpha0=warm)
    r_on, s_on, r_off, _ = _pair(G, tasks4, cfg, dict(tile_rows=64))
    _assert_identical(r_on, r_off)
    assert s_on.bytes_hit > 0


# --------------------------------------------------- accounting identities

@pytest.mark.parametrize("wire", WIRES)
def test_hit_miss_accounting_identities(wire):
    """Exact complementarity: the cache only redirects compacted cheap-epoch
    G bytes, so hit + miss with caching equals the miss (= all-compacted-G)
    bytes without, and the H2D saving is exactly `bytes_hit`.  Per-epoch
    breakouts sum back to the totals and align with `epoch_bytes`."""
    G, tasks, _ = _problem(n=420)
    cfg = SolverConfig(tol=1e-3, max_epochs=300)
    _, s_on, _, s_off = _pair(G, tasks, cfg,
                              dict(tile_rows=64, block_dtype=wire))
    assert s_on.bytes_hit + s_on.bytes_miss == s_off.bytes_miss
    assert s_on.bytes_h2d == s_off.bytes_h2d - s_on.bytes_hit
    assert sum(s_on.epoch_hit_bytes) == s_on.bytes_hit
    assert sum(s_on.epoch_miss_bytes) == s_on.bytes_miss
    assert len(s_on.epoch_hit_bytes) == len(s_on.epoch_miss_bytes)
    # warm compacted epochs are >= 90% cache-hit by bytes (the acceptance
    # bar): after the first post-compaction (miss) epoch, everything hits
    rates = s_on.epoch_hit_rate
    warm = [r for r, h, m in zip(rates, s_on.epoch_hit_bytes,
                                 s_on.epoch_miss_bytes) if h + m > 0 and h > 0]
    assert warm and max(warm) == 1.0
    assert s_on.bytes_hit >= 9 * s_on.bytes_miss // 2  # hits dominate overall
    # block counters tell the same story as the byte counters
    assert s_on.cache_hits > 0 and s_on.cache_misses > 0
    assert s_off.cache_misses == 0   # caching off: counter never engages
    # the pinned residency is bounded by the wire size of one union
    assert 0 < s_on.cache_resident_bytes <= s_on.tile_rows * G.shape[1] * 4 \
        * (len(s_on.epoch_bytes) + G.shape[0] // s_on.tile_rows + 1)


def test_warm_cheap_epoch_zero_g_h2d(monkeypatch):
    """THE tentpole assertion: once the cache is warm, a compacted cheap
    epoch moves ZERO G bytes host-to-device — every `_put` during the epoch
    is a 1-D task vector, asserted under the H2D transfer guard (so an
    implicit fallback transfer would raise, not slip through)."""
    G, tasks, _ = _problem()
    cfg = SolverConfig(tol=1e-8, max_epochs=40)   # never converges: engine
    scfg = StreamConfig(tile_rows=64)             # state survives the drive
    eng = ss._Stage2Engine(G, tasks, cfg, scfg,
                           epoch_fn=ss.smo_epoch_oracle, device=None,
                           tile=64)
    ss.drive_streamed_engines([eng], G, cfg, scfg, tile=64)
    assert eng.act is not None, "no compaction happened — grow max_epochs"
    assert eng.cache is not None and eng.cache.n_entries > 0
    hit0, miss0 = eng.stats.bytes_hit, eng.stats.bytes_miss

    puts = []
    orig = ss._put

    def spy(a, device=None):
        puts.append(np.shape(a))
        return orig(a, device)

    monkeypatch.setattr(ss, "_put", spy)
    guard = getattr(jax, "transfer_guard_host_to_device", None)
    if guard is None:
        pytest.skip("no transfer guard in this jax")
    with guard("disallow"):
        eng.run_cheap_epoch()
    assert all(len(s) == 1 for s in puts), \
        f"G block crossed the bus during a warm cheap epoch: {puts}"
    assert eng.stats.bytes_miss == miss0          # zero G H2D...
    assert eng.stats.bytes_hit == hit0 + sum(eng._act_sizes)  # ...all hits


# ---------------------------------------------------------------- eviction

@pytest.mark.parametrize("wire", ["f32", "int8"])
def test_tiny_budget_evicts_and_stays_exact(wire):
    """A budget worth ~2 blocks forces partial pinning: the residency never
    exceeds the budget, the cold tail keeps streaming (misses persist), and
    the trajectory is still bit-identical to the uncached solve."""
    G, tasks, _ = _problem(n=420)
    rank = G.shape[1]
    tile = 64
    blk = (tile * rank + tile * 8) if wire == "int8" else tile * rank * 4
    budget = 2 * blk
    cfg = SolverConfig(tol=1e-3, max_epochs=300)
    r_on, s_on, r_off, s_off = _pair(
        G, tasks, cfg, dict(tile_rows=tile, block_dtype=wire,
                            cache_budget_bytes=budget))
    _assert_identical(r_on, r_off)
    assert 0 < s_on.cache_resident_bytes <= budget
    assert s_on.bytes_hit + s_on.bytes_miss == s_off.bytes_miss
    assert s_on.bytes_hit > 0
    # partial pinning: unlike the unbounded cache, misses keep flowing after
    # the warm-up epoch whenever the union needs more than 2 blocks
    assert s_on.bytes_miss > s_off.bytes_miss // len(s_off.epoch_bytes)


def test_zero_budget_is_cache_off():
    """`cache_budget_bytes=0` pins nothing — byte-for-byte the uncached
    stream, with the cache counters flat."""
    G, tasks, _ = _problem()
    cfg = SolverConfig(tol=1e-3, max_epochs=200)
    r_on, s_on, r_off, s_off = _pair(G, tasks, cfg,
                                     dict(tile_rows=64,
                                          cache_budget_bytes=0))
    _assert_identical(r_on, r_off)
    assert s_on.bytes_hit == 0 and s_on.cache_resident_bytes == 0
    assert s_on.bytes_h2d == s_off.bytes_h2d


# ------------------------------------------------------- planning helpers

def test_stage2_cache_budget_model():
    cfg = StreamConfig(device_budget_bytes=1 << 22)
    b = stage2_cache_budget(64, 3, 256, cfg.prefetch, cfg)
    assert b == (cfg.device_budget_bytes
                 - ss.stage2_resident_bytes(64, 3)
                 - cfg.prefetch * ss.stage2_block_bytes(256, 64, 3))
    # explicit budget wins; disabled or over-committed models floor at 0
    cfg_x = StreamConfig(cache_budget_bytes=12345)
    assert stage2_cache_budget(64, 3, 256, 2, cfg_x) == 12345
    assert stage2_cache_budget(64, 3, 256, 2,
                               StreamConfig(cache_blocks=False)) == 0
    assert stage2_cache_budget(512, 100, 4096, 8,
                               StreamConfig(device_budget_bytes=1 << 10)) == 0
    # an explicit carve-out shrinks the auto tile (cache residency is real)
    roomy = StreamConfig(device_budget_bytes=1 << 22)
    carved = StreamConfig(device_budget_bytes=1 << 22,
                          cache_budget_bytes=3 << 20)
    assert ss.auto_tile_rows(10_000, 128, 3, carved) \
        < ss.auto_tile_rows(10_000, 128, 3, roomy)
    # ...but only while caching is on
    carved_off = StreamConfig(device_budget_bytes=1 << 22,
                              cache_budget_bytes=3 << 20, cache_blocks=False)
    assert ss.auto_tile_rows(10_000, 128, 3, carved_off) \
        == ss.auto_tile_rows(10_000, 128, 3, roomy)


def test_violation_recency_ranks_hot_blocks_first():
    """The eviction policy: under pressure the plan keeps the blocks whose
    rows violated most recently (smallest unchanged counters)."""
    union = np.arange(8)
    u = np.array([[9, 9, 0, 1, 9, 9, 5, 5]])      # rows 2,3 hottest
    masks = np.ones((1, 8), bool)
    scores = violation_recency_scores(union, 2, u, masks)
    assert scores == [9.0, 0.0, 9.0, 5.0]         # per 2-row block
    cache = HotRowBlockCache(budget_bytes=200)
    keys = [block_key(union[s:s + 2], "f32") for s in range(0, 8, 2)]
    planned = cache.plan(keys, [100] * 4, scores)
    assert planned == {keys[1], keys[3]}          # hottest two fit
    # masked-out rows don't vote: a block whose hot rows all went inactive
    # scores colder than every block with a live row
    masks2 = masks.copy()
    masks2[0, 2:4] = False
    s2 = violation_recency_scores(union, 2, u, masks2)
    assert s2[1] > max(s2[0], s2[2], s2[3])


def test_cache_keys_survive_stable_recompaction():
    """Content-addressed keys: re-planning the SAME block list keeps the
    pinned entries (no eviction, immediate hits); a changed union drops
    exactly the stale ones."""
    cache = HotRowBlockCache(budget_bytes=1000)
    rows_a, rows_b = np.arange(0, 4), np.arange(4, 8)
    ka, kb = block_key(rows_a, "f32"), block_key(rows_b, "f32")
    cache.plan([ka, kb], [400, 400], [0.0, 1.0])
    assert cache.put(ka, "payload-a", 400)
    assert cache.put(kb, "payload-b", 400)
    cache.plan([ka, kb], [400, 400], [1.0, 0.0])   # same keys, new scores
    assert cache.evictions == 0 and cache.n_entries == 2
    assert cache.lookup(ka).payload == "payload-a"
    kc = block_key(np.arange(4, 9), "f32")
    cache.plan([ka, kc], [400, 400], [0.0, 0.0])   # b fell out of the union
    assert cache.evictions == 1 and cache.lookup(kb) is None
    assert cache.lookup(ka) is not None
    # same rows on a different wire are a different device payload
    assert block_key(rows_a, "int8") != ka


# ------------------------------------------------------ multi-device farm

def test_farm_shared_bytes_device_invariant_with_cache():
    """2-device subprocess: with caching ON (the default), per-pass shared
    `bytes_h2d` stays independent of device count — full passes never touch
    the per-device caches — while BOTH devices' caches serve their shard's
    compacted epochs, and the farm trajectory still matches monolithic."""
    code = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import (KernelParams, SolverConfig, StreamConfig,
                        compute_factor, solve_batch, solve_batch_streamed,
                        solve_tasks_streamed)
from repro.core.ovo import build_ovo_tasks
from repro.data import make_multiclass

x, y = make_multiclass(360, p=6, n_classes=4, seed=9)
_, labels = np.unique(y, return_inverse=True)
fac = compute_factor(jnp.asarray(x, jnp.float32),
                     KernelParams("rbf", gamma=0.25), 64)
G = np.asarray(fac.G)
tasks, _ = build_ovo_tasks(labels, 4, 4.0)
cfg = SolverConfig(tol=1e-4, max_epochs=300)
scfg = StreamConfig(tile_rows=96)
devs = jax.local_devices()
assert len(devs) == 2 and scfg.cache_blocks

mono = solve_batch(jnp.asarray(G), tasks, cfg)
one, st1 = solve_batch_streamed(G, tasks, cfg, stream_config=scfg,
                                return_stats=True)
two, st2 = solve_tasks_streamed(G, tasks, cfg, devices=devs,
                                stream_config=scfg, return_stats=True)
np.testing.assert_allclose(two.alpha, np.asarray(mono.alpha),
                           rtol=1e-4, atol=1e-5)
np.testing.assert_allclose(two.w, np.asarray(mono.w), rtol=1e-4, atol=1e-5)
np.testing.assert_array_equal(two.epochs, np.asarray(mono.epochs))
# shared reader invariant survives caching: identical first-full-pass bytes
assert st2.epoch_bytes[0] == st1.epoch_bytes[0], \
    (st2.epoch_bytes[0], st1.epoch_bytes[0])
# every device's cache engaged on its own shard
assert len(st2.per_device) == 2
assert all(s.bytes_hit > 0 for s in st2.per_device), \
    [(s.bytes_hit, s.bytes_miss) for s in st2.per_device]
assert st2.bytes_hit == sum(s.bytes_hit for s in st2.per_device)
assert st2.cache_resident_bytes == sum(s.cache_resident_bytes
                                       for s in st2.per_device)
print("CACHE-MESH-OK")
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=900, env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "CACHE-MESH-OK" in out.stdout


# --------------------------------------------------------- prefetch clamp

def test_prefetch_clamped_when_majority_cache_hit(monkeypatch):
    """Satellite fix: a first full pass that already compacted a
    majority-pinned union clamps the autotune cap to the current depth — a
    deeper H2D queue buys nothing when the coming epochs mostly hit HBM.
    Tasks covering only half the rows compact at epoch 0, so the clamp is
    observable through the tune_prefetch call."""
    from repro.core.dual_solver import TaskBatch
    rng = np.random.default_rng(11)
    n, rank = 320, 48
    G = rng.normal(size=(n, rank)).astype(np.float32) / np.sqrt(rank)
    n_pad = 160
    idx = np.zeros((1, n_pad), np.int32)
    idx[0] = np.arange(160)                        # half the rows: union < n
    y = np.ones((1, n_pad), np.float32)
    y[:, 80:] = -1.0
    c = np.full((1, n_pad), 4.0, np.float32)
    tasks = TaskBatch(idx=jnp.asarray(idx), y=jnp.asarray(y),
                      c=jnp.asarray(c), alpha0=jnp.zeros((1, n_pad)))
    calls = []

    def fake_tune(put, drain, prefetch, cap):
        calls.append((prefetch, cap))
        return prefetch

    monkeypatch.setattr(ss, "tune_prefetch", fake_tune)
    cfg = SolverConfig(tol=1e-3, max_epochs=60)
    solve_batch_streamed(G, tasks, cfg,
                         stream_config=StreamConfig(tile_rows=64,
                                                    prefetch_cap=9))
    assert calls == [(2, 2)], calls      # cap clamped to the current depth
    calls.clear()
    solve_batch_streamed(G, tasks, cfg,
                         stream_config=StreamConfig(tile_rows=64,
                                                    prefetch_cap=9,
                                                    cache_blocks=False))
    assert calls == [(2, 9)], calls      # cache off: the old cap survives
