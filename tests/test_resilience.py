"""Chaos suite for the fault-tolerance layer (core/resilience.py +
core/faults.py).

Every fault here is DETERMINISTIC — injected at a named site (reader block k,
device d's H2D at epoch e, the driver's epoch boundary) via `core.faults`,
never on a timer; stalls park on an Event the test releases.  The invariants
under test are the strong ones from the streaming stack:

  * kill at ANY epoch boundary + `resume` is BIT-equal to the uninterrupted
    run (monolithic streamed, int8 wire, C-ladder grid farm, multi-device);
  * a persistent device loss degrades the farm onto the survivors and
    converges to the SAME model as a clean run at the surviving device
    count, with per-pass G bytes unchanged (shared-reader invariant);
  * disabled resilience (no checkpoint dir, fail_fast default) is a no-op:
    zero snapshot calls, bit-identical outputs and byte counters.

Multi-device cases run in subprocesses (XLA_FLAGS must precede jax import,
same as tests/test_multidevice.py).
"""
import json
import os
import signal
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

import jax

from repro.core import (KernelParams, SolverConfig, StreamConfig,
                        build_cv_grid_tasks, build_ovo_tasks, compute_factor,
                        kfold_masks, solve_batch_streamed)
from repro.core import faults as F
from repro.core.resilience import WatchdogTimeout, WorkerStuckError
from repro.core.trace import Tracer
from repro.data import (BadRowError, IngestStats, make_multiclass,
                        read_libsvm, read_libsvm_blocks, write_libsvm)

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def run_sub(code: str, n_dev: int = 4, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.fixture(autouse=True)
def _no_leftover_plan():
    yield
    F.uninstall()


def _problem(n=300, classes=3, seed=1, budget=48, C=1.0):
    x, y = make_multiclass(n=n, n_classes=classes, seed=seed)
    _, labels = np.unique(y, return_inverse=True)
    fac = compute_factor(np.asarray(x, np.float32),
                         KernelParams("rbf", gamma=0.25), budget,
                         key=jax.random.PRNGKey(0))
    G = np.asarray(fac.G)
    tasks, _ = build_ovo_tasks(labels, classes, C)
    return G, tasks, labels


def _assert_same_result(a, b):
    np.testing.assert_array_equal(np.asarray(a.alpha), np.asarray(b.alpha))
    np.testing.assert_array_equal(np.asarray(a.w), np.asarray(b.w))
    np.testing.assert_array_equal(np.asarray(a.epochs), np.asarray(b.epochs))
    np.testing.assert_array_equal(np.asarray(a.violation),
                                  np.asarray(b.violation))


def _kill_resume_roundtrip(G, tasks, cfg, base_sc, tmp_path, kill_epoch=2,
                           chain=None):
    """Clean run vs (kill at epoch boundary -> resume); returns both."""
    clean, st_clean = solve_batch_streamed(
        G, tasks, cfg, stream_config=base_sc, return_stats=True,
        chain_next=chain)
    d = str(tmp_path / "ckpt")
    sc = dataclasses_replace(base_sc, checkpoint_dir=d, checkpoint_every=1)
    F.install(F.FaultPlan().add("epoch_boundary", kind="kill",
                                epoch=kill_epoch))
    try:
        with pytest.raises(F.SimulatedKill):
            solve_batch_streamed(G, tasks, cfg, stream_config=sc,
                                 chain_next=chain)
    finally:
        F.uninstall()
    assert any(f.startswith("step_") for f in os.listdir(d))
    sc2 = dataclasses_replace(sc, resume=True)
    res, st = solve_batch_streamed(G, tasks, cfg, stream_config=sc2,
                                   return_stats=True, chain_next=chain)
    return clean, st_clean, res, st


def dataclasses_replace(cfg, **kw):
    import dataclasses
    return dataclasses.replace(cfg, **kw)


# --------------------------------------------------------------------------
# kill / resume bit-parity
# --------------------------------------------------------------------------

def test_kill_resume_bit_parity_streamed(tmp_path):
    G, tasks, _ = _problem()
    cfg = SolverConfig(tol=1e-3, max_epochs=40)
    clean, st_clean, res, st = _kill_resume_roundtrip(
        G, tasks, cfg, StreamConfig(tile_rows=64), tmp_path)
    _assert_same_result(clean, res)
    # stats stitch across the kill: counters are for COMPLETED passes only
    assert st.epochs == st_clean.epochs
    assert st.full_passes == st_clean.full_passes
    assert st.epoch_bytes == st_clean.epoch_bytes


def test_kill_resume_bit_parity_int8(tmp_path):
    G, tasks, _ = _problem(seed=2)
    cfg = SolverConfig(tol=1e-3, max_epochs=40)
    clean, _, res, _ = _kill_resume_roundtrip(
        G, tasks, cfg, StreamConfig(tile_rows=64, block_dtype="int8"),
        tmp_path, kill_epoch=3)
    _assert_same_result(clean, res)


def test_kill_resume_bit_parity_ladder_farm(tmp_path):
    """The CV-grid C-ladder farm: dormant successors, pending w0-init passes,
    and warm-start seeding all live INSIDE the snapshot."""
    x, y = make_multiclass(n=240, n_classes=3, seed=1)
    _, labels = np.unique(y, return_inverse=True)
    fac = compute_factor(np.asarray(x, np.float32),
                         KernelParams("rbf", gamma=0.25), 48,
                         key=jax.random.PRNGKey(0))
    G = np.asarray(fac.G)
    masks = kfold_masks(len(labels), 2)
    gtasks, _, chain = build_cv_grid_tasks(labels, 3, [0.5, 2.0], masks,
                                           ladder=True)
    cfg = SolverConfig(tol=1e-3, max_epochs=30 * 2 + 2)
    clean, _, res, _ = _kill_resume_roundtrip(
        G, gtasks, cfg, StreamConfig(tile_rows=64), tmp_path, kill_epoch=4,
        chain=chain)
    _assert_same_result(clean, res)


def test_kill_resume_multidevice_farm(tmp_path):
    run_sub(r"""
import dataclasses, os, numpy as np, jax
from repro.core import (KernelParams, SolverConfig, StreamConfig,
                        build_ovo_tasks, compute_factor, solve_tasks_streamed)
from repro.core import faults as F
from repro.data import make_multiclass

x, y = make_multiclass(n=400, n_classes=4, seed=2)
_, labels = np.unique(y, return_inverse=True)
fac = compute_factor(np.asarray(x, np.float32),
                     KernelParams("rbf", gamma=0.25), 48,
                     key=jax.random.PRNGKey(0))
G = np.asarray(fac.G)
tasks, _ = build_ovo_tasks(labels, 4, 1.0)
cfg = SolverConfig(tol=1e-3, max_epochs=30)
devs = jax.devices()
assert len(devs) == 4

sc = StreamConfig(tile_rows=64)
clean, st0 = solve_tasks_streamed(G, tasks, cfg, devices=devs,
                                  stream_config=sc, return_stats=True)
d = %r
sck = dataclasses.replace(sc, checkpoint_dir=d, checkpoint_every=1)
F.install(F.FaultPlan().add("epoch_boundary", kind="kill", epoch=2))
try:
    solve_tasks_streamed(G, tasks, cfg, devices=devs, stream_config=sck)
    raise SystemExit("kill did not fire")
except F.SimulatedKill:
    pass
finally:
    F.uninstall()
assert any(f.startswith("step_") for f in os.listdir(d))
scr = dataclasses.replace(sck, resume=True)
res, st = solve_tasks_streamed(G, tasks, cfg, devices=devs,
                               stream_config=scr, return_stats=True)
np.testing.assert_array_equal(np.asarray(clean.alpha), np.asarray(res.alpha))
np.testing.assert_array_equal(np.asarray(clean.w), np.asarray(res.w))
np.testing.assert_array_equal(np.asarray(clean.epochs), np.asarray(res.epochs))
assert st.epochs == st0.epochs and st.epoch_bytes == st0.epoch_bytes
print("FARM-RESUME-OK")
""" % str(tmp_path / "ckpt"))


# --------------------------------------------------------------------------
# graceful degradation
# --------------------------------------------------------------------------

def test_transient_h2d_retry_is_bit_exact():
    G, tasks, _ = _problem(n=240, seed=3)
    cfg = SolverConfig(tol=1e-3, max_epochs=25)
    clean = solve_batch_streamed(G, tasks, cfg,
                                 stream_config=StreamConfig(tile_rows=64))
    tr = Tracer()
    sc = StreamConfig(tile_rows=64, fail_fast=False, max_retries=3,
                      retry_backoff=0.0, trace=tr)
    plan = F.install(F.FaultPlan().add("h2d", kind="transient", times=2,
                                       device="dev0", epoch=1))
    try:
        res = solve_batch_streamed(G, tasks, cfg, stream_config=sc)
    finally:
        F.uninstall()
    assert len(plan.fired) == 2   # both injected failures were consumed
    _assert_same_result(clean, res)
    inst = [e[2] for e in tr.events()
            if e[0] == "i" and e[1] in ("fault", "recovery")]
    assert inst.count("h2d_retry") == 2
    assert "h2d_retry_ok" in inst


def test_transient_fault_with_fail_fast_raises():
    G, tasks, _ = _problem(n=240, seed=3)
    cfg = SolverConfig(tol=1e-3, max_epochs=25)
    F.install(F.FaultPlan().add("h2d", kind="transient", device="dev0",
                                epoch=1))
    try:
        with pytest.raises(F.TransientH2DError):
            solve_batch_streamed(G, tasks, cfg,
                                 stream_config=StreamConfig(tile_rows=64))
    finally:
        F.uninstall()


def test_device_loss_degrades_to_clean_survivor_run(tmp_path):
    """Persistent loss of one of 4 devices: the farm re-shards onto the 3
    survivors from the last epoch-boundary snapshot and converges to the
    SAME model as a clean 3-device run — and the shared-reader per-pass
    `bytes_h2d` stays device-count invariant through the change."""
    run_sub(r"""
import numpy as np, jax
from repro.core import (KernelParams, SolverConfig, StreamConfig,
                        build_ovo_tasks, compute_factor, solve_tasks_streamed)
from repro.core import faults as F
from repro.data import make_multiclass

x, y = make_multiclass(n=400, n_classes=4, seed=2)
_, labels = np.unique(y, return_inverse=True)
fac = compute_factor(np.asarray(x, np.float32),
                     KernelParams("rbf", gamma=0.25), 48,
                     key=jax.random.PRNGKey(0))
G = np.asarray(fac.G)
tasks, _ = build_ovo_tasks(labels, 4, 1.0)
cfg = SolverConfig(tol=1e-3, max_epochs=30)
devs = jax.devices()

clean, st_clean = solve_tasks_streamed(
    G, tasks, cfg, devices=devs[:3],
    stream_config=StreamConfig(tile_rows=64), return_stats=True)

sc = StreamConfig(tile_rows=64, fail_fast=False)
F.install(F.FaultPlan().add("h2d", kind="persistent", device="dev3",
                            epoch=1))
try:
    res, st = solve_tasks_streamed(G, tasks, cfg, devices=devs,
                                   stream_config=sc, return_stats=True)
finally:
    F.uninstall()
np.testing.assert_array_equal(np.asarray(clean.alpha), np.asarray(res.alpha))
np.testing.assert_array_equal(np.asarray(clean.w), np.asarray(res.w))
np.testing.assert_array_equal(np.asarray(clean.epochs), np.asarray(res.epochs))
# byte accounting: every completed pass costs ONE G stream, before and
# after the device count changed mid-run
assert st.epoch_bytes == st_clean.epoch_bytes, (st.epoch_bytes,
                                                st_clean.epoch_bytes)
assert st.n_devices == 3
print("QUARANTINE-OK")
""")


def test_watchdog_raises_diagnostics_instead_of_hanging():
    from repro.core.distributed import _DeviceWorkers

    class E:   # engines are only identity keys for the worker queues
        pass

    engines = [E(), E()]
    gate = threading.Event()
    w = _DeviceWorkers(engines, depth=2, names=["dev0", "dev1"],
                       watchdog=0.25, join_timeout=5.0)
    try:
        w.submit(engines[0], gate.wait)   # dev0 starves the barrier
        w.submit(engines[1], lambda: None)
        with pytest.raises(WatchdogTimeout) as ei:
            w.barrier()
        assert "dev0" in str(ei.value)    # the diagnostic names the culprit
    finally:
        gate.set()
        w.close()


def test_close_reports_stuck_worker_threads():
    from repro.core.distributed import _DeviceWorkers

    class E:
        pass

    # raise path: a stuck worker is an error on the clean-exit close...
    gate = threading.Event()
    e = E()
    w = _DeviceWorkers([e], depth=2, names=["dev0"], join_timeout=0.1)
    try:
        w.submit(e, gate.wait)
        with pytest.raises(WorkerStuckError):
            w.close()
    finally:
        gate.set()
    # ...and a warning (never a masking raise) when closing during unwind
    gate2 = threading.Event()
    e2 = E()
    w2 = _DeviceWorkers([e2], depth=2, names=["dev0"], join_timeout=0.1)
    try:
        w2.submit(e2, gate2.wait)
        with pytest.warns(RuntimeWarning):
            w2.close(suppress=True)
    finally:
        gate2.set()


# --------------------------------------------------------------------------
# stage 1 resume
# --------------------------------------------------------------------------

def test_stage1_chunk_resume(tmp_path):
    from repro.core.streaming import compute_factor_streamed

    x, _ = make_multiclass(n=300, n_classes=3, seed=1)
    x = np.asarray(x, np.float32)
    kp = KernelParams("rbf", gamma=0.25)
    key = jax.random.PRNGKey(0)
    clean = compute_factor_streamed(x, kp, 48, key=key,
                                    config=StreamConfig(chunk_rows=64))
    d = str(tmp_path / "s1")
    sc = StreamConfig(chunk_rows=64, checkpoint_dir=d)
    F.install(F.FaultPlan().add("stage1", kind="io", chunk=2))
    try:
        with pytest.raises(OSError):
            compute_factor_streamed(x, kp, 48, key=key, config=sc)
    finally:
        F.uninstall()
    assert os.path.exists(os.path.join(d, "stage1_G.npy"))
    scr = StreamConfig(chunk_rows=64, checkpoint_dir=d, resume=True)
    fac = compute_factor_streamed(x, kp, 48, key=key, config=scr)
    assert fac.stage1_stats.chunks_skipped >= 1
    assert fac.stage1_stats.rows_resumed >= 64
    np.testing.assert_array_equal(np.asarray(clean.G), np.asarray(fac.G))


# --------------------------------------------------------------------------
# ingest validation
# --------------------------------------------------------------------------

def test_ingest_raises_on_bad_rows(tmp_path):
    p = str(tmp_path / "bad.svm")
    with open(p, "w") as f:
        f.write("1 1:0.5 2:0.5\n")
        f.write("-1 1:nan 2:0.5\n")
    with pytest.raises(BadRowError, match="line 2"):
        read_libsvm(p)
    with open(p, "w") as f:
        f.write("1 1:0.5 garbage\n")
    with pytest.raises(BadRowError, match="malformed"):
        read_libsvm(p)
    with open(p, "w") as f:
        f.write("1 0:0.5\n")   # 0-based index
    with pytest.raises(BadRowError, match="1-based"):
        read_libsvm(p)


def test_ingest_skip_drops_rows_atomically(tmp_path):
    p = str(tmp_path / "mixed.svm")
    with open(p, "w") as f:
        f.write("1 1:0.5 2:0.25\n")
        f.write("-1 1:0.1 2:inf 3:0.9\n")   # bad VALUE after good tokens
        f.write("nan 1:0.1\n")              # bad label
        f.write("# comment\n")
        f.write("-1 3:0.75\n")
    st = IngestStats()
    data = read_libsvm(p, on_bad_row="skip", stats=st)
    assert st.rows_read == 2 and st.rows_skipped == 2
    assert data.n == 2
    np.testing.assert_array_equal(data.labels, [1.0, -1.0])
    # the half-parsed bad row left NOTHING behind (atomic rollback)
    assert len(data.values) == 3
    # the block reader agrees, block boundaries included
    st2 = IngestStats()
    blocks = list(read_libsvm_blocks(p, rows=1, n_features=3,
                                     on_bad_row="skip", stats=st2))
    assert st2.rows_skipped == 2
    dense = np.concatenate([b for b, _ in blocks])
    np.testing.assert_array_equal(dense, data.densify())


# --------------------------------------------------------------------------
# failed runs still export a valid trace, with no leaked threads
# --------------------------------------------------------------------------

def test_failed_run_exports_valid_trace(tmp_path):
    G, tasks, _ = _problem(n=240, seed=4)
    cfg = SolverConfig(tol=1e-3, max_epochs=25)
    tr = Tracer()
    n_threads = threading.active_count()
    F.install(F.FaultPlan().add("reader", kind="io", block=1))
    try:
        with pytest.raises(OSError):
            solve_batch_streamed(G, tasks, cfg,
                                 stream_config=StreamConfig(tile_rows=64,
                                                            trace=tr))
    finally:
        F.uninstall()
    deadline = time.monotonic() + 10
    while threading.active_count() > n_threads and time.monotonic() < deadline:
        time.sleep(0.01)
    assert threading.active_count() <= n_threads   # no leaked workers
    out = str(tmp_path / "failed.json")
    tr.export(out)
    with open(out) as f:
        events = json.load(f)["traceEvents"]       # valid JSON end to end
    # the in-flight read span was CLOSED with the error recorded on it
    errs = [e for e in events
            if e.get("name") == "stage_block"
            and e.get("args", {}).get("error")]
    assert errs and errs[-1]["args"]["error"] == "InjectedIOError"
    assert any(e.get("cat") == "fault" for e in events)


# --------------------------------------------------------------------------
# zero overhead when disabled
# --------------------------------------------------------------------------

def test_disabled_resilience_is_bit_identical_no_snapshots(tmp_path,
                                                           monkeypatch):
    import repro.core.resilience as R

    calls = {"n": 0}
    orig = R.snapshot_engines

    def spy(*a, **kw):
        calls["n"] += 1
        return orig(*a, **kw)

    monkeypatch.setattr(R, "snapshot_engines", spy)
    G, tasks, _ = _problem(n=240, seed=5)
    cfg = SolverConfig(tol=1e-3, max_epochs=25)
    base, st_base = solve_batch_streamed(
        G, tasks, cfg, stream_config=StreamConfig(tile_rows=64),
        return_stats=True)
    assert calls["n"] == 0                       # default path: no guard work
    # checkpoint machinery armed but checkpoint_every=0: still zero snapshots
    # and bit-identical outputs AND byte counters
    sc = StreamConfig(tile_rows=64, checkpoint_dir=str(tmp_path / "z"),
                      checkpoint_every=0)
    res, st = solve_batch_streamed(G, tasks, cfg, stream_config=sc,
                                   return_stats=True)
    assert calls["n"] == 0
    _assert_same_result(base, res)
    for f in ("bytes_h2d", "bytes_d2h", "bytes_g", "blocks_streamed",
              "rows_streamed", "epochs", "full_passes"):
        assert getattr(st, f) == getattr(st_base, f), f
    assert st.epoch_bytes == st_base.epoch_bytes
    # spy sanity: snapshots DO happen once checkpoint_every is set
    sc1 = StreamConfig(tile_rows=64, checkpoint_dir=str(tmp_path / "z1"),
                       checkpoint_every=1)
    solve_batch_streamed(G, tasks, cfg, stream_config=sc1)
    assert calls["n"] >= 1


# --------------------------------------------------------------------------
# CLI: kill -9 between epochs, then --resume
# --------------------------------------------------------------------------

def test_cli_kill9_then_resume(tmp_path):
    x, y = make_multiclass(n=1200, n_classes=5, seed=0)
    data = str(tmp_path / "train.svm")
    write_libsvm(data, np.asarray(x, np.float32), y)
    ck = str(tmp_path / "ckpt")
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    args = [sys.executable, "-m", "repro.launch.train_svm",
            "--libsvm", data, "--budget", "48", "--gamma", "0.25",
            "--chunk-rows", "256", "--tile-rows", "128",
            "--checkpoint-dir", ck, "--checkpoint-every", "1"]
    proc = subprocess.Popen(args, env=env, stdout=subprocess.DEVNULL,
                            stderr=subprocess.DEVNULL)
    try:
        deadline = time.monotonic() + 300
        while time.monotonic() < deadline and proc.poll() is None:
            if any(f.startswith("step_") for f in
                   (os.listdir(ck) if os.path.isdir(ck) else [])):
                proc.send_signal(signal.SIGKILL)   # the real thing
                break
            time.sleep(0.02)
        proc.wait(timeout=300)
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.wait()
    out = subprocess.run(args + ["--resume"], env=env, capture_output=True,
                         text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "resuming" in out.stdout
    assert "train error" in out.stdout
