"""Overlapped multi-device streamed stage-2 task farm (core/distributed.py).

Pins down (a) overlapped-mesh == serial-mesh == monolithic `solve_batch`
(alpha, w, epochs) including warm starts and shrinking; (b) the shared block
reader makes per-pass `bytes_h2d` INDEPENDENT of device count, while the
legacy serial farm pays ~D x; (c) the row-count-balanced task split isolates
fat OVO pairs; (d) the minimal overlap-autotune loop (`tune_prefetch`)
deepens the in-flight queue when transfer lags compute; (e) estimator entry
points route onto the farm.  Multi-device behaviour runs in subprocesses
(the parent process has already locked jax to one CPU device; XLA_FLAGS must
be set before jax import), like tests/test_multidevice.py.
"""
import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest

import repro.core.solver_stream as ss
from repro.core import (KernelParams, SolverConfig, StreamConfig,
                        balance_task_split, compute_factor, solve_batch,
                        solve_batch_streamed, solve_tasks_streamed,
                        tune_prefetch)
from repro.core.ovo import build_ovo_tasks
from repro.data import make_multiclass

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
KP = KernelParams("rbf", gamma=0.25)


def run_sub(code: str, n_dev: int = 4, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def _problem(n=360, classes=4, budget=64, C=4.0, seed=9):
    x, y = make_multiclass(n, p=6, n_classes=classes, seed=seed)
    _, labels = np.unique(y, return_inverse=True)
    fac = compute_factor(jnp.asarray(x, jnp.float32), KP, budget)
    tasks, _ = build_ovo_tasks(labels, classes, C)
    return np.asarray(fac.G), tasks, labels


# --------------------------------------------------------- balanced split

def test_balance_split_isolates_fat_task():
    """One fat OVO pair must land alone instead of serialising a linspace
    slice that also carries other work."""
    counts = [1000, 10, 10, 10, 10, 10]
    parts = balance_task_split(counts, 3)
    assert sorted(np.concatenate(parts).tolist()) == list(range(6))
    fat = [p for p in parts if 0 in p]
    assert len(fat) == 1 and len(fat[0]) == 1
    loads = sorted(sum(counts[t] for t in p) for p in parts)
    assert loads == [20, 30, 1000]


def test_balance_split_shapes_and_determinism():
    counts = [7, 3, 9, 1, 4]
    a = balance_task_split(counts, 2)
    b = balance_task_split(counts, 2)
    assert all(np.array_equal(x, y) for x, y in zip(a, b))
    # more parts than tasks: empties dropped, every task still covered once
    parts = balance_task_split(counts, 8)
    assert len(parts) == 5
    assert sorted(np.concatenate(parts).tolist()) == list(range(5))
    # inert (zero-row) tasks still spread instead of piling on one part
    parts = balance_task_split([0, 0, 0, 0], 2)
    assert len(parts) == 2 and all(len(p) == 2 for p in parts)


# ------------------------------------------------------- overlap autotune

def test_tune_prefetch_rules():
    # transfer lags compute -> double, bounded by the cap
    assert tune_prefetch(2.0, 1.0, 2, cap=8) == 4
    assert tune_prefetch(2.0, 1.0, 4, cap=8) == 8
    assert tune_prefetch(2.0, 1.0, 6, cap=8) == 8
    assert tune_prefetch(2.0, 1.0, 1, cap=8) == 2
    # already at/over the cap, or compute-bound: unchanged
    assert tune_prefetch(2.0, 1.0, 8, cap=8) == 8
    assert tune_prefetch(0.5, 1.0, 2, cap=8) == 2
    assert tune_prefetch(1.0, 1.0, 2, cap=8) == 2


def test_autotune_plumbing(monkeypatch):
    """The driver applies `tune_prefetch` once, after the FIRST full pass,
    and the tuned depth surfaces in the stats record."""
    calls = []

    def fake_tune(put, drain, prefetch, cap):
        calls.append((prefetch, cap))
        return 7

    monkeypatch.setattr(ss, "tune_prefetch", fake_tune)
    G, tasks, _ = _problem(n=240, budget=48)
    cfg = SolverConfig(tol=1e-2, max_epochs=60)
    _, st = solve_batch_streamed(
        G, tasks, cfg, return_stats=True,
        stream_config=StreamConfig(tile_rows=64, prefetch_cap=9))
    assert calls == [(2, 9)]
    assert st.prefetch_final == 7

    # a tight device budget tightens the cap: deepening the queue must not
    # blow the in-flight byte model
    calls.clear()
    rank, T = G.shape[1], tasks.n_tasks
    budget = (ss.stage2_resident_bytes(rank, T)
              + 3 * ss.stage2_block_bytes(64, rank, T))
    solve_batch_streamed(
        G, tasks, cfg,
        stream_config=StreamConfig(tile_rows=64, prefetch_cap=9,
                                   device_budget_bytes=budget))
    assert calls == [(2, 3)]


def test_autotune_disabled():
    G, tasks, _ = _problem(n=240, budget=48)
    cfg = SolverConfig(tol=1e-2, max_epochs=60)
    _, st = solve_batch_streamed(
        G, tasks, cfg, return_stats=True,
        stream_config=StreamConfig(tile_rows=64, prefetch=3,
                                   autotune_prefetch=False))
    assert st.prefetch_final == 3


def test_stream_config_validation():
    with pytest.raises(ValueError):
        StreamConfig(block_dtype="fp8")
    with pytest.raises(ValueError):
        StreamConfig(prefetch_cap=0)
    with pytest.raises(ValueError):
        StreamConfig(stage1_dtype="bf16")   # stage-1 wire is f32 or int8
    with pytest.raises(ValueError):
        StreamConfig(quant_group_rows=0)
    StreamConfig(block_dtype="bf16")    # valid
    StreamConfig(block_dtype="int8", stage1_dtype="int8",
                 quant_group_rows=8)    # valid


# ------------------------------------------------- single-device fallback

def test_farm_single_device_matches_monolithic():
    """With one local device (the test process) both overlap settings reduce
    to the plain single-engine stream."""
    G, tasks, _ = _problem(n=240, budget=48)
    cfg = SolverConfig(tol=1e-2, max_epochs=120)
    mono = solve_batch(jnp.asarray(G), tasks, cfg)
    for overlap in (True, False):
        res = solve_tasks_streamed(G, tasks, cfg,
                                   devices=jax.local_devices(),
                                   stream_config=StreamConfig(tile_rows=64),
                                   overlap=overlap)
        np.testing.assert_allclose(res.alpha, np.asarray(mono.alpha),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(res.w, np.asarray(mono.w),
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_array_equal(res.epochs, np.asarray(mono.epochs))


# ------------------------------------------------------ multi-device farm

def test_overlapped_farm_parity_and_bytes_on_4_devices():
    """The heart of the PR, on a 4-device CPU mesh: overlapped == serial ==
    monolithic (cold AND warm-started, with shrinking), and the mesh-level
    per-pass H2D bytes equal the single-device figure exactly (G is streamed
    once per pass, not once per device) while the serial farm pays ~D x."""
    run_sub(r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import (KernelParams, SolverConfig, StreamConfig,
                        compute_factor, solve_batch, solve_batch_streamed,
                        solve_tasks_streamed)
from repro.core.ovo import build_ovo_tasks
from repro.data import make_multiclass

x, y = make_multiclass(360, p=6, n_classes=4, seed=9)
_, labels = np.unique(y, return_inverse=True)
fac = compute_factor(jnp.asarray(x, jnp.float32),
                     KernelParams("rbf", gamma=0.25), 64)
G = np.asarray(fac.G)
tasks, _ = build_ovo_tasks(labels, 4, 4.0)
cfg = SolverConfig(tol=1e-2, max_epochs=300)
scfg = StreamConfig(tile_rows=96)
devs = jax.local_devices()
assert len(devs) == 4

def check(res, mono):
    np.testing.assert_allclose(res.alpha, np.asarray(mono.alpha),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(res.w, np.asarray(mono.w),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_array_equal(res.epochs, np.asarray(mono.epochs))

mono = solve_batch(jnp.asarray(G), tasks, cfg)
single, st1 = solve_batch_streamed(G, tasks, cfg, stream_config=scfg,
                                   return_stats=True)
over, stov = solve_tasks_streamed(G, tasks, cfg, devices=devs,
                                  stream_config=scfg, overlap=True,
                                  return_stats=True)
ser, stse = solve_tasks_streamed(G, tasks, cfg, devices=devs,
                                 stream_config=scfg, overlap=False,
                                 return_stats=True)
check(single, mono); check(over, mono); check(ser, mono)
assert stov.n_devices == 4 and len(stov.per_device) == 4
print("PARITY-OK")

# shared reader: first-full-pass H2D bytes identical at 1 and 4 devices;
# serial farm re-streams G once per device shard
assert stov.epoch_bytes[0] == st1.epoch_bytes[0], \
    (stov.epoch_bytes[0], st1.epoch_bytes[0])
assert stse.epoch_bytes[0] > 2 * st1.epoch_bytes[0]
# ... while the PHYSICAL per-device DMA copies are tracked honestly:
# at one device the views coincide; on the farm every device still
# receives every broadcast block, so bytes_put exceeds the unique bytes
assert st1.bytes_put == st1.bytes_h2d
assert stov.bytes_put > stov.bytes_h2d
print("BYTES-OK")

# warm starts (the C-grid pattern) flow through the farm unchanged
warm = [np.asarray(a) for a in np.asarray(single.alpha)]
tasks8, _ = build_ovo_tasks(labels, 4, 8.0, alpha0=warm)
mono8 = solve_batch(jnp.asarray(G), tasks8, cfg)
over8 = solve_tasks_streamed(G, tasks8, cfg, devices=devs,
                             stream_config=scfg, overlap=True)
check(over8, mono8)
print("WARM-OK")

# estimator-level routing: a streamed fit on a multi-device host lands on
# the overlapped farm for free
from repro.core import LPDSVM
svm = LPDSVM(KernelParams("rbf", gamma=0.25), C=2.0, budget=64,
             stream_config=StreamConfig(device_budget_bytes=64 << 10))
svm.fit(x, y)
assert svm.stats.stage2_streamed
assert svm.stats.stage2_stats.n_devices == 4
plain = LPDSVM(KernelParams("rbf", gamma=0.25), C=2.0, budget=64).fit(x, y)
np.testing.assert_allclose(np.asarray(svm.W_), np.asarray(plain.W_),
                           rtol=1e-4, atol=1e-4)
# overlap_devices=False must still use every device (the SERIAL farm),
# not silently drop to one
svm_ser = LPDSVM(KernelParams("rbf", gamma=0.25), C=2.0, budget=64,
                 stream_config=StreamConfig(device_budget_bytes=64 << 10,
                                            overlap_devices=False))
svm_ser.fit(x, y)
assert svm_ser.stats.stage2_stats.n_devices == 4
np.testing.assert_allclose(np.asarray(svm_ser.W_), np.asarray(plain.W_),
                           rtol=1e-4, atol=1e-4)
print("FIT-OK")
""")


def test_bf16_farm_bytes_halve_on_2_devices():
    """bf16 wire blocks through the OVERLAPPED farm: the shared-reader G
    bytes halve relative to f32 at the same device count."""
    run_sub(r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import (KernelParams, SolverConfig, StreamConfig,
                        compute_factor, solve_tasks_streamed)
from repro.core.ovo import build_ovo_tasks
from repro.data import make_multiclass

x, y = make_multiclass(300, p=6, n_classes=3, seed=2)
_, labels = np.unique(y, return_inverse=True)
fac = compute_factor(jnp.asarray(x, jnp.float32),
                     KernelParams("rbf", gamma=0.25), 64)
G = np.asarray(fac.G)
n, rank = G.shape
tasks, _ = build_ovo_tasks(labels, 3, 4.0)
cfg = SolverConfig(tol=1e-2, max_epochs=200)
devs = jax.local_devices()
r32, s32 = solve_tasks_streamed(
    G, tasks, cfg, devices=devs, return_stats=True,
    stream_config=StreamConfig(tile_rows=96))
rbf, sbf = solve_tasks_streamed(
    G, tasks, cfg, devices=devs, return_stats=True,
    stream_config=StreamConfig(tile_rows=96, block_dtype="bf16"))
import math
g32 = math.ceil(n / 96) * 96 * rank * 4
assert s32.epoch_bytes[0] - sbf.epoch_bytes[0] == g32 // 2, \
    (s32.epoch_bytes[0], sbf.epoch_bytes[0], g32)
# decisions stay aligned despite the rounded wire format
d32 = G @ r32.w.T; dbf = G @ rbf.w.T
assert np.mean(np.sign(d32) == np.sign(dbf)) > 0.98
print("BF16-MESH-OK")
""", n_dev=2)


def test_int8_farm_bytes_quarter_and_device_invariance_on_2_devices():
    """int8 wire blocks through the OVERLAPPED farm: the shared-reader G
    bytes quarter relative to f32 (scale tables included, exact byte model),
    and per-pass `bytes_h2d` stays INDEPENDENT of device count — the
    acceptance invariant for `block_dtype="int8"`."""
    run_sub(r"""
import math
import numpy as np, jax, jax.numpy as jnp
from repro.core import (KernelParams, SolverConfig, StreamConfig,
                        compute_factor, solve_batch_streamed,
                        solve_tasks_streamed, wire_group)
from repro.core.quant import quant_scale_bytes
from repro.core.ovo import build_ovo_tasks
from repro.data import make_multiclass

x, y = make_multiclass(300, p=6, n_classes=3, seed=2)
_, labels = np.unique(y, return_inverse=True)
fac = compute_factor(jnp.asarray(x, jnp.float32),
                     KernelParams("rbf", gamma=0.25), 64)
G = np.asarray(fac.G)
n, rank = G.shape
tasks, _ = build_ovo_tasks(labels, 3, 4.0)
cfg = SolverConfig(tol=1e-2, max_epochs=200)
devs = jax.local_devices()
assert len(devs) == 2
tile = 96
scfg8 = StreamConfig(tile_rows=tile, block_dtype="int8")
r32, s32 = solve_tasks_streamed(
    G, tasks, cfg, devices=devs, return_stats=True,
    stream_config=StreamConfig(tile_rows=tile))
r8, s8 = solve_tasks_streamed(
    G, tasks, cfg, devices=devs, return_stats=True, stream_config=scfg8)
nb = math.ceil(n / tile)
eff = wire_group(tile, scfg8)
g32 = nb * tile * rank * 4
g8 = nb * (tile * rank + quant_scale_bytes(tile, eff))
assert s32.epoch_bytes[0] - s8.epoch_bytes[0] == g32 - g8, \
    (s32.epoch_bytes[0], s8.epoch_bytes[0], g32, g8)
assert g32 > 3 * g8
assert s8.bytes_scales > 0
# device-count byte invariance: the farm's first-full-pass bytes equal the
# SINGLE-device figure exactly — G is staged/quantised once per pass
_, s8_1 = solve_batch_streamed(G, tasks, cfg, stream_config=scfg8,
                               return_stats=True)
assert s8.epoch_bytes[0] == s8_1.epoch_bytes[0], \
    (s8.epoch_bytes[0], s8_1.epoch_bytes[0])
# predictions stay aligned despite the quantised wire format (raw OVO
# values flip only near zero, where the vote does not care)
from repro.core.ovo import ovo_vote, class_pairs
d32 = G @ r32.w.T; d8 = G @ r8.w.T
pairs = class_pairs(3)
assert np.mean(ovo_vote(d32, pairs, 3) == ovo_vote(d8, pairs, 3)) >= 0.99
assert np.mean(np.sign(d32) == np.sign(d8)) > 0.95
print("INT8-MESH-OK")
""", n_dev=2)
