"""Per-architecture smoke tests (deliverable f): reduced variants of every
assigned config run one forward + one train step on CPU, asserting output
shapes and no NaNs; decode consistency for one arch per family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, list_configs
from repro.models import (forward, init_decode_state, init_model, lm_loss,
                          prefill_cross_attention)
from repro.models import model as M
from repro.optim import get_optimizer

KEY = jax.random.PRNGKey(0)


def _batch(cfg, rng, B=2, S=64):
    b = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                               jnp.int32)}
    if cfg.modality == "vision":
        b["prefix"] = jnp.asarray(
            rng.normal(size=(B, cfg.num_prefix_embeddings, cfg.d_model)),
            jnp.bfloat16)
    if cfg.is_encoder_decoder:
        b["frames"] = jnp.asarray(rng.normal(size=(B, 32, cfg.d_model)),
                                  jnp.bfloat16)
    return b


def test_all_archs_registered():
    assert set(list_configs()) == set(ARCH_IDS)
    assert len(ARCH_IDS) == 10


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_config_bounds(arch):
    cfg = get_config(arch, reduced=True)
    assert cfg.n_layers <= 2 and cfg.d_model <= 512
    assert cfg.n_experts <= 4


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finiteness(rng, arch):
    cfg = get_config(arch, reduced=True)
    params, specs = init_model(KEY, cfg)
    # every param leaf has a spec leaf
    assert len(jax.tree.leaves(params)) == len(jax.tree.leaves(
        specs, is_leaf=lambda x: x is None or isinstance(x, tuple)))
    B, S = 2, 64
    batch = _batch(cfg, rng, B, S)
    logits, aux = jax.jit(lambda p, b: forward(p, cfg, b))(params, batch)
    S_tot = S + (cfg.num_prefix_embeddings if cfg.modality == "vision" else 0)
    assert logits.shape == (B, S_tot, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    if cfg.n_experts:
        assert float(aux) > 0.0          # router aux loss is live


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_decreases_loss(rng, arch):
    cfg = get_config(arch, reduced=True)
    params, _ = init_model(KEY, cfg)
    B, S = 2, 64
    batch = _batch(cfg, rng, B, S)
    tgts = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    P = cfg.num_prefix_embeddings if cfg.modality == "vision" else 0

    def loss_fn(p):
        logits, aux = forward(p, cfg, batch)
        return lm_loss(logits, tgts, prefix_len=P) + 0.01 * aux

    opt = get_optimizer(cfg.optimizer, lr=1e-3)
    st = opt.init(params)

    @jax.jit
    def step(p, st):
        loss, grads = jax.value_and_grad(loss_fn)(p)
        p2, st2 = opt.update(grads, st, p)
        return loss, p2, st2

    losses = []
    for _ in range(3):
        l, params, st = step(params, st)
        losses.append(float(l))
    assert np.isfinite(losses[-1])
    assert losses[-1] < losses[0]


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "qwen3-0.6b",
                                  "rwkv6-1.6b", "jamba-v0.1-52b",
                                  "deepseek-v2-236b", "seamless-m4t-large-v2"])
def test_decode_matches_forward(arch):
    import zlib
    rng = np.random.default_rng(zlib.crc32(arch.encode()))  # stable per-arch
    cfg = get_config(arch, reduced=True)
    params, _ = init_model(KEY, cfg)
    B, S = 2, 16
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    batch = {"tokens": toks}
    enc_len = 0
    if cfg.is_encoder_decoder:
        frames = jnp.asarray(rng.normal(size=(B, 8, cfg.d_model)), jnp.bfloat16)
        batch["frames"] = frames
        enc_len = 8
    logits_full, _ = forward(params, cfg, batch, remat=False)
    state = init_decode_state(cfg, B, kv_len=S, enc_len=enc_len)
    if cfg.is_encoder_decoder:
        memory = M._run_encoder(params, cfg, frames)
        state = prefill_cross_attention(params, cfg, state, memory)
    dec = jax.jit(lambda p, t, s, pos: M.decode(p, cfg, t, s, pos))
    outs = []
    for t in range(S):
        lg, state = dec(params, toks[:, t:t + 1], state, jnp.int32(t))
        outs.append(lg)
    err = float(jnp.max(jnp.abs(
        logits_full.astype(jnp.float32)
        - jnp.concatenate(outs, 1).astype(jnp.float32))))
    # bf16 end-to-end; MLA's absorbed decode reorders the contractions, so
    # per-logit noise is larger than for plain GQA
    assert err < 0.08, err


def test_sliding_window_cache_rolls(rng):
    """Windowed decode must equal full-cache decode for pos < window and
    keep producing finite logits beyond it."""
    cfg = get_config("tinyllama-1.1b", reduced=True)
    params, _ = init_model(KEY, cfg)
    B, W, S = 1, 8, 20
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)), jnp.int32)
    state_w = init_decode_state(cfg, B, kv_len=W)
    state_f = init_decode_state(cfg, B, kv_len=S)
    dec = jax.jit(lambda p, t, s, pos: M.decode(p, cfg, t, s, pos))
    for t in range(S):
        lw, state_w = dec(params, toks[:, t:t + 1], state_w, jnp.int32(t))
        lf, state_f = dec(params, toks[:, t:t + 1], state_f, jnp.int32(t))
        if t < W:
            assert float(jnp.max(jnp.abs(lw - lf))) < 1e-2
        assert bool(jnp.all(jnp.isfinite(lw.astype(jnp.float32))))


def test_param_counts_match_published():
    expect = {
        "tinyllama-1.1b": 1.1e9, "qwen3-0.6b": 0.6e9,
        "deepseek-v2-236b": 236e9, "kimi-k2-1t-a32b": 1.0e12,
        "jamba-v0.1-52b": 52e9, "minitron-4b": 4.2e9,
        "rwkv6-1.6b": 1.6e9, "phi-3-vision-4.2b": 3.8e9,
    }
    for arch, n in expect.items():
        got = get_config(arch).param_count()
        assert abs(got - n) / n < 0.25, (arch, got, n)
