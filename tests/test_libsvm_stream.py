"""LIBSVM streaming ingest: block iterators must match the in-memory reader
and feed stage 1 without materialising the full dense matrix."""
import os
import tempfile

import numpy as np
import pytest

from repro.core import (KernelParams, StreamConfig, compute_factor,
                        compute_factor_streamed_csr, stream_factor_blocks)
from repro.data import (count_libsvm_rows, make_multiclass, read_libsvm,
                        read_libsvm_blocks, write_libsvm)

KP = KernelParams("rbf", gamma=0.4)


@pytest.fixture(scope="module")
def svm_file():
    x, y = make_multiclass(310, p=7, n_classes=3, seed=21)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "data.svm")
        write_libsvm(path, x, y)
        yield path, x.astype(np.float32), y


def test_densify_vectorized_matches_rows(svm_file):
    path, x, _ = svm_file
    csr = read_libsvm(path, n_features=x.shape[1])
    np.testing.assert_allclose(csr.densify(), x, rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(csr.densify(100, 207), x[100:207],
                               rtol=1e-3, atol=1e-4)
    rows = np.array([5, 300, 0, 17, 17])          # any order, repeats allowed
    np.testing.assert_allclose(csr.densify_rows(rows), x[rows],
                               rtol=1e-3, atol=1e-4)


def test_iter_dense_blocks_covers_everything(svm_file):
    path, x, y = svm_file
    csr = read_libsvm(path, n_features=x.shape[1])
    blocks = list(csr.iter_dense_blocks(77))       # 310 = 4*77 + 2: ragged
    assert [b.shape[0] for b, _ in blocks] == [77, 77, 77, 77, 2]
    np.testing.assert_allclose(np.concatenate([b for b, _ in blocks]),
                               csr.densify())
    np.testing.assert_array_equal(np.concatenate([l for _, l in blocks]),
                                  csr.labels)


def test_read_libsvm_blocks_matches_reader(svm_file):
    path, x, _ = svm_file
    csr = read_libsvm(path, n_features=x.shape[1])
    assert count_libsvm_rows(path) == csr.n
    dense = np.concatenate([b for b, _ in read_libsvm_blocks(path, 64, x.shape[1])])
    np.testing.assert_allclose(dense, csr.densify())


def test_blocks_feed_stream_factor(svm_file):
    """A file-block iterator drives `stream_factor_blocks` straight into the
    same G as the monolithic path."""
    path, x, _ = svm_file
    mono = compute_factor(x, KP, 64)
    blocks = (b for b, _ in read_libsvm_blocks(path, 49, x.shape[1]))
    out = stream_factor_blocks(blocks, x.shape[0], mono.landmarks,
                               mono.projector, KP)
    np.testing.assert_allclose(out, np.asarray(mono.G), rtol=1e-4, atol=1e-4)


def test_compute_factor_streamed_csr_matches_dense(svm_file):
    path, x, _ = svm_file
    csr = read_libsvm(path, n_features=x.shape[1])
    fac = compute_factor_streamed_csr(csr, KP, 64,
                                      config=StreamConfig(chunk_rows=50))
    from repro.core.streaming import compute_factor_streamed
    ref = compute_factor_streamed(csr.densify(), KP, 64,
                                  config=StreamConfig(chunk_rows=50))
    assert fac.streamed and isinstance(fac.G, np.ndarray)
    assert fac.effective_rank == ref.effective_rank
    np.testing.assert_allclose(fac.G, ref.G, rtol=1e-5, atol=1e-5)


def test_block_iterator_row_count_validated(svm_file):
    path, x, _ = svm_file
    mono = compute_factor(x, KP, 32)
    short = (b for b, _ in read_libsvm_blocks(path, 64, x.shape[1]))
    with pytest.raises(ValueError):
        stream_factor_blocks(short, x.shape[0] + 5, mono.landmarks,
                             mono.projector, KP)


def test_out_of_range_feature_index_raises(tmp_path):
    p = tmp_path / "bad.svm"
    p.write_text("1 3:1.5\n-1 9:2.0\n")
    with pytest.raises(ValueError):
        list(read_libsvm_blocks(str(p), 8, n_features=4))
