"""Stage 1: low-rank factor quality, eigenvalue dropping, feature map."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.kernel_fn import KernelParams, gram
from repro.core.nystrom import (approximation_error, compute_factor,
                                select_landmarks)


def test_full_budget_is_exact(rng):
    """With B = n the Nyström factor reproduces K exactly (up to eig drop)."""
    x = jnp.asarray(rng.normal(size=(60, 4)), jnp.float32)
    kp = KernelParams("rbf", gamma=0.5)
    fac = compute_factor(x, kp, budget=60)
    K = np.asarray(gram(x, x, kp))
    K_hat = np.asarray(fac.G @ fac.G.T)
    assert np.abs(K - K_hat).max() < 1e-2


def test_error_decreases_with_budget(rng):
    x = jnp.asarray(rng.normal(size=(400, 6)), jnp.float32)
    kp = KernelParams("rbf", gamma=0.3)
    errs = [approximation_error(compute_factor(x, kp, budget=b), x, kp)
            for b in (25, 100, 300)]
    assert errs[0] > errs[1] > errs[2]
    assert errs[2] < 0.15


def test_eigenvalue_dropping(rng):
    # duplicate landmarks -> rank-deficient K_mm -> dropped directions
    base = rng.normal(size=(20, 4)).astype(np.float32)
    x = jnp.asarray(np.concatenate([base, base, base]), jnp.float32)
    kp = KernelParams("rbf", gamma=0.5)
    fac = compute_factor(x, kp, budget=60)
    assert fac.effective_rank <= 20 + 1
    assert fac.G.shape[1] == fac.effective_rank
    assert bool(jnp.all(jnp.isfinite(fac.G)))


def test_features_match_training_rows(rng):
    """factor.features(x_train) must reproduce the G rows (consistency of
    the prediction path with the training representation)."""
    x = jnp.asarray(rng.normal(size=(100, 5)), jnp.float32)
    kp = KernelParams("rbf", gamma=0.8)
    fac = compute_factor(x, kp, budget=40)
    feats = fac.features(x)
    assert np.abs(np.asarray(feats - fac.G)).max() < 1e-3


def test_landmark_selection_subset(rng):
    x = jnp.asarray(rng.normal(size=(50, 3)), jnp.float32)
    lm = select_landmarks(x, 20, jax.random.PRNGKey(0))
    assert lm.shape == (20, 3)
    # each landmark is an actual row of x
    d = jnp.min(jnp.sum((lm[:, None] - x[None]) ** 2, axis=-1), axis=1)
    assert float(jnp.max(d)) < 1e-9


def test_streaming_blocks_match(rng):
    x = jnp.asarray(rng.normal(size=(150, 4)), jnp.float32)
    kp = KernelParams("rbf", gamma=0.4)
    f1 = compute_factor(x, kp, budget=32, block_rows=37)
    f2 = compute_factor(x, kp, budget=32, block_rows=100000)
    assert np.abs(np.asarray(f1.G - f2.G)).max() < 1e-5
