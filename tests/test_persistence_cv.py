"""Model persistence + cross-gamma warm start (beyond-paper features)."""
import tempfile

import numpy as np

from repro.core import KernelParams, LPDSVM, SolverConfig, grid_search
from repro.data import make_multiclass, train_test_split


def test_save_load_roundtrip(rng):
    x, y = make_multiclass(500, p=6, n_classes=3, seed=31)
    xtr, ytr, xte, yte = train_test_split(x, y, 0.3)
    svm = LPDSVM(KernelParams("rbf", gamma=0.1), C=4.0, budget=128,
                 tol=1e-2).fit(xtr, ytr)
    with tempfile.TemporaryDirectory() as d:
        svm.save(d)
        back = LPDSVM.load(d)
    np.testing.assert_array_equal(svm.predict(xte), back.predict(xte))
    np.testing.assert_allclose(svm.decision_function(xte),
                               back.decision_function(xte), atol=1e-5)
    assert back.kernel.kind == svm.kernel.kind
    assert abs(back.kernel.gamma - svm.kernel.gamma) < 1e-6  # f32 roundtrip
    assert back.C == svm.C


def test_save_requires_fit():
    import pytest
    with pytest.raises(RuntimeError):
        LPDSVM().save("/tmp/nowhere")


def test_save_load_roundtrip_streamed_factor(rng):
    """A model fitted fully out-of-core (both stages streamed) must roundtrip
    through save -> load -> predict like any other."""
    from repro.core import StreamConfig
    x, y = make_multiclass(400, p=5, n_classes=3, seed=33)
    xtr, ytr, xte, yte = train_test_split(x, y, 0.3)
    tiny = StreamConfig(device_budget_bytes=128 << 10)
    svm = LPDSVM(KernelParams("rbf", gamma=0.2), C=2.0, budget=96,
                 stream_config=tiny).fit(xtr, ytr)
    assert svm.stats.stage1_streamed and svm.stats.stage2_streamed
    with tempfile.TemporaryDirectory() as d:
        svm.save(d)
        back = LPDSVM.load(d)
    np.testing.assert_array_equal(svm.predict(xte), back.predict(xte))
    np.testing.assert_allclose(svm.decision_function(xte),
                               back.decision_function(xte), atol=1e-5)


def test_load_discovers_latest_step(rng):
    """`load` must pick the newest step_*.msgpack, not a hardcoded step 0."""
    import pytest
    x, y = make_multiclass(300, p=4, n_classes=2, seed=34)
    svm = LPDSVM(KernelParams("rbf", gamma=0.3), C=1.0, budget=64).fit(x, y)
    with tempfile.TemporaryDirectory() as d:
        svm.save(d, step=0)
        svm.C = 99.0                      # marker visible in the payload
        svm.save(d, step=17)
        assert LPDSVM.load(d).C == 99.0           # latest wins
        assert LPDSVM.load(d, step=0).C != 99.0   # pinning still works
    with tempfile.TemporaryDirectory() as d:
        with pytest.raises(FileNotFoundError):
            LPDSVM.load(d)


def test_cross_gamma_warm_start_same_errors(rng):
    x, y = make_multiclass(700, p=8, n_classes=3, seed=32)
    kw = dict(gammas=[0.05, 0.1, 0.2], Cs=[2.0, 8.0], budget=150, folds=3,
              config=SolverConfig(tol=1e-3, max_epochs=1500))
    base = grid_search(x, y, warm_start_gamma=False, **kw)
    warm = grid_search(x, y, warm_start_gamma=True, **kw)
    # identical error surface (same optima), typically less stage-2 work
    assert np.abs(base.errors - warm.errors).max() < 0.03
    assert warm.best_error <= base.best_error + 0.03
