"""Model persistence + cross-gamma warm start (beyond-paper features)."""
import tempfile

import numpy as np

from repro.core import KernelParams, LPDSVM, SolverConfig, grid_search
from repro.data import make_multiclass, train_test_split


def test_save_load_roundtrip(rng):
    x, y = make_multiclass(500, p=6, n_classes=3, seed=31)
    xtr, ytr, xte, yte = train_test_split(x, y, 0.3)
    svm = LPDSVM(KernelParams("rbf", gamma=0.1), C=4.0, budget=128,
                 tol=1e-2).fit(xtr, ytr)
    with tempfile.TemporaryDirectory() as d:
        svm.save(d)
        back = LPDSVM.load(d)
    np.testing.assert_array_equal(svm.predict(xte), back.predict(xte))
    np.testing.assert_allclose(svm.decision_function(xte),
                               back.decision_function(xte), atol=1e-5)
    assert back.kernel.kind == svm.kernel.kind
    assert abs(back.kernel.gamma - svm.kernel.gamma) < 1e-6  # f32 roundtrip
    assert back.C == svm.C


def test_save_requires_fit():
    import pytest
    with pytest.raises(RuntimeError):
        LPDSVM().save("/tmp/nowhere")


def test_cross_gamma_warm_start_same_errors(rng):
    x, y = make_multiclass(700, p=8, n_classes=3, seed=32)
    kw = dict(gammas=[0.05, 0.1, 0.2], Cs=[2.0, 8.0], budget=150, folds=3,
              config=SolverConfig(tol=1e-3, max_epochs=1500))
    base = grid_search(x, y, warm_start_gamma=False, **kw)
    warm = grid_search(x, y, warm_start_gamma=True, **kw)
    # identical error surface (same optima), typically less stage-2 work
    assert np.abs(base.errors - warm.errors).max() < 0.03
    assert warm.best_error <= base.best_error + 0.03
