"""Out-of-core stage 2: streamed row-block SMO must match `solve_batch`.

Pins down (a) streamed == monolithic (alpha, w, violation, epochs) including
shrinking, non-divisible tiles, and warm starts; (b) the full G is never
device-materialised under a small budget (transfer-guard + block-put spy);
(c) shrinking cuts per-epoch H2D bytes, not just FLOPs; (d) estimator / CV /
mesh entry points route onto the streamed solver.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

import repro.core.solver_stream as ss
from repro.core import (KernelParams, LPDSVM, StreamConfig, compute_factor,
                        cross_validate, solve_batch, solve_batch_streamed)
from repro.core.dual_solver import SolverConfig, TaskBatch
from repro.core.ovo import build_ovo_tasks
from repro.core.solver_stream import (auto_tile_rows, should_stream_stage2,
                                      stage2_block_bytes,
                                      stage2_monolithic_bytes,
                                      stage2_resident_bytes)
from repro.data import make_multiclass

KP = KernelParams("rbf", gamma=0.25)


def _problem(n=360, classes=3, budget=64, C=4.0, seed=9):
    x, y = make_multiclass(n, p=6, n_classes=classes, seed=seed)
    _, labels = np.unique(y, return_inverse=True)
    fac = compute_factor(jnp.asarray(x, jnp.float32), KP, budget)
    tasks, _ = build_ovo_tasks(labels, classes, C)
    return np.asarray(fac.G), tasks, labels


def _assert_matches(mono, res, rtol=1e-4, atol=1e-5):
    np.testing.assert_allclose(res.alpha, np.asarray(mono.alpha),
                               rtol=rtol, atol=atol)
    np.testing.assert_allclose(res.w, np.asarray(mono.w), rtol=rtol, atol=atol)
    np.testing.assert_allclose(res.violation, np.asarray(mono.violation),
                               rtol=1e-2, atol=1e-5)
    np.testing.assert_array_equal(res.epochs, np.asarray(mono.epochs))


@pytest.mark.parametrize("tile", [96, 67, 512])
def test_streamed_matches_monolithic(tile):
    """Divisible, ragged, and single-block tiles all reproduce the monolithic
    trajectory (global row order == sorted task idx order)."""
    G, tasks, _ = _problem()
    cfg = SolverConfig(tol=1e-2, max_epochs=300)
    mono = solve_batch(jnp.asarray(G), tasks, cfg)
    res = solve_batch_streamed(G, tasks, cfg,
                               stream_config=StreamConfig(tile_rows=tile))
    _assert_matches(mono, res)


def test_streamed_matches_with_disjoint_task_rows():
    """Regression: tasks living in disjoint G row ranges (CV folds do this)
    must keep cheap-epoch block skipping aligned with the COMPACTED row
    positions — a global-position slice silently starves late-range tasks."""
    rng = np.random.default_rng(11)
    n, rank = 400, 48
    G = rng.normal(size=(n, rank)).astype(np.float32) / np.sqrt(rank)
    n_pad = 104
    idx = np.zeros((2, n_pad), np.int32)
    idx[0, :100] = np.arange(100)            # task 0: rows 0..99
    idx[1, :100] = np.arange(300, 400)       # task 1: rows 300..399
    y = np.ones((2, n_pad), np.float32)
    y[:, 50:100] = -1.0
    c = np.zeros((2, n_pad), np.float32)
    c[:, :100] = 4.0
    tasks = TaskBatch(idx=jnp.asarray(idx), y=jnp.asarray(y),
                      c=jnp.asarray(c), alpha0=jnp.zeros((2, n_pad)))
    cfg = SolverConfig(tol=1e-4, max_epochs=300)
    mono = solve_batch(jnp.asarray(G), tasks, cfg)
    res = solve_batch_streamed(G, tasks, cfg,
                               stream_config=StreamConfig(tile_rows=64))
    _assert_matches(mono, res)


def test_streamed_matches_without_shrinking():
    G, tasks, _ = _problem(n=280)
    cfg = SolverConfig(tol=1e-2, max_epochs=200, shrink=False)
    mono = solve_batch(jnp.asarray(G), tasks, cfg)
    res = solve_batch_streamed(G, tasks, cfg,
                               stream_config=StreamConfig(tile_rows=80))
    _assert_matches(mono, res)


def test_warm_start_parity_and_speedup():
    """Warm-started solves (the C-grid pattern) match the monolithic path and
    converge in no more epochs than cold starts."""
    G, tasks, labels = _problem(C=1.0)
    cfg = SolverConfig(tol=1e-2, max_epochs=300)
    first = solve_batch(jnp.asarray(G), tasks, cfg)
    warm = [np.asarray(a) for a in np.asarray(first.alpha)]
    tasks4, _ = build_ovo_tasks(labels, 3, 4.0, alpha0=warm)
    mono = solve_batch(jnp.asarray(G), tasks4, cfg)
    res = solve_batch_streamed(G, tasks4, cfg,
                               stream_config=StreamConfig(tile_rows=96))
    _assert_matches(mono, res)
    cold4, _ = build_ovo_tasks(labels, 3, 4.0)
    cold = solve_batch_streamed(G, cold4, cfg,
                                stream_config=StreamConfig(tile_rows=96))
    assert res.epochs.sum() <= cold.epochs.sum()


def test_pallas_epoch_fn_streams():
    """The Pallas SMO kernel (interpret off-TPU) slots in as epoch_fn."""
    from repro.kernels.ops import smo_epoch
    G, tasks, _ = _problem(n=160, budget=48)
    cfg = SolverConfig(tol=1e-2, max_epochs=60)
    mono = solve_batch(jnp.asarray(G), tasks, cfg)
    res = solve_batch_streamed(G, tasks, cfg, epoch_fn=smo_epoch,
                               stream_config=StreamConfig(tile_rows=64))
    # Pallas pads/tiles differently from the jnp oracle: fp32 tolerance.
    np.testing.assert_allclose(res.w, np.asarray(mono.w), rtol=2e-3, atol=2e-3)


def test_full_G_never_device_materialized(monkeypatch):
    """Every H2D move is an explicit <= tile-row block put; a stray implicit
    transfer (the old solve_batch-on-host-G failure mode) raises under the
    guard, and the spy pins the largest block shape."""
    G, tasks, _ = _problem()
    cfg = SolverConfig(tol=1e-2, max_epochs=120)
    tile = 96
    puts = []
    orig = ss._put

    def spy(a, device=None):
        puts.append(np.shape(a))
        return orig(a, device)

    monkeypatch.setattr(ss, "_put", spy)
    guard = getattr(jax, "transfer_guard_host_to_device", None)
    cm = guard("disallow") if guard is not None else None
    if cm is None:
        pytest.skip("no transfer guard in this jax")
    with cm:
        solve_batch_streamed(G, tasks, cfg,
                             stream_config=StreamConfig(tile_rows=tile))
    two_d = [s for s in puts if len(s) == 2]
    assert two_d, "no G blocks streamed?"
    assert max(s[0] for s in two_d) == tile
    assert np.shape(G) not in two_d
    # sanity: the guard actually fires on the monolithic host-G path
    with guard("disallow"):
        with pytest.raises(Exception):
            solve_batch(G, tasks, SolverConfig(tol=1e-2, max_epochs=1))


def test_shrinking_cuts_h2d_bytes():
    """Bucket compaction streams only active-row blocks: cheap-epoch H2D
    bytes drop well below the full-pass bytes."""
    G, tasks, _ = _problem(n=480)
    cfg = SolverConfig(tol=1e-4, max_epochs=300)
    _, st = solve_batch_streamed(G, tasks, cfg, return_stats=True,
                                 stream_config=StreamConfig(tile_rows=96))
    assert st.full_passes >= 2 and len(st.active_history) >= 1
    assert min(st.epoch_bytes) < st.epoch_bytes[0] / 2
    cfg_off = SolverConfig(tol=1e-4, max_epochs=300, shrink=False)
    _, st_off = solve_batch_streamed(G, tasks, cfg_off, return_stats=True,
                                     stream_config=StreamConfig(tile_rows=96))
    per_epoch_on = st.rows_streamed / st.epochs
    per_epoch_off = st_off.rows_streamed / st_off.epochs
    assert per_epoch_on < per_epoch_off


# ------------------------------------------------------------ bf16 blocks

@pytest.mark.parametrize("dataset", ["checker", "spiral"])
def test_bf16_blocks_parity_tolerance(dataset):
    """`StreamConfig.block_dtype="bf16"` halves the streamed G bytes (the
    ROADMAP's bandwidth-doubling epilogue, measured on the wire) while the
    solution stays within tolerance of the fp32 monolithic solve on the
    classic RBF stress suites."""
    from repro.data import make_checker, make_two_spirals
    if dataset == "checker":
        x, y = make_checker(500, seed=3)
        kp = KernelParams("rbf", gamma=8.0)
    else:
        x, y = make_two_spirals(500, seed=4)
        kp = KernelParams("rbf", gamma=16.0)
    _, labels = np.unique(y, return_inverse=True)
    fac = compute_factor(jnp.asarray(x, jnp.float32), kp, 128)
    G = np.asarray(fac.G)
    n, rank = G.shape
    tasks, _ = build_ovo_tasks(labels, 2, 8.0)
    cfg = SolverConfig(tol=1e-2, max_epochs=300)
    mono = solve_batch(jnp.asarray(G), tasks, cfg)
    tile = 96
    _, s32 = solve_batch_streamed(
        G, tasks, cfg, return_stats=True,
        stream_config=StreamConfig(tile_rows=tile))
    res, sbf = solve_batch_streamed(
        G, tasks, cfg, return_stats=True,
        stream_config=StreamConfig(tile_rows=tile, block_dtype="bf16"))
    assert sbf.block_dtype == "bf16"
    # wire bytes: the G component of the first full pass halves exactly
    import math
    g32 = math.ceil(n / tile) * tile * rank * 4
    assert s32.epoch_bytes[0] - sbf.epoch_bytes[0] == g32 // 2
    # solution tolerance: weights, box feasibility, decisions, objective
    w_m = np.asarray(mono.w)
    assert np.max(np.abs(res.w - w_m)) <= 0.05 * np.max(np.abs(w_m))
    assert (res.alpha >= 0).all()
    assert (res.alpha <= np.asarray(tasks.c) + 1e-6).all()
    dec_m = G @ w_m.T
    dec_b = G @ res.w.T
    pred_m = (dec_m[:, 0] <= 0)
    pred_b = (dec_b[:, 0] <= 0)
    assert np.mean(pred_m != pred_b) <= 0.01
    err_m = np.mean(pred_m != (labels == 1))
    err_b = np.mean(pred_b != (labels == 1))
    assert abs(err_b - err_m) <= 0.02
    np.testing.assert_allclose(res.dual_obj, np.asarray(mono.dual_obj),
                               rtol=5e-3)
    # bf16 still converges below tol
    assert (res.violation < cfg.tol).all()


# ------------------------------------------------------------ int8 blocks

@pytest.mark.parametrize("dataset", ["checker", "spiral"])
def test_int8_blocks_parity_tolerance(dataset):
    """`StreamConfig.block_dtype="int8"` quarters the streamed G bytes
    (scales included — the exact byte model is asserted) while the solution
    stays within tolerance of the fp32 monolithic solve on the classic RBF
    stress suites: <= 1% decision flips, dual objective within rtol 5e-3,
    converged below the same tol."""
    import math
    from repro.core.quant import quant_scale_bytes
    from repro.core.solver_stream import wire_group
    from repro.data import make_checker, make_two_spirals
    if dataset == "checker":
        x, y = make_checker(500, seed=3)
        kp = KernelParams("rbf", gamma=8.0)
    else:
        x, y = make_two_spirals(500, seed=4)
        kp = KernelParams("rbf", gamma=16.0)
    _, labels = np.unique(y, return_inverse=True)
    fac = compute_factor(jnp.asarray(x, jnp.float32), kp, 128)
    G = np.asarray(fac.G)
    n, rank = G.shape
    tasks, _ = build_ovo_tasks(labels, 2, 8.0)
    cfg = SolverConfig(tol=1e-2, max_epochs=300)
    mono = solve_batch(jnp.asarray(G), tasks, cfg)
    tile = 96
    scfg8 = StreamConfig(tile_rows=tile, block_dtype="int8")
    _, s32 = solve_batch_streamed(
        G, tasks, cfg, return_stats=True,
        stream_config=StreamConfig(tile_rows=tile))
    res, s8 = solve_batch_streamed(G, tasks, cfg, return_stats=True,
                                   stream_config=scfg8)
    assert s8.block_dtype == "int8"
    # wire bytes: the G component of the first full pass quarters exactly,
    # scale-table bytes INCLUDED (the per-block (ng, 2) f32 tables)
    nb = math.ceil(n / tile)
    eff = wire_group(tile, scfg8)
    g32 = nb * tile * rank * 4
    g8 = nb * (tile * rank + quant_scale_bytes(tile, eff))
    assert s32.epoch_bytes[0] - s8.epoch_bytes[0] == g32 - g8
    assert g32 > 3 * g8                  # >= 3x with scales counted
    assert s8.bytes_scales > 0
    # solution tolerance: weights, box feasibility, decisions, objective
    w_m = np.asarray(mono.w)
    assert np.max(np.abs(res.w - w_m)) <= 0.1 * np.max(np.abs(w_m))
    assert (res.alpha >= 0).all()
    assert (res.alpha <= np.asarray(tasks.c) + 1e-6).all()
    pred_m = (G @ w_m.T)[:, 0] <= 0
    pred_8 = (G @ res.w.T)[:, 0] <= 0
    assert np.mean(pred_m != pred_8) <= 0.01
    err_m = np.mean(pred_m != (labels == 1))
    err_8 = np.mean(pred_8 != (labels == 1))
    assert abs(err_8 - err_m) <= 0.02
    np.testing.assert_allclose(res.dual_obj, np.asarray(mono.dual_obj),
                               rtol=5e-3)
    # int8 still converges below tol
    assert (res.violation < cfg.tol).all()


def test_int8_shrinking_consistency_and_byte_decay():
    """Shrinking through the int8 wire: compacted cheap epochs re-encode
    rows with their GLOBAL group scales, so the full-pass KKT check sees the
    same perturbed problem and converges in the monolithic epoch count —
    and the compaction still cuts per-epoch H2D bytes."""
    G, tasks, _ = _problem(n=480)
    cfg = SolverConfig(tol=1e-4, max_epochs=300)
    mono = solve_batch(jnp.asarray(G), tasks, cfg)
    res, st = solve_batch_streamed(
        G, tasks, cfg, return_stats=True,
        stream_config=StreamConfig(tile_rows=96, block_dtype="int8"))
    assert (res.violation < cfg.tol).all()
    # quantisation may cost a shrinking verification cycle (20-epoch
    # cadence) per task, but must not stall the full-pass KKT check — the
    # failure mode of re-grouped (inconsistent) compacted encodings is
    # epochs pinned at max_epochs
    assert res.epochs.max() < cfg.max_epochs
    assert res.epochs.sum() <= np.asarray(mono.epochs).sum() \
        + 20 * tasks.n_tasks + 8
    assert st.full_passes >= 2 and len(st.active_history) >= 1
    assert min(st.epoch_bytes) < st.epoch_bytes[0] / 2


def test_int8_warm_start_parity():
    """Warm starts (the C-grid pattern) flow through the quantised wire: the
    init pass accumulates w0 from dequantised blocks and converges in no
    more epochs than a cold int8 solve."""
    G, tasks, labels = _problem(C=1.0)
    cfg = SolverConfig(tol=1e-2, max_epochs=300)
    scfg = StreamConfig(tile_rows=96, block_dtype="int8")
    first = solve_batch_streamed(G, tasks, cfg, stream_config=scfg)
    warm = [np.asarray(a) for a in np.asarray(first.alpha)]
    tasks4, _ = build_ovo_tasks(labels, 3, 4.0, alpha0=warm)
    res = solve_batch_streamed(G, tasks4, cfg, stream_config=scfg)
    cold4, _ = build_ovo_tasks(labels, 3, 4.0)
    cold = solve_batch_streamed(G, cold4, cfg, stream_config=scfg)
    assert res.epochs.sum() <= cold.epochs.sum()
    assert (res.violation < cfg.tol).all()
    mono = solve_batch(jnp.asarray(G), tasks4, cfg)
    w_m = np.asarray(mono.w)
    assert np.max(np.abs(res.w - w_m)) <= 0.1 * np.max(np.abs(w_m))


def test_int8_wire_never_ships_f32_blocks(monkeypatch):
    """Every 2-D H2D block put on the int8 wire is int8 values or an (ng, 2)
    scale table — no fp32 G block ever crosses the bus."""
    G, tasks, _ = _problem()
    cfg = SolverConfig(tol=1e-2, max_epochs=60)
    puts = []
    orig = ss._put

    def spy(a, device=None):
        puts.append((np.shape(a), np.asarray(a).dtype))
        return orig(a, device)

    monkeypatch.setattr(ss, "_put", spy)
    solve_batch_streamed(G, tasks, cfg,
                         stream_config=StreamConfig(tile_rows=96,
                                                    block_dtype="int8"))
    two_d = [(s, dt) for s, dt in puts if len(s) == 2]
    assert two_d
    for shape, dt in two_d:
        assert dt == np.int8 or shape[1] == 2, (shape, dt)


# ------------------------------------------------------------- budget model

def test_stage2_memory_model_accounting():
    n, rank, T, n_pad = 10_000, 128, 3, 8_000
    assert stage2_resident_bytes(rank, T) == T * rank * 4
    assert stage2_block_bytes(100, rank, T) == 100 * (rank + 7 * T) * 4
    assert stage2_monolithic_bytes(n, rank, T, n_pad) == \
        (n * rank + T * (7 * n_pad + 2 * rank)) * 4
    small = auto_tile_rows(n, rank, T, StreamConfig(device_budget_bytes=1 << 20))
    large = auto_tile_rows(n, rank, T, StreamConfig(device_budget_bytes=1 << 28))
    assert small < large <= -(-n // 8) * 8
    assert auto_tile_rows(n, rank, T, StreamConfig(tile_rows=100)) == 104
    cfg = StreamConfig(device_budget_bytes=1 << 22)
    tile = auto_tile_rows(n, rank, T, cfg)
    if tile > cfg.min_chunk_rows:
        assert cfg.prefetch * stage2_block_bytes(tile, rank, T) \
            + stage2_resident_bytes(rank, T) <= cfg.device_budget_bytes
    assert should_stream_stage2(100_000, 512, 10, 80_000,
                                StreamConfig(device_budget_bytes=1 << 20))
    assert not should_stream_stage2(100, 16, 1, 100,
                                    StreamConfig(device_budget_bytes=1 << 30))


# ----------------------------------------------------------- entry points

def test_fit_streams_both_stages_under_budget():
    x, y = make_multiclass(500, p=6, n_classes=3, seed=2)
    plain = LPDSVM(KP, C=2.0, budget=96).fit(x, y)
    assert not plain.stats.stage2_streamed
    tiny = StreamConfig(device_budget_bytes=256 << 10)
    routed = LPDSVM(KP, C=2.0, budget=96, stream_config=tiny).fit(x, y)
    assert routed.stats.stage1_streamed and routed.stats.stage2_streamed
    assert routed.stats.stage2_stats is not None
    assert routed.stats.stage2_stats.rows_streamed > 0
    np.testing.assert_allclose(np.asarray(routed.W_), np.asarray(plain.W_),
                               rtol=1e-4, atol=1e-4)
    assert routed.score(x, y) == plain.score(x, y)
    np.testing.assert_array_equal(routed.predict_from_factor(),
                                  routed.predict(x))


def test_fit_respects_custom_solve_fn():
    calls = []

    def my_solve(G, tasks, config):
        calls.append(1)
        return solve_batch(jnp.asarray(np.asarray(G)), tasks, config)

    x, y = make_multiclass(200, p=4, n_classes=2, seed=3)
    svm = LPDSVM(KP, C=1.0, budget=48, solve_fn=my_solve,
                 stream_config=StreamConfig(device_budget_bytes=64 << 10))
    svm.fit(x, y)
    assert calls and not svm.stats.stage2_streamed


def test_cross_validate_routes_streamed():
    x, y = make_multiclass(400, p=5, n_classes=3, seed=4)
    err_plain, _ = cross_validate(x, y, KP, 2.0, budget=64, folds=3)
    tiny = StreamConfig(device_budget_bytes=128 << 10)
    err_stream, fac = cross_validate(x, y, KP, 2.0, budget=64, folds=3,
                                     stream_config=tiny)
    assert fac.streamed
    assert abs(err_plain - err_stream) < 1e-6


def test_polish_final_level_streams_int8():
    """`solve_polished` threads the quantised wire into its routed FINAL
    level: a forced-stream polish with `block_dtype="int8"` records int8
    stream stats and still matches the plain polished fit's predictions."""
    from repro.core import make_schedule, solve_polished
    x, y = make_multiclass(400, p=6, n_classes=3, seed=5)
    _, labels = np.unique(y, return_inverse=True)
    fac = compute_factor(jnp.asarray(x, jnp.float32), KP, 64)
    tasks, _ = build_ovo_tasks(labels, 3, 4.0)
    cfg = SolverConfig(tol=1e-2, max_epochs=300)
    sched = make_schedule(levels=2)
    res_plain = solve_polished(fac, tasks, cfg, sched)
    fac_host = type(fac)(G=np.asarray(fac.G), landmarks=fac.landmarks,
                         projector=fac.projector, eigvals=fac.eigvals,
                         effective_rank=fac.effective_rank, kernel=fac.kernel,
                         streamed=True)
    res8, trace = solve_polished(
        fac_host, tasks, cfg, sched, stream=True,
        stream_config=StreamConfig(tile_rows=96, block_dtype="int8"),
        return_trace=True)
    assert trace.final.streamed
    assert trace.final.stream_stats.block_dtype == "int8"
    assert trace.final.stream_stats.bytes_scales > 0
    G = np.asarray(fac.G)
    from repro.core.ovo import class_pairs, ovo_vote
    pairs = class_pairs(3)
    v_plain = ovo_vote(G @ np.asarray(res_plain.w).T, pairs, 3)
    v8 = ovo_vote(G @ np.asarray(res8.w).T, pairs, 3)
    assert np.mean(v_plain == v8) >= 0.99


def test_streamed_mesh_single_device_matches():
    from repro.compat import make_mesh
    from repro.core import solve_tasks_streamed_mesh
    G, tasks, _ = _problem(n=240, budget=48)
    cfg = SolverConfig(tol=1e-2, max_epochs=120)
    mesh = make_mesh((1,), ("data",))
    res = solve_tasks_streamed_mesh(mesh, G, tasks, cfg,
                                    stream_config=StreamConfig(tile_rows=64))
    mono = solve_batch(jnp.asarray(G), tasks, cfg)
    _assert_matches(mono, res)
