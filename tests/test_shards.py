"""Chaos suite for the durable disk tier (core/shards.py).

The store's promises are the strong ones:

  * TORN WRITES NEVER LIE — kill -9 at any point of an ingest leaves a
    directory that either loads verified-clean or refuses with a ShardError
    naming exactly what to rebuild (the manifest is written LAST, atomically);
  * SILENT BIT ROT CANNOT PASS — every single-byte corruption of a shard
    file is caught by the footer digest, quarantined, and rebuilt from
    source BIT-EQUAL (the codec and the chunking are deterministic);
  * THE DISK TIER IS INVISIBLE TO THE MATH — shard-backed stage 1 and a
    shard-spilled G driving stage 2 are bit-equal to the host-RAM streams,
    per wire dtype and device count, with the per-pass H2D invariant intact.

All faults are deterministic (`core.faults` sites shard_write / shard_read /
shard_corrupt), mirroring tests/test_resilience.py.
"""
import glob
import os
import signal
import subprocess
import sys
import time

import numpy as np
import pytest

import jax

from repro.core import (GShardView, KernelParams, ShardCorruptionError,
                        ShardError, ShardStore, ShardStoreStats, SolverConfig,
                        StreamConfig, build_ovo_tasks,
                        compute_factor_streamed,
                        compute_factor_streamed_shards, ingest_libsvm_shards,
                        open_or_ingest, solve_batch_streamed)
from repro.core import faults as F
from repro.core import shards as SH
from repro.core.quant import GROUP_ROWS, dequantize_rows, quantize_rows
from repro.core.trace import Tracer
from repro.data import make_multiclass, write_libsvm
from repro.data.libsvm_format import read_libsvm_rows_range

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

try:
    import hypothesis
    import hypothesis.strategies as hst
    from hypothesis import given, settings
    HAVE_HYP = True
except ImportError:                                    # dev dep; CI installs
    HAVE_HYP = False


@pytest.fixture(autouse=True)
def _no_leftover_plan():
    yield
    F.uninstall()


def run_sub(code: str, n_dev: int = 2, timeout: int = 900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def _toy_libsvm(tmp_path, n=200, p=9, seed=0, name="toy.svm"):
    """LIBSVM text + its canonical parsed f32 (text round-trip loses the
    f32 bit pattern via %g, so parity baselines PARSE, never reuse x)."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, p)).astype(np.float32)
    y = rng.choice([-1.0, 1.0], size=n)
    path = str(tmp_path / name)
    write_libsvm(path, x, y)
    dense, labels = read_libsvm_rows_range(path, 0, n, p)
    return path, dense, labels


def _flip(path, offset=None):
    F._flip_byte(path, offset)


# --------------------------------------------------------------------------
# codec / store roundtrip
# --------------------------------------------------------------------------

def test_roundtrip_f32(tmp_path):
    path, x, y = _toy_libsvm(tmp_path)
    store = ingest_libsvm_shards(path, str(tmp_path / "s"), n_features=9,
                                 shard_rows=64)
    assert (store.n, store.cols, store.n_shards) == (200, 9, 4)
    np.testing.assert_array_equal(store.read_rows(0, store.n), x)
    np.testing.assert_array_equal(store.labels(), y)
    np.testing.assert_array_equal(store.read_rows(60, 130), x[60:130])
    np.testing.assert_array_equal(store.gather_rows([199, 0, 64, 63]),
                                  x[[199, 0, 64, 63]])
    assert store.verify_all() == []
    # identity survives reopen
    again = ShardStore(str(tmp_path / "s"))
    assert again.fingerprint == store.fingerprint
    assert int(store.manifest["rows_read"]) == 200


def test_roundtrip_int8_stored_codes_are_the_wire_codes(tmp_path):
    path, x, _ = _toy_libsvm(tmp_path, seed=3)
    store = ingest_libsvm_shards(path, str(tmp_path / "s8"), n_features=9,
                                 shard_rows=64, dtype="int8")
    for i in range(store.n_shards):
        lo, hi = store.shard_range(i)
        qb = store.read_shard(i, wire=True)
        v, s = quantize_rows(x[lo:hi], GROUP_ROWS, symmetric=True)
        np.testing.assert_array_equal(qb.values, v)
        np.testing.assert_array_equal(qb.scales, s)
        np.testing.assert_array_equal(store.read_shard(i),
                                      dequantize_rows(v, s, GROUP_ROWS))
    # partial reads decode only the touched scale groups (cache off) yet
    # match the full decode bitwise
    cold = ShardStore(str(tmp_path / "s8"), cache_shards=0)
    np.testing.assert_array_equal(cold.read_rows(37, 170),
                                  store.read_rows(0, store.n)[37:170])


def test_wire_read_requires_int8(tmp_path):
    path, _, _ = _toy_libsvm(tmp_path)
    store = ingest_libsvm_shards(path, str(tmp_path / "s"), n_features=9,
                                 shard_rows=64)
    with pytest.raises(ShardError, match="int8"):
        store.read_shard(0, wire=True)


def test_config_validation():
    with pytest.raises(ValueError, match="multiple"):
        StreamConfig(shard_rows=100)
    with pytest.raises(ValueError, match="shard_dir"):
        StreamConfig(spill_g=True)
    with pytest.raises(ValueError, match="checkpoint_keep"):
        StreamConfig(checkpoint_keep=-1)


# --------------------------------------------------------------------------
# torn writes: interrupted ingest can never produce a readable-but-wrong store
# --------------------------------------------------------------------------

def test_simulated_kill_mid_ingest_leaves_no_manifest(tmp_path):
    path, x, y = _toy_libsvm(tmp_path)
    d = str(tmp_path / "s")
    F.install(F.FaultPlan().add("shard_write", kind="kill", shard=2))
    with pytest.raises(F.SimulatedKill):
        ingest_libsvm_shards(path, d, n_features=9, shard_rows=64)
    F.uninstall()
    with pytest.raises(ShardError, match="re-ingest"):
        ShardStore(d)
    # re-ingest over the debris converges to a clean verified store
    store = ingest_libsvm_shards(path, d, n_features=9, shard_rows=64)
    np.testing.assert_array_equal(store.read_rows(0, store.n), x)
    np.testing.assert_array_equal(store.labels(), y)
    assert store.verify_all() == []


def test_real_sigkill_mid_ingest(tmp_path):
    """kill -9 the writer process at an arbitrary real point: the store
    either loads verified-clean or refuses naming the interrupted ingest."""
    path, x, y = _toy_libsvm(tmp_path, n=400)
    d = str(tmp_path / "s")
    code = f"""
import sys, time
from repro.core.shards import ingest_libsvm_shards
import repro.core.shards as SH
_orig = SH._fsync_write
def slow(path, buffers):
    r = _orig(path, buffers)
    print("WROTE", path, flush=True)
    time.sleep(0.25)
    return r
SH._fsync_write = slow
ingest_libsvm_shards({path!r}, {d!r}, n_features=9, shard_rows=64)
print("DONE", flush=True)
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    proc = subprocess.Popen([sys.executable, "-c", code], env=env,
                            stdout=subprocess.PIPE, text=True)
    # wait until at least one shard landed, then SIGKILL mid-write window
    deadline = time.time() + 120
    seen = 0
    while time.time() < deadline and seen < 2:
        line = proc.stdout.readline()
        if line.startswith("WROTE"):
            seen += 1
        if line.startswith("DONE"):
            break
    proc.kill()
    proc.wait()
    assert seen >= 1, "writer never produced a shard"
    try:
        store = ShardStore(d)
        # manifest landed => the store MUST be complete and verified-clean
        np.testing.assert_array_equal(store.read_rows(0, store.n), x)
        assert store.verify_all() == []
    except ShardError as exc:
        assert "re-ingest" in str(exc) or "missing" in str(exc)
    # and recovery is always just: ingest again
    store = ingest_libsvm_shards(path, d, n_features=9, shard_rows=64)
    np.testing.assert_array_equal(store.read_rows(0, store.n), x)
    np.testing.assert_array_equal(store.labels(), y)


# --------------------------------------------------------------------------
# bit rot: detect -> quarantine -> rebuild bit-equal
# --------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", ["f32", "int8"])
def test_bitflip_detected_quarantined_rebuilt_bit_equal(tmp_path, dtype):
    path, x, y = _toy_libsvm(tmp_path, seed=5)
    d = str(tmp_path / "s")
    store = ingest_libsvm_shards(path, d, n_features=9, shard_rows=64,
                                 dtype=dtype)
    before = store.read_rows(0, store.n).copy()
    shard = os.path.join(d, SH.shard_name(1))
    _flip(shard)

    tr = Tracer()
    st = ShardStoreStats()
    fresh = ShardStore(d, stats=st, trace=tr)
    SH.attach_source_rebuilder(fresh, path)
    after = fresh.read_rows(0, fresh.n)
    np.testing.assert_array_equal(after, before)          # bit-equal rebuild
    np.testing.assert_array_equal(fresh.labels(), y)
    assert st.checksum_failures == 1
    assert st.quarantined == 1
    assert st.rebuilt == 1
    # the rotten file is preserved for forensics, not deleted
    assert os.path.exists(os.path.join(d, SH.QUARANTINE_DIR,
                                       SH.shard_name(1)))
    names = [(e[1], e[2]) for e in tr.events()]
    assert ("fault", "shard_corrupt") in names
    assert ("recovery", "shard_rebuilt") in names
    # the rebuilt file is byte-identical: a re-read verifies clean
    assert ShardStore(d).verify_all() == []


def test_bitflip_without_rebuilder_raises(tmp_path):
    path, _, _ = _toy_libsvm(tmp_path)
    d = str(tmp_path / "s")
    ingest_libsvm_shards(path, d, n_features=9, shard_rows=64)
    _flip(os.path.join(d, SH.shard_name(2)))
    store = ShardStore(d)      # no rebuilder attached
    with pytest.raises(ShardCorruptionError, match="no rebuilder"):
        store.read_rows(0, store.n)


def test_missing_shards_reported_exactly(tmp_path):
    path, x, _ = _toy_libsvm(tmp_path)
    d = str(tmp_path / "s")
    ingest_libsvm_shards(path, d, n_features=9, shard_rows=64)
    os.remove(os.path.join(d, SH.shard_name(0)))
    os.remove(os.path.join(d, SH.shard_name(3)))
    with pytest.raises(ShardError) as exc:
        ShardStore(d)
    assert SH.shard_name(0) in str(exc.value)
    assert SH.shard_name(3) in str(exc.value)
    # re-ingest heals the store completely
    healed = ingest_libsvm_shards(path, d, n_features=9, shard_rows=64)
    np.testing.assert_array_equal(healed.read_rows(0, healed.n), x)


def test_missing_shard_rebuilds_from_source(tmp_path):
    path, x, _ = _toy_libsvm(tmp_path)
    d = str(tmp_path / "s")
    store = ingest_libsvm_shards(path, d, n_features=9, shard_rows=64)
    os.remove(os.path.join(d, SH.shard_name(1)))
    st = ShardStoreStats()
    fresh = ShardStore(d, stats=st,
                       rebuilder=store.rebuilder)   # source re-parse closure
    np.testing.assert_array_equal(fresh.read_rows(0, fresh.n), x)
    assert st.rebuilt == 1 and st.quarantined == 0


def test_rebuild_refuses_changed_source(tmp_path):
    path, _, _ = _toy_libsvm(tmp_path)
    d = str(tmp_path / "s")
    ingest_libsvm_shards(path, d, n_features=9, shard_rows=64)
    _flip(os.path.join(d, SH.shard_name(1)))
    with open(path) as f:
        lines = f.readlines()
    lines[70] = "1 1:9.75 2:-3.5\n"          # row inside shard 1's range
    with open(path, "w") as f:
        f.writelines(lines)
    store = ShardStore(d)
    SH.attach_source_rebuilder(store, path)
    with pytest.raises(ShardError, match="source changed"):
        store.read_rows(0, store.n)


def test_every_single_byte_corruption_detected(tmp_path):
    """Exhaustive: flip EVERY byte of a shard file in turn — the verified
    read must refuse each one (header, payload, labels, footer alike)."""
    path, _, _ = _toy_libsvm(tmp_path, n=40, p=3)
    d = str(tmp_path / "s")
    ingest_libsvm_shards(path, d, n_features=3, shard_rows=32)
    shard = os.path.join(d, SH.shard_name(0))
    raw = open(shard, "rb").read()
    store = ShardStore(d, cache_shards=0)
    for off in range(len(raw)):
        bad = bytearray(raw)
        bad[off] ^= 0x01
        with open(shard, "wb") as f:
            f.write(bad)
        with pytest.raises(ShardCorruptionError):
            store._load(0)
    with open(shard, "wb") as f:
        f.write(raw)
    store._load(0)                                   # restored: clean again


# --------------------------------------------------------------------------
# transient IO: bounded retry vs fail-fast
# --------------------------------------------------------------------------

def test_transient_io_retry_recovers(tmp_path):
    path, x, _ = _toy_libsvm(tmp_path)
    d = str(tmp_path / "s")
    ingest_libsvm_shards(path, d, n_features=9, shard_rows=64)
    tr = Tracer()
    st = ShardStoreStats()
    store = ShardStore(d, retries=3, retry_backoff=0.0, stats=st, trace=tr)
    F.install(F.FaultPlan().add("shard_read", kind="io", times=2, shard=1))
    np.testing.assert_array_equal(store.read_rows(0, store.n), x)
    assert st.retries == 2
    names = [(e[1], e[2]) for e in tr.events()]
    assert ("fault", "shard_read_retry") in names
    assert ("recovery", "shard_read_ok") in names


def test_transient_io_fail_fast(tmp_path):
    path, _, _ = _toy_libsvm(tmp_path)
    d = str(tmp_path / "s")
    ingest_libsvm_shards(path, d, n_features=9, shard_rows=64)
    store = ShardStore(d, retries=0)
    F.install(F.FaultPlan().add("shard_read", kind="io", shard=1))
    with pytest.raises(F.InjectedIOError):
        store.read_rows(0, store.n)


def test_retry_budget_exhausted_raises(tmp_path):
    path, _, _ = _toy_libsvm(tmp_path)
    d = str(tmp_path / "s")
    ingest_libsvm_shards(path, d, n_features=9, shard_rows=64)
    store = ShardStore(d, retries=2, retry_backoff=0.0)
    F.install(F.FaultPlan().add("shard_read", kind="io", times=5, shard=0))
    with pytest.raises(F.InjectedIOError):
        store.read_rows(0, 10)
    assert store.stats.retries == 2


# --------------------------------------------------------------------------
# parse-once: re-runs never touch the text
# --------------------------------------------------------------------------

def test_open_or_ingest_reuses_without_parsing(tmp_path, monkeypatch):
    path, x, y = _toy_libsvm(tmp_path)
    d = str(tmp_path / "s")
    _, ingested = open_or_ingest(path, d, n_features=9, shard_rows=64)
    assert ingested

    import repro.data.libsvm_format as lf

    def _boom(*a, **k):
        raise AssertionError("reused store must not re-parse the text")

    monkeypatch.setattr(lf, "read_libsvm", _boom)
    monkeypatch.setattr(lf, "read_libsvm_blocks", _boom)
    monkeypatch.setattr(lf, "count_libsvm_rows", _boom)
    store, ingested = open_or_ingest(path, d, n_features=9, shard_rows=64)
    assert not ingested
    assert store.n == 200                      # row count from the manifest
    np.testing.assert_array_equal(store.labels(), y)
    np.testing.assert_array_equal(store.read_rows(0, store.n), x)


def test_open_or_ingest_invalidates_on_change(tmp_path):
    path, _, _ = _toy_libsvm(tmp_path)
    d = str(tmp_path / "s")
    open_or_ingest(path, d, n_features=9, shard_rows=64)
    # different shard size -> re-ingest
    _, again = open_or_ingest(path, d, n_features=9, shard_rows=128)
    assert again
    # edited source -> re-ingest (fingerprint covers content, not mtime)
    with open(path, "a") as f:
        f.write("1 1:0.5\n")
    _, again = open_or_ingest(path, d, n_features=9, shard_rows=128)
    assert again


# --------------------------------------------------------------------------
# stage-1 parity: the disk tier is numerically invisible
# --------------------------------------------------------------------------

def _parity_problem(tmp_path, seed=7):
    path, x, y = _toy_libsvm(tmp_path, n=300, seed=seed)
    store = ingest_libsvm_shards(path, str(tmp_path / "s"), n_features=9,
                                 shard_rows=64)
    return path, x, y, store


@pytest.mark.parametrize("wire", ["f32", "int8"])
def test_stage1_shard_parity(tmp_path, wire):
    _, x, _, store = _parity_problem(tmp_path)
    params = KernelParams("rbf", gamma=0.5)
    cfg = StreamConfig(chunk_rows=64, stage1_dtype=wire)
    host = compute_factor_streamed(x, params, 48, config=cfg)
    shrd = compute_factor_streamed_shards(store, params, 48, config=cfg)
    np.testing.assert_array_equal(np.asarray(host.G), np.asarray(shrd.G))
    np.testing.assert_array_equal(np.asarray(host.landmarks),
                                  np.asarray(shrd.landmarks))


def test_stage1_int8_store_passthrough_deterministic(tmp_path):
    path, x, _, _ = _parity_problem(tmp_path)
    st8 = ingest_libsvm_shards(path, str(tmp_path / "s8"), n_features=9,
                               shard_rows=64, dtype="int8")
    params = KernelParams("rbf", gamma=0.5)
    cfg = StreamConfig(chunk_rows=64, stage1_dtype="int8")
    a = compute_factor_streamed_shards(st8, params, 48, config=cfg)
    b = compute_factor_streamed_shards(st8, params, 48, config=cfg)
    np.testing.assert_array_equal(np.asarray(a.G), np.asarray(b.G))
    # stored codes went straight to the wire: no host re-encode was traced
    assert a.stage1_stats.bytes_scales > 0


# --------------------------------------------------------------------------
# G spill: stage 2 off the disk tier, bit-equal per wire dtype
# --------------------------------------------------------------------------

def _spilled_factor(tmp_path, store, gamma=0.5):
    params = KernelParams("rbf", gamma=gamma)
    cfg = StreamConfig(chunk_rows=64, shard_dir=str(tmp_path / "spill"),
                       shard_rows=64, spill_g=True)
    return compute_factor_streamed_shards(store, params, 48, config=cfg)


def test_spill_g_matches_host_factor(tmp_path):
    _, x, _, store = _parity_problem(tmp_path)
    host = compute_factor_streamed(x, KernelParams("rbf", gamma=0.5), 48,
                                   config=StreamConfig(chunk_rows=64))
    spill = _spilled_factor(tmp_path, store)
    G = spill.G
    assert isinstance(G, GShardView) and G.is_shard_view
    assert G.shape == np.asarray(host.G).shape
    np.testing.assert_array_equal(np.asarray(G), np.asarray(host.G))


def test_spilled_g_corrupt_rebuild_bit_equal(tmp_path):
    _, x, _, store = _parity_problem(tmp_path)
    spill = _spilled_factor(tmp_path, store)
    G = spill.G
    want = np.asarray(G).copy()
    shard = sorted(glob.glob(str(tmp_path / "spill" / "g_spill" /
                                 "shard_*.bin")))[2]
    _flip(shard)
    G.store._cache.clear()
    np.testing.assert_array_equal(np.asarray(G), want)
    assert G.store.stats.rebuilt == 1
    assert G.store.stats.quarantined == 1


@pytest.mark.parametrize("wire", ["f32", "bf16", "int8"])
def test_stage2_from_shard_view_bit_equal(tmp_path, wire):
    _, x, labels01, store = _parity_problem(tmp_path)
    labels = (labels01 > 0).astype(int)
    host = compute_factor_streamed(x, KernelParams("rbf", gamma=0.5), 48,
                                   config=StreamConfig(chunk_rows=64))
    spill = _spilled_factor(tmp_path, store)
    Gh = np.asarray(host.G)
    tasks, _ = build_ovo_tasks(labels, 2, 1.0)
    cfg = SolverConfig(tol=1e-3, max_epochs=30)
    sc = StreamConfig(tile_rows=64, block_dtype=wire)
    a = solve_batch_streamed(Gh, tasks, cfg, stream_config=sc)
    b = solve_batch_streamed(spill.G, tasks, cfg, stream_config=sc)
    np.testing.assert_array_equal(np.asarray(a.alpha), np.asarray(b.alpha))
    np.testing.assert_array_equal(np.asarray(a.w), np.asarray(b.w))
    np.testing.assert_array_equal(np.asarray(a.epochs), np.asarray(b.epochs))


def test_stage2_warm_start_from_shard_view(tmp_path):
    _, x, labels01, store = _parity_problem(tmp_path)
    labels = (labels01 > 0).astype(int)
    host = compute_factor_streamed(x, KernelParams("rbf", gamma=0.5), 48,
                                   config=StreamConfig(chunk_rows=64))
    spill = _spilled_factor(tmp_path, store)
    cfg = SolverConfig(tol=1e-3, max_epochs=8)
    sc = StreamConfig(tile_rows=64)
    tasks, _ = build_ovo_tasks(labels, 2, 1.0)
    seed = solve_batch_streamed(np.asarray(host.G), tasks, cfg,
                                stream_config=sc)
    warm, _ = build_ovo_tasks(labels, 2, 4.0,
                              alpha0=list(np.asarray(seed.alpha)))
    a = solve_batch_streamed(np.asarray(host.G), warm, cfg, stream_config=sc)
    b = solve_batch_streamed(spill.G, warm, cfg, stream_config=sc)
    np.testing.assert_array_equal(np.asarray(a.alpha), np.asarray(b.alpha))
    np.testing.assert_array_equal(np.asarray(a.w), np.asarray(b.w))


def test_multidevice_farm_from_shard_view():
    """2-device farm off a spilled G: same model as host G, and the shared
    reader's per-pass G bytes unchanged by the disk tier."""
    out = run_sub("""
import os, tempfile, numpy as np, jax
from repro.core import (KernelParams, SolverConfig, StreamConfig,
                        build_ovo_tasks, compute_factor_streamed,
                        compute_factor_streamed_shards, ingest_libsvm_shards,
                        solve_tasks_streamed)
from repro.data import write_libsvm
from repro.data.libsvm_format import read_libsvm_rows_range

assert jax.device_count() == 2
d = tempfile.mkdtemp()
rng = np.random.default_rng(11)
x = rng.normal(size=(240, 7)).astype(np.float32)
y = rng.integers(0, 3, size=240)
path = os.path.join(d, "t.svm")
write_libsvm(path, x, y.astype(float))
xt, yt = read_libsvm_rows_range(path, 0, 240, 7)
store = ingest_libsvm_shards(path, os.path.join(d, "s"), n_features=7,
                             shard_rows=64)
host = compute_factor_streamed(xt, KernelParams("rbf", gamma=0.5), 40,
                               config=StreamConfig(chunk_rows=64))
spill = compute_factor_streamed_shards(
    store, KernelParams("rbf", gamma=0.5), 40,
    config=StreamConfig(chunk_rows=64, shard_dir=os.path.join(d, "sp"),
                        shard_rows=64, spill_g=True))
np.testing.assert_array_equal(np.asarray(host.G), np.asarray(spill.G))
_, labels = np.unique(yt, return_inverse=True)
tasks, _ = build_ovo_tasks(labels, 3, 1.0)
cfg = SolverConfig(tol=1e-3, max_epochs=25)
sc = StreamConfig(tile_rows=64)
a, sa = solve_tasks_streamed(np.asarray(host.G), tasks, cfg,
                             devices=jax.devices(), stream_config=sc,
                             return_stats=True)
b, sb = solve_tasks_streamed(spill.G, tasks, cfg, devices=jax.devices(),
                             stream_config=sc, return_stats=True)
np.testing.assert_array_equal(np.asarray(a.alpha), np.asarray(b.alpha))
np.testing.assert_array_equal(np.asarray(a.w), np.asarray(b.w))
assert sa.epoch_bytes == sb.epoch_bytes, (sa.epoch_bytes, sb.epoch_bytes)
print("OK", sb.n_devices, sb.epoch_bytes[0])
""")
    assert "OK 2" in out


# --------------------------------------------------------------------------
# resume safety: snapshots pin the shard-manifest identity
# --------------------------------------------------------------------------

def test_resume_refuses_mutated_store(tmp_path):
    _, x, labels01, store = _parity_problem(tmp_path)
    labels = (labels01 > 0).astype(int)
    spill = _spilled_factor(tmp_path, store)
    tasks, _ = build_ovo_tasks(labels, 2, 1.0)
    cfg = SolverConfig(tol=1e-3, max_epochs=30)
    ck = str(tmp_path / "ckpt")
    sc = StreamConfig(tile_rows=64, checkpoint_dir=ck, checkpoint_every=1)
    F.install(F.FaultPlan().add("epoch_boundary", kind="kill", epoch=2))
    with pytest.raises(F.SimulatedKill):
        solve_batch_streamed(spill.G, tasks, cfg, stream_config=sc)
    F.uninstall()
    # a DIFFERENT spilled store (other gamma -> other shard digests)
    other = _spilled_factor(tmp_path / "other", store, gamma=0.9)
    assert other.G.g_fingerprint != spill.G.g_fingerprint
    sc2 = StreamConfig(tile_rows=64, checkpoint_dir=ck, checkpoint_every=1,
                       resume=True)
    with pytest.raises(ValueError, match="fingerprint"):
        solve_batch_streamed(other.G, tasks, cfg, stream_config=sc2)
    # the untouched store resumes fine, bit-equal to a clean run
    clean = solve_batch_streamed(spill.G, tasks, cfg,
                                 stream_config=StreamConfig(tile_rows=64))
    res = solve_batch_streamed(spill.G, tasks, cfg, stream_config=sc2)
    np.testing.assert_array_equal(np.asarray(clean.alpha),
                                  np.asarray(res.alpha))
    np.testing.assert_array_equal(np.asarray(clean.w), np.asarray(res.w))


def test_checkpoint_keep_last_k(tmp_path):
    G, tasks, _ = _solver_problem()
    # shrinking off: every epoch is a full pass, so checkpoint_every=1
    # snapshots on every epoch boundary and retention has work to do
    cfg = SolverConfig(tol=1e-4, max_epochs=40, shrink=False)
    d = str(tmp_path / "ck")
    sc = StreamConfig(tile_rows=64, checkpoint_dir=d, checkpoint_every=1,
                      checkpoint_keep=2)
    solve_batch_streamed(G, tasks, cfg, stream_config=sc)
    steps = sorted(f for f in os.listdir(d) if f.startswith("step_"))
    assert len(steps) == 2
    # the survivors are the NEWEST snapshots
    all_d = str(tmp_path / "ck_all")
    sc_all = StreamConfig(tile_rows=64, checkpoint_dir=all_d,
                          checkpoint_every=1, checkpoint_keep=0)
    solve_batch_streamed(G, tasks, cfg, stream_config=sc_all)
    every = sorted(f for f in os.listdir(all_d) if f.startswith("step_"))
    assert len(every) > 2
    assert steps == every[-2:]


def _solver_problem(n=240, classes=3, seed=1, budget=40):
    x, y = make_multiclass(n=n, n_classes=classes, seed=seed)
    _, labels = np.unique(y, return_inverse=True)
    fac = compute_factor_streamed(np.asarray(x, np.float32),
                                  KernelParams("rbf", gamma=0.25), budget,
                                  config=StreamConfig(chunk_rows=64))
    tasks, _ = build_ovo_tasks(labels, classes, 1.0)
    return np.asarray(fac.G), tasks, labels


# --------------------------------------------------------------------------
# hypothesis properties (dev dep; CI runs them, bare containers skip)
# --------------------------------------------------------------------------

if HAVE_HYP:
    hypothesis.settings.register_profile(
        "shards", deadline=None, max_examples=15,
        suppress_health_check=[hypothesis.HealthCheck.too_slow,
                               hypothesis.HealthCheck.function_scoped_fixture])
    hypothesis.settings.load_profile("shards")

    @given(hst.integers(33, 150), hst.integers(1, 6), hst.integers(0, 2**32))
    def test_hyp_store_roundtrip(tmp_path_factory, n, p, seed):
        tmp = tmp_path_factory.mktemp("hyp")
        rng = np.random.default_rng(seed)
        x = rng.normal(size=(n, p)).astype(np.float32)
        y = rng.choice([-1.0, 1.0], size=n)
        path = str(tmp / "d.svm")
        write_libsvm(path, x, y)
        xt, yt = read_libsvm_rows_range(path, 0, n, p)
        store = ingest_libsvm_shards(path, str(tmp / "s"), n_features=p,
                                     shard_rows=32)
        np.testing.assert_array_equal(store.read_rows(0, n), xt)
        np.testing.assert_array_equal(store.labels(), yt)

    @given(hst.integers(0, 2**32), hst.integers(1, 8), hst.integers(0, 10**9))
    def test_hyp_any_corruption_detected(tmp_path_factory, seed, bit, where):
        tmp = tmp_path_factory.mktemp("hypc")
        rng = np.random.default_rng(seed)
        x = rng.normal(size=(64, 4)).astype(np.float32)
        y = rng.choice([-1.0, 1.0], size=64)
        path = str(tmp / "d.svm")
        write_libsvm(path, x, y)
        store = ingest_libsvm_shards(path, str(tmp / "s"), n_features=4,
                                     shard_rows=32)
        shard = os.path.join(str(tmp / "s"), SH.shard_name(0))
        raw = bytearray(open(shard, "rb").read())
        raw[where % len(raw)] ^= (1 << (bit - 1)) or 1
        with open(shard, "wb") as f:
            f.write(raw)
        cold = ShardStore(str(tmp / "s"), cache_shards=0)
        with pytest.raises(ShardCorruptionError):
            cold._load(0)
