"""Stage 2: optimality (KKT / duality gap), shrinking, warm starts, batching."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dual_solver import (SolverConfig, TaskBatch, duality_gap,
                                    solve_batch, solve_one)
from repro.core.kernel_fn import KernelParams
from repro.core.nystrom import compute_factor


def _problem(rng, n=400, C=4.0, budget=128):
    x = rng.normal(size=(n, 5)).astype(np.float32)
    y = np.where(x[:, 0] * x[:, 1] + 0.3 * x[:, 2] > 0, 1.0, -1.0).astype(np.float32)
    fac = compute_factor(jnp.asarray(x), KernelParams("rbf", gamma=0.7), budget)
    idx = jnp.arange(n, dtype=jnp.int32)
    c = jnp.full((n,), C, jnp.float32)
    return fac.G, idx, jnp.asarray(y), c


def test_converges_with_small_gap(rng):
    G, idx, y, c = _problem(rng)
    cfg = SolverConfig(tol=1e-3, max_epochs=3000)
    res = solve_one(G, idx, y, c, jnp.zeros_like(c), cfg)
    assert float(res.violation) < 1e-3
    gap = float(duality_gap(G, idx, y, c, res.alpha))
    assert abs(gap) < 1e-2 * abs(float(res.dual_obj))


def test_alpha_in_box(rng):
    G, idx, y, c = _problem(rng, C=2.0)
    res = solve_one(G, idx, y, c, jnp.zeros_like(c),
                    SolverConfig(tol=1e-2, max_epochs=500))
    a = np.asarray(res.alpha)
    assert a.min() >= 0.0 and a.max() <= 2.0 + 1e-6


def test_shrinking_preserves_solution(rng):
    G, idx, y, c = _problem(rng)
    cfg_on = SolverConfig(tol=1e-3, max_epochs=3000, shrink=True)
    cfg_off = SolverConfig(tol=1e-3, max_epochs=3000, shrink=False)
    r_on = solve_one(G, idx, y, c, jnp.zeros_like(c), cfg_on)
    r_off = solve_one(G, idx, y, c, jnp.zeros_like(c), cfg_off)
    assert abs(float(r_on.dual_obj - r_off.dual_obj)) < 1e-2 * abs(float(r_off.dual_obj))


def test_warm_start_fewer_epochs(rng):
    G, idx, y, c = _problem(rng, C=1.0)
    cfg = SolverConfig(tol=1e-3, max_epochs=3000)
    res1 = solve_one(G, idx, y, c, jnp.zeros_like(c), cfg)
    # re-solve at larger C warm vs cold (paper: warm start over the C grid)
    c2 = 4.0 * c
    warm = jnp.clip(res1.alpha, 0.0, c2)
    res_warm = solve_one(G, idx, y, c2, warm, cfg)
    res_cold = solve_one(G, idx, y, c2, jnp.zeros_like(c), cfg)
    assert int(res_warm.epochs) <= int(res_cold.epochs)
    assert abs(float(res_warm.dual_obj - res_cold.dual_obj)) \
        < 1e-2 * abs(float(res_cold.dual_obj))


def test_padding_inert(rng):
    G, idx, y, c = _problem(rng, n=200)
    cfg = SolverConfig(tol=1e-3, max_epochs=2000)
    res = solve_one(G, idx, y, c, jnp.zeros_like(c), cfg)
    pad = 64
    idx_p = jnp.concatenate([idx, jnp.zeros((pad,), jnp.int32)])
    y_p = jnp.concatenate([y, jnp.ones((pad,))])
    c_p = jnp.concatenate([c, jnp.zeros((pad,))])
    res_p = solve_one(G, idx_p, y_p, c_p, jnp.zeros_like(c_p), cfg)
    assert np.allclose(np.asarray(res_p.alpha[:200]), np.asarray(res.alpha),
                       atol=1e-5)
    assert np.all(np.asarray(res_p.alpha[200:]) == 0.0)


def test_batch_matches_single(rng):
    G, idx, y, c = _problem(rng, n=150)
    cfg = SolverConfig(tol=1e-2, max_epochs=1000)
    single = solve_one(G, idx, y, c, jnp.zeros_like(c), cfg)
    tasks = TaskBatch(idx=jnp.stack([idx] * 3), y=jnp.stack([y] * 3),
                      c=jnp.stack([c, 0.5 * c, 2.0 * c]),
                      alpha0=jnp.zeros((3, 150)))
    res = solve_batch(G, tasks, cfg)
    assert np.allclose(np.asarray(res.w[0]), np.asarray(single.w), atol=1e-4)
    # different C -> different solutions
    assert not np.allclose(np.asarray(res.w[1]), np.asarray(res.w[2]), atol=1e-3)


def test_respects_max_epochs(rng):
    G, idx, y, c = _problem(rng)
    res = solve_one(G, idx, y, c, jnp.zeros_like(c),
                    SolverConfig(tol=1e-9, max_epochs=7))
    assert int(res.epochs) == 7
