"""End-to-end behaviour tests for the paper's system.

The three paper-level claims, reproduced at CPU scale:
  1. the two-stage LPD solver reaches near-exact-solver accuracy (Table 2);
  2. grid search + CV reuses stage 1 and warm starts (Table 3 mechanism);
  3. the full deep-features -> OVO-SVM pipeline trains end to end (ImageNet
     experiment in miniature).
Plus: the LM training loop learns, and serving generates.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.baselines import ExactDualSVM
from repro.core import KernelParams, LPDSVM, SolverConfig, grid_search
from repro.data import make_checker, make_multiclass, train_test_split


def test_claim1_near_exact_accuracy(rng):
    x, y = make_checker(1200, cells=2, seed=21)
    xtr, ytr, xte, yte = train_test_split(x, y, 0.3)
    kp = KernelParams("rbf", gamma=4.0)
    lpd = LPDSVM(kp, C=8.0, budget=400, tol=1e-2).fit(xtr, ytr)
    exact = ExactDualSVM(kp, C=8.0, tol=1e-2).fit(xtr, ytr)
    e_lpd, e_exact = lpd.error(xte, yte), exact.error(xte, yte)
    # paper: "LPD-SVM comes quite close to the (nearly exact) solutions"
    assert e_lpd <= e_exact + 0.03, (e_lpd, e_exact)


def test_claim2_grid_search_shares_stage1(rng):
    x, y = make_multiclass(900, p=8, n_classes=3, seed=22)
    res = grid_search(x, y, gammas=[0.05, 0.2], Cs=[1.0, 8.0], budget=200,
                      folds=3, config=SolverConfig(tol=1e-2, max_epochs=600))
    # 2 gammas x 2 Cs x 3 folds x 3 pairs = 36 binary SVMs, 2 stage-1 runs
    assert res.n_binary_solved == 36
    assert res.best_error < 0.5
    # stage 2 (all 36 solves) must not be dwarfed by repeated stage-1 work:
    # G was computed once per gamma, not once per cell
    assert res.stage1_seconds < res.stage2_seconds * 10


def test_claim3_backbone_features_to_svm():
    from repro.launch.train_svm import class_conditioned_tokens, extract_features
    from repro.configs import get_config
    from repro.models import init_model
    cfg = get_config("qwen3-0.6b", reduced=True)
    params, _ = init_model(jax.random.PRNGKey(0), cfg)
    toks, y = class_conditioned_tokens(400, 4, 32, cfg.vocab_size, seed=5,
                                       mix=0.6)
    feats = extract_features(cfg, params, toks, batch=64)
    assert feats.shape == (400, cfg.d_model)
    d2 = ((feats[:128, None] - feats[None, :128]) ** 2).sum(-1)
    gamma = 1.0 / np.median(d2[d2 > 0])
    svm = LPDSVM(KernelParams("rbf", gamma=gamma), C=8.0, budget=128,
                 tol=1e-2)
    svm.fit(feats[:320], y[:320])
    err = svm.error(feats[320:], y[320:])
    assert err < 0.75 * 0.75  # clearly better than the 0.75 chance rate


def test_lm_training_learns():
    from repro.launch.train import train
    losses = train("tinyllama-1.1b", reduced=True, steps=60, batch=4,
                   seq=64, lr=2e-3, log_every=100)
    assert min(losses[-5:]) < losses[0] * 0.75


def test_serving_generates():
    from repro.launch.serve import serve
    out = serve("qwen3-0.6b", reduced=True, batch=2, prompt_len=8, gen=8)
    assert out.shape == (2, 8)
    assert out.min() >= 0
