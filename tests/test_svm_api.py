"""LPDSVM estimator + OVO + CV/grid search + baselines (system behaviour)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.baselines import ExactDualSVM, LLSVMStyle, PrimalSGDSVM
from repro.core import (KernelParams, LPDSVM, SolverConfig, cross_validate,
                        grid_search)
from repro.core.ovo import build_ovo_tasks, class_pairs, ovo_vote
from repro.data import make_checker, make_multiclass, train_test_split


def test_binary_accuracy(rng):
    x, y = make_checker(1500, cells=3, seed=1)
    xtr, ytr, xte, yte = train_test_split(x, y, 0.3, seed=2)
    svm = LPDSVM(KernelParams("rbf", gamma=8.0), C=16.0, budget=300, tol=1e-2)
    svm.fit(xtr, ytr)
    assert svm.error(xte, yte) < 0.12


def test_close_to_exact_solver(rng):
    x, y = make_checker(700, cells=2, seed=3)
    xtr, ytr, xte, yte = train_test_split(x, y, 0.3)
    kp = KernelParams("rbf", gamma=4.0)
    lpd = LPDSVM(kp, C=8.0, budget=350, tol=1e-2).fit(xtr, ytr)
    exact = ExactDualSVM(kp, C=8.0, tol=1e-2).fit(xtr, ytr)
    # paper Table 2: budget approximation costs only a little accuracy
    assert lpd.error(xte, yte) <= exact.error(xte, yte) + 0.04


def test_multiclass_ovo(rng):
    x, y = make_multiclass(1200, p=10, n_classes=5, seed=4)
    xtr, ytr, xte, yte = train_test_split(x, y, 0.3)
    svm = LPDSVM(KernelParams("rbf", gamma=0.05), C=8.0, budget=300, tol=1e-2)
    svm.fit(xtr, ytr)
    assert svm.stats.n_tasks == 10          # C(5,2)
    err = svm.error(xte, yte)
    assert err < 0.35                        # >> chance (0.8)


def test_ovo_task_construction():
    labels = np.array([0, 1, 2, 0, 1, 2, 0])
    tasks, pairs = build_ovo_tasks(labels, 3, C=1.5)
    assert pairs == class_pairs(3) == [(0, 1), (0, 2), (1, 2)]
    t01 = 0
    idx = np.asarray(tasks.idx[t01])
    c = np.asarray(tasks.c[t01])
    real = c > 0
    assert real.sum() == 5                   # 3 zeros + 2 ones
    assert set(labels[idx[real]]) == {0, 1}
    y = np.asarray(tasks.y[t01])[real]
    assert np.all(y[labels[idx[real]] == 0] == 1.0)


def test_ovo_vote_tie_break():
    # one sample, 3 classes, decisions crafted so votes are 1,1,1 -> class 0
    pairs = class_pairs(3)
    d = np.array([[+1.0, -1.0, +1.0]])      # 0 beats 1; 2 beats 0; 1 beats 2
    assert ovo_vote(d, pairs, 3)[0] == 0


def test_cross_validate_and_factor_reuse(rng):
    x, y = make_multiclass(600, p=8, n_classes=3, seed=5)
    err1, factor = cross_validate(x, y, KernelParams("rbf", gamma=0.1), C=4.0,
                                  budget=200, folds=3)
    err2, _ = cross_validate(x, y, KernelParams("rbf", gamma=0.1), C=8.0,
                             budget=200, folds=3, factor=factor)
    assert 0.0 <= err1 <= 1.0 and 0.0 <= err2 <= 1.0
    assert err1 < 0.6 and err2 < 0.6


def test_grid_search_warm_start_equivalence(rng):
    """Warm-started grid must find the same error surface as cold starts."""
    x, y = make_checker(600, cells=2, seed=6)
    kw = dict(gammas=[2.0, 8.0], Cs=[1.0, 8.0], budget=150, folds=3,
              config=SolverConfig(tol=1e-3, max_epochs=2000))
    g_warm = grid_search(x, y, warm_start=True, **kw)
    g_cold = grid_search(x, y, warm_start=False, **kw)
    assert np.abs(g_warm.errors - g_cold.errors).max() < 0.03
    assert g_warm.n_binary_solved == 2 * 2 * 3


def test_llsvm_baseline_no_convergence_check(rng):
    x, y = make_checker(800, cells=3, seed=7)
    kp = KernelParams("rbf", gamma=8.0)
    ll = LLSVMStyle(kp, C=16.0, budget=200, chunk_size=200).fit(x, y)
    lpd = LPDSVM(kp, C=16.0, budget=200, tol=1e-3).fit(x, y)
    # LPD (converged) must beat the single-pass fixed-epoch chunked scheme
    assert lpd.error(x, y) <= ll.error(x, y) + 1e-9


def test_primal_sgd_less_precise(rng):
    """Paper sec. 2: dual methods reach precise solutions, SGD is rough."""
    x, y = make_checker(800, cells=2, seed=8)
    kp = KernelParams("rbf", gamma=4.0)
    lpd = LPDSVM(kp, C=8.0, budget=200, tol=1e-3).fit(x, y)
    sgd = PrimalSGDSVM(kp, C=8.0, budget=200, steps=1500, seed=8)
    sgd.fit(x, y, factor=lpd.factor)
    from repro.core.dual_solver import primal_objective
    n = x.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    _, labels = np.unique(y, return_inverse=True)
    y_pm = jnp.asarray(np.where(labels == 0, 1.0, -1.0), jnp.float32)
    c = jnp.full((n,), 8.0, jnp.float32)
    p_dual, _, _ = primal_objective(lpd.factor.G, idx, y_pm, c, lpd.W_[0])
    p_sgd, _, _ = primal_objective(lpd.factor.G, idx, y_pm, c, sgd.w_)
    assert float(p_dual) <= float(p_sgd) + 1e-3 * abs(float(p_sgd))


def test_multiclass_rejected_by_llsvm():
    x, y = make_multiclass(200, n_classes=3)
    with pytest.raises(ValueError):
        LLSVMStyle(KernelParams("rbf", gamma=0.1)).fit(x, y)
