"""Polishing (core/polish.py): coarse-to-fine ladder vs cold solves.

Acceptance: the polished final level reaches the same KKT tolerance as a
cold `solve_batch` solve (w within tol-scaled bounds, duality gap no worse),
on the monolithic AND streamed stage-2 paths, under OVO multi-class; and
`grid_search(polish=True)` selects the same cell as the unpolished search.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (KernelParams, LPDSVM, SolverConfig, StreamConfig,
                        compute_factor, grid_search, make_schedule,
                        solve_batch, solve_polished)
from repro.core.dual_solver import duality_gap
from repro.core.ovo import build_ovo_tasks
from repro.core.polish import PolishSchedule
from repro.data import make_checker, make_multiclass, train_test_split

CFG = SolverConfig(tol=1e-3, max_epochs=4000)


def _ovo_problem(n=900, classes=3, budget=128, C=4.0, gamma=0.2, seed=3):
    x, y = make_multiclass(n, p=8, n_classes=classes, seed=seed)
    _, labels = np.unique(y, return_inverse=True)
    factor = compute_factor(jnp.asarray(x, jnp.float32),
                            KernelParams("rbf", gamma=gamma), budget)
    tasks, _ = build_ovo_tasks(labels, classes, C)
    return factor, tasks


def _gaps(G, tasks, alpha):
    return np.array([float(duality_gap(jnp.asarray(G), tasks.idx[t],
                                       tasks.y[t], tasks.c[t],
                                       jnp.asarray(alpha[t])))
                     for t in range(tasks.n_tasks)])


def _assert_matches_cold(factor, tasks, res, trace, cold):
    # (1) same KKT stopping criterion satisfied on the final level
    assert np.all(np.asarray(res.violation) < CFG.tol)
    assert np.all(np.asarray(res.epochs) < CFG.max_epochs)
    # (2) duality gap no worse than the cold solve's (tol-scaled slack for
    # float accumulation; both stopped at the same KKT tolerance)
    slack = CFG.tol * (1.0 + np.abs(np.asarray(cold.dual_obj)))
    gp = _gaps(factor.G, tasks, np.asarray(res.alpha))
    gc = _gaps(factor.G, tasks, np.asarray(cold.alpha))
    assert np.all(gp <= gc + slack), (gp, gc)
    # (3) w is unique at the optimum (primal strongly convex) -> tol-scaled
    # agreement between the two solutions
    wc, wp = np.asarray(cold.w), np.asarray(res.w)
    wscale = max(1.0, float(np.max(np.abs(wc))))
    assert np.max(np.abs(wc - wp)) <= 0.05 * wscale
    # (4) alpha feasible and prolongation hit every level
    a = np.asarray(res.alpha)
    c = np.asarray(tasks.c)
    assert a.min() >= 0.0 and np.all(a <= c + 1e-5)
    assert trace.levels[-1].fraction == 1.0


def test_polished_matches_cold_monolithic():
    factor, tasks = _ovo_problem()
    cold = solve_batch(factor.G, tasks, CFG)
    res, trace = solve_polished(factor, tasks, CFG, make_schedule(3),
                                return_trace=True)
    assert len(trace.levels) >= 2          # ladder actually ran coarse levels
    assert not any(lv.streamed for lv in trace.levels)
    _assert_matches_cold(factor, tasks, res, trace, cold)
    # the trace records per-level convergence evidence
    for lv in trace.levels:
        assert lv.epochs.shape == (tasks.n_tasks,)
        assert np.all(np.isfinite(lv.duality_gap))
        assert lv.row_visits > 0 and lv.n_rows > 0


def test_polished_matches_cold_streamed():
    factor, tasks = _ovo_problem(n=700, budget=96)
    sfac = dataclasses.replace(factor, G=np.asarray(factor.G), streamed=True)
    cold = solve_batch(factor.G, tasks, CFG)
    res, trace = solve_polished(
        sfac, tasks, CFG, make_schedule(3), stream=True,
        stream_config=StreamConfig(tile_rows=128), return_trace=True)
    # per-level routing: gathered coarse levels stay monolithic on device,
    # the full-data level streams host G row-blocks
    assert trace.final.streamed and trace.final.stream_stats is not None
    assert not any(lv.streamed for lv in trace.levels[:-1])
    _assert_matches_cold(factor, tasks, res, trace, cold)


def test_polish_levels_are_nested_and_annealed():
    factor, tasks = _ovo_problem(n=600)
    _, trace = solve_polished(factor, tasks, CFG, make_schedule(3),
                              return_trace=True)
    rows = [lv.n_rows for lv in trace.levels]
    tols = [lv.tol for lv in trace.levels]
    assert rows == sorted(rows) and rows[-1] == 600
    assert tols == sorted(tols, reverse=True)
    assert tols[-1] == pytest.approx(CFG.tol)


def test_polish_warm_start_composes():
    """C-grid composition: a warm start in tasks.alpha0 must seed the ladder
    (the final level then starts near the optimum and polishes quickly)."""
    factor, tasks = _ovo_problem()
    res1 = solve_polished(factor, tasks, CFG, make_schedule(3))
    warm = tasks._replace(alpha0=jnp.asarray(res1.alpha))
    res2, tr2 = solve_polished(factor, warm, CFG, make_schedule(3),
                               return_trace=True)
    # re-solving from the solution is a verification pass, not a re-solve
    assert int(np.asarray(tr2.final.epochs).max()) <= \
        int(np.asarray(res1.epochs).max())
    wscale = max(1.0, float(np.max(np.abs(np.asarray(res1.w)))))
    assert np.max(np.abs(np.asarray(res1.w) - np.asarray(res2.w))) \
        <= 0.05 * wscale


def test_schedule_validation():
    with pytest.raises(ValueError):
        PolishSchedule(fractions=(0.25, 0.5), tol_factors=(4.0, 1.0))
    with pytest.raises(ValueError):
        PolishSchedule(fractions=(0.5, 0.25, 1.0), tol_factors=(4, 2, 1))
    with pytest.raises(ValueError):
        PolishSchedule(fractions=(0.25, 1.0), tol_factors=(0.5, 1.0))
    with pytest.raises(ValueError):
        make_schedule(0)
    s = make_schedule(3, ratio=4.0)
    assert s.fractions == (1 / 16, 1 / 4, 1.0)
    assert s.tol_factors == (16.0, 4.0, 1.0)


def test_tiny_problem_degenerates_to_plain_solve():
    """min_rows flooring makes every coarse level equal the full set on a
    tiny problem -> redundant levels are dropped, single final level runs."""
    factor, tasks = _ovo_problem(n=60, budget=32)
    res, trace = solve_polished(factor, tasks, CFG, make_schedule(3),
                                return_trace=True)
    assert len(trace.levels) == 1
    assert trace.levels[0].fraction == 1.0
    assert np.all(np.asarray(res.violation) < CFG.tol)


def test_lpdsvm_polish_flag():
    x, y = make_multiclass(800, p=6, n_classes=3, seed=9)
    xtr, ytr, xte, yte = train_test_split(x, y, 0.3)
    kp = KernelParams("rbf", gamma=0.2)
    base = LPDSVM(kp, C=4.0, budget=128, tol=1e-3).fit(xtr, ytr)
    pol = LPDSVM(kp, C=4.0, budget=128, tol=1e-3, polish=True).fit(xtr, ytr)
    assert pol.stats.polished and pol.stats.polish_trace is not None
    assert len(pol.stats.polish_trace.levels) >= 2
    assert not base.stats.polished and base.stats.polish_trace is None
    # same model, to tolerance: predictions agree on (nearly) all points
    agree = float(np.mean(pol.predict(xte) == base.predict(xte)))
    assert agree > 0.98
    assert pol.error(xte, yte) <= base.error(xte, yte) + 0.03


def test_lpdsvm_polish_streamed_end_to_end():
    x, y = make_multiclass(600, p=6, n_classes=3, seed=10)
    tiny = StreamConfig(device_budget_bytes=256 << 10)
    svm = LPDSVM(KernelParams("rbf", gamma=0.2), C=2.0, budget=96, tol=1e-3,
                 stream_config=tiny, polish=True).fit(x, y)
    assert svm.stats.polished and svm.stats.stage2_streamed
    assert svm.stats.stage2_stats is not None    # final level's stream stats
    assert svm.error(x, y) < 0.2


def test_grid_search_polish_selects_same_cell():
    x, y = make_checker(800, cells=2, seed=5)
    kw = dict(gammas=[0.25, 4.0], Cs=[1.0, 8.0], budget=150, folds=3,
              config=SolverConfig(tol=1e-3, max_epochs=2000))
    base = grid_search(x, y, **kw)
    pol = grid_search(x, y, polish=True, **kw)
    assert (pol.best_gamma, pol.best_C) == (base.best_gamma, base.best_C)
    assert np.abs(pol.errors - base.errors).max() < 0.03
