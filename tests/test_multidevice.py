"""Multi-device behaviour via subprocesses (the parent process has already
locked jax to 1 CPU device; XLA_FLAGS must be set before jax import)."""
import os
import subprocess
import sys

import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def run_sub(code: str, n_dev: int = 8, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=timeout, env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_moe_a2a_and_replicated_match_local():
    run_sub(r"""
import dataclasses, jax, numpy as np, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.compat import AxisType, make_mesh, set_mesh
from repro.configs import get_config
from repro.models.common import activation
from repro.models.moe import init_moe, moe_ffn

mesh = make_mesh((2, 4), ("data", "model"), axis_types=(AxisType.Auto,)*2)
cfg = get_config("jamba-v0.1-52b", reduced=True)
cfg = dataclasses.replace(cfg, n_experts=8, top_k=2, moe_d_ff=64, d_model=32,
                          capacity_factor=8.0)
params, _ = init_moe(jax.random.PRNGKey(1), cfg, jnp.float32)
rng = np.random.default_rng(0)
x = jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)
act = activation(cfg.act)
out_local, aux_local = moe_ffn(params, cfg, x, act, strategy="local")
with set_mesh(mesh):
    out_a2a, aux_a2a = jax.jit(lambda p, x: moe_ffn(p, cfg, x, act, strategy="a2a"))(params, x)
    out_rep, aux_rep = jax.jit(lambda p, x: moe_ffn(p, cfg, x, act, strategy="replicated", token_spec=P(None, None)))(params, x)
np.testing.assert_allclose(np.asarray(out_a2a), np.asarray(out_local), rtol=2e-3, atol=2e-3)
np.testing.assert_allclose(np.asarray(out_rep), np.asarray(out_local), rtol=2e-3, atol=2e-3)
# a2a aux is the mean of per-shard load-balance losses (standard DP
# approximation of the global statistic); rep sees all tokens -> exact
assert 0.5 * float(aux_local) < float(aux_a2a) < 2.0 * float(aux_local)
assert abs(float(aux_rep - aux_local)) < 1e-3
print("MOE-OK")
""")


def test_sharded_train_step_matches_single_device():
    run_sub(r"""
import jax, numpy as np, jax.numpy as jnp
from repro.compat import AxisType, make_mesh, set_mesh
from repro.configs import get_config
from repro.launch.steps import make_train_step
from repro.models import init_model
from repro.optim import get_optimizer

cfg = get_config("tinyllama-1.1b", reduced=True)
params, _ = init_model(jax.random.PRNGKey(0), cfg)
opt = get_optimizer("adamw", lr=1e-3)
st = opt.init(params)
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 64)), jnp.int32),
         "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 64)), jnp.int32)}

# single device reference
step0 = jax.jit(make_train_step(cfg, opt, global_batch=8))
_, _, m0 = step0(params, st, batch)

mesh = make_mesh((2, 4), ("data", "model"), axis_types=(AxisType.Auto,)*2)
with set_mesh(mesh):
    step1 = jax.jit(make_train_step(cfg, opt, mesh, global_batch=8))
    _, _, m1 = step1(params, st, batch)
diff = abs(float(m0["loss"]) - float(m1["loss"]))
assert diff < 5e-2, (float(m0["loss"]), float(m1["loss"]))
print("TRAIN-OK", float(m0["loss"]), float(m1["loss"]))
""")


def test_task_farm_on_8_devices():
    run_sub(r"""
import jax, numpy as np, jax.numpy as jnp
from repro.compat import AxisType, make_mesh, set_mesh
from repro.core import KernelParams, SolverConfig, compute_factor
from repro.core.distributed import solve_tasks_sharded
from repro.core.dual_solver import solve_batch
from repro.core.ovo import build_ovo_tasks

mesh = make_mesh((4, 2), ("data", "model"), axis_types=(AxisType.Auto,)*2)
rng = np.random.default_rng(0)
x = rng.normal(size=(240, 4)).astype(np.float32)
y = (x[:, 0] > 0).astype(int) + 2 * (x[:, 1] > 0).astype(int)   # 4 classes
fac = compute_factor(jnp.asarray(x), KernelParams("rbf", gamma=0.5), 96)
tasks, _ = build_ovo_tasks(y, 4, C=2.0)   # 6 tasks over 8 devices (pads to 8)
cfg = SolverConfig(tol=1e-2, max_epochs=400)
local = solve_batch(fac.G, tasks, cfg)
sharded = solve_tasks_sharded(fac.G, tasks, cfg, mesh)
np.testing.assert_allclose(np.asarray(sharded.w), np.asarray(local.w), atol=1e-4)
print("FARM-OK")
""")
