"""Flash attention Pallas kernel vs oracle: shape/dtype/block sweeps."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import flash_attention
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.ref import flash_attention_ref


def _qkv(rng, BH, S, D, dtype=jnp.float32):
    mk = lambda: jnp.asarray(rng.normal(size=(BH, S, D)), dtype)
    return mk(), mk(), mk()


@pytest.mark.parametrize("BH,S,D,bq,bk", [
    (4, 128, 64, 32, 32),
    (2, 64, 32, 16, 32),
    (3, 96, 128, 32, 48),
    (1, 256, 64, 256, 64),     # single q block
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_allclose(rng, BH, S, D, bq, bk, causal):
    q, k, v = _qkv(rng, BH, S, D)
    got = flash_attention_pallas(q, k, v, causal=causal, bq=bq, bk=bk,
                                 interpret=True)
    want = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_bf16(rng):
    q, k, v = _qkv(rng, 2, 64, 64, jnp.bfloat16)
    got = flash_attention_pallas(q, k, v, causal=True, bq=32, bk=32,
                                 interpret=True)
    want = flash_attention_ref(q, k, v, causal=True)
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_flash_wrapper_pads_ragged_seq(rng):
    """(B, H, S, D) wrapper with S not divisible by the block size."""
    B, H, S, D = 2, 3, 80, 32
    q = jnp.asarray(rng.normal(size=(B, H, S, D)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, H, S, D)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, H, S, D)), jnp.float32)
    got = flash_attention(q, k, v, causal=True, bq=32, bk=32, interpret=True)
    want = flash_attention_ref(q.reshape(B * H, S, D), k.reshape(B * H, S, D),
                               v.reshape(B * H, S, D), causal=True)
    np.testing.assert_allclose(np.asarray(got).reshape(B * H, S, D),
                               np.asarray(want), rtol=2e-5, atol=2e-5)


def test_flash_matches_model_attention(rng):
    """The kernel and the model's two-level-chunked jnp path must agree."""
    from repro.models.attention import _flash as model_flash
    BH, S, D = 2, 64, 32
    q, k, v = _qkv(rng, BH, S, D)
    pos = jnp.arange(S)
    # model layout: (B, Sq, Hkv, G, hd) with Hkv=BH, G=1, B=1
    qm = q.transpose(1, 0, 2)[None, :, :, None, :]
    km = k.transpose(1, 0, 2)[None]
    vm = v.transpose(1, 0, 2)[None]
    out_model = model_flash(qm, km, vm, pos, pos, causal=True, window=0,
                            kv_chunk=16, q_chunk=16)
    out_kernel = flash_attention_pallas(q, k, v, causal=True, bq=32, bk=32,
                                        interpret=True)
    a = np.asarray(out_model)[0, :, :, 0].transpose(1, 0, 2)
    np.testing.assert_allclose(a, np.asarray(out_kernel), rtol=2e-4, atol=2e-4)
