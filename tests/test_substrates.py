"""Data pipeline, optimizers, checkpointing."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint
from repro.data import (TokenStream, make_blobs, make_checker,
                        make_two_spirals, synthetic_token_batches,
                        train_test_split)
from repro.optim import adafactor, adamw, cosine_schedule, get_optimizer, sgd


def test_split_disjoint(rng):
    x, y = make_blobs(100, p=3)
    xtr, ytr, xte, yte = train_test_split(x, y, 0.25, seed=1)
    assert len(xtr) == 75 and len(xte) == 25
    all_rows = np.concatenate([xtr, xte])
    assert np.unique(all_rows, axis=0).shape[0] == np.unique(x, axis=0).shape[0]


def test_checker_labels_follow_grid():
    x, y = make_checker(500, cells=2, noise=0.0)
    want = ((np.floor(x[:, 0]) + np.floor(x[:, 1])) % 2).astype(int)
    assert (y == want).mean() > 0.99


def test_spirals_balanced():
    x, y = make_two_spirals(400)
    assert abs(y.mean() - 0.5) < 0.01
    assert np.abs(x).max() < 2.0


def test_token_stream_deterministic():
    it1 = synthetic_token_batches(500, 2, 16, seed=3)
    it2 = synthetic_token_batches(500, 2, 16, seed=3)
    a, at = next(it1)
    b, bt = next(it2)
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(a[:, 1:], at[:, :-1])   # shifted targets
    assert a.max() < 500 and a.min() >= 0


def test_token_stream_has_motif_structure():
    ts = TokenStream(1000, seed=0, motif_prob=0.9)
    seq = ts.sample(np.random.default_rng(0), 4000)
    # high motif probability -> repeated (sliding) 8-grams appear
    from collections import Counter
    grams = Counter(tuple(seq[i:i + 8]) for i in range(3992))
    assert grams.most_common(1)[0][1] > 3


@pytest.mark.parametrize("make", [adamw, adafactor, sgd])
def test_optimizer_converges_quadratic(make):
    opt = make(lr=0.1)
    params = {"w": jnp.array([3.0, -2.0, 1.5])}
    st = opt.init(params)

    @jax.jit
    def step(p, s):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(p)
        return opt.update(g, s, p)

    for _ in range(150):
        params, st = step(params, st)
    assert float(jnp.abs(params["w"]).max()) < 0.15


def test_adafactor_state_is_factored():
    opt = adafactor(lr=0.01)
    params = {"w": jnp.ones((64, 32)), "b": jnp.ones((32,))}
    st = opt.init(params)
    vr, vc = st.inner["w"]
    assert vr.shape == (64,) and vc.shape == (32,)     # O(r+c), not O(rc)
    assert st.inner["b"].shape == (32,)


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, warmup=10, total=100)
    assert float(lr(jnp.int32(0))) == 0.0
    assert abs(float(lr(jnp.int32(10))) - 1.0) < 1e-6
    assert float(lr(jnp.int32(100))) < 1e-6
    assert float(lr(jnp.int32(55))) < float(lr(jnp.int32(20)))


def test_checkpoint_roundtrip_and_latest():
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.bfloat16),
            "nested": {"b": jnp.ones((4,), jnp.float32)},
            "list": [jnp.zeros((2,)), jnp.full((3,), 7.0)]}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 5, tree)
        save_checkpoint(d, 12, tree)
        assert latest_step(d) == 12
        back = load_checkpoint(d, 12, tree)
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_shape_mismatch_rejected():
    tree = {"a": jnp.ones((2, 3))}
    with tempfile.TemporaryDirectory() as d:
        save_checkpoint(d, 1, tree)
        with pytest.raises(ValueError):
            load_checkpoint(d, 1, {"a": jnp.ones((3, 2))})


def test_optimizer_unknown_name():
    with pytest.raises(ValueError):
        get_optimizer("adamax")
