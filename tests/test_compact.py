"""Bucket-compaction solver: equivalence with the jit path + traffic savings."""
import jax.numpy as jnp
import numpy as np

from repro.core.compact import solve_compact
from repro.core.dual_solver import SolverConfig, solve_one
from repro.core.kernel_fn import KernelParams
from repro.core.nystrom import compute_factor
from repro.kernels import ref as kref


def oracle_epoch(G, yv, cv, qv, a, u, w, *, full_pass, shrink_k):
    a2, u2, w2, v2 = kref.smo_epoch_ref(
        G, yv[:, None], cv[:, None], qv[:, None], a[:, None], u[:, None],
        w[None, :], full_pass=full_pass, shrink_k=shrink_k)
    return a2[:, 0], u2[:, 0], w2[0], v2[0, 0]


def _problem(rng, n=500):
    x = rng.normal(size=(n, 5)).astype(np.float32)
    y = np.where(x[:, 0] * x[:, 1] > 0, 1.0, -1.0).astype(np.float32)
    fac = compute_factor(jnp.asarray(x), KernelParams("rbf", gamma=0.8),
                         budget=160)
    return fac.G, jnp.asarray(y), jnp.full((n,), 4.0, jnp.float32)


def test_compact_matches_jit_path(rng):
    G, y, c = _problem(rng)
    cfg = SolverConfig(tol=1e-2, max_epochs=500)
    ref_res = solve_one(G, jnp.arange(G.shape[0], dtype=jnp.int32), y, c,
                        jnp.zeros_like(c), cfg)
    alpha, w, st = solve_compact(G, y, c, cfg, epoch_fn=oracle_epoch)
    dual = float(jnp.sum(alpha) - 0.5 * jnp.dot(w, w))
    assert abs(dual - float(ref_res.dual_obj)) < 1e-3 * abs(dual)
    assert st.final_violation < cfg.tol


def test_compaction_reduces_streamed_rows(rng):
    G, y, c = _problem(rng)
    cfg = SolverConfig(tol=1e-2, max_epochs=500)
    _, _, st_on = solve_compact(G, y, c, cfg, epoch_fn=oracle_epoch)
    cfg_off = SolverConfig(tol=1e-2, max_epochs=500, shrink=False)
    _, _, st_off = solve_compact(G, y, c, cfg_off, epoch_fn=oracle_epoch)
    # shrinking + compaction must stream fewer G rows overall
    assert st_on.rows_streamed < st_off.rows_streamed


def test_compact_with_pallas_epoch(rng):
    G, y, c = _problem(rng, n=300)
    cfg = SolverConfig(tol=1e-2, max_epochs=300)
    alpha, w, st = solve_compact(G, y, c, cfg)      # default: pallas interpret
    a2, w2, _ = solve_compact(G, y, c, cfg, epoch_fn=oracle_epoch)
    d1 = float(jnp.sum(alpha) - 0.5 * jnp.dot(w, w))
    d2 = float(jnp.sum(a2) - 0.5 * jnp.dot(w2, w2))
    assert abs(d1 - d2) < 1e-3 * abs(d2)
