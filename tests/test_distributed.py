"""Distribution layer on the host mesh: task farm, stage-1 shardings, MoE
strategies agree with the local path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compat import set_mesh
from repro.core import KernelParams, SolverConfig, compute_factor
from repro.core.distributed import (replicate, solve_tasks_sharded,
                                    stage1_gram_sharded)
from repro.core.dual_solver import TaskBatch, solve_batch
from repro.core.kernel_fn import gram
from repro.core.ovo import build_ovo_tasks
from repro.launch.mesh import make_host_mesh


@pytest.fixture(scope="module")
def mesh():
    return make_host_mesh()


def test_task_farm_matches_local(rng, mesh):
    x = rng.normal(size=(300, 5)).astype(np.float32)
    y = (x[:, 0] > 0).astype(int) + (x[:, 1] > 0).astype(int)
    fac = compute_factor(jnp.asarray(x), KernelParams("rbf", gamma=0.5), 128)
    tasks, _ = build_ovo_tasks(y, 3, C=4.0)
    cfg = SolverConfig(tol=1e-2, max_epochs=500)
    local = solve_batch(fac.G, tasks, cfg)
    sharded = solve_tasks_sharded(fac.G, tasks, cfg, mesh)
    np.testing.assert_allclose(np.asarray(sharded.w), np.asarray(local.w),
                               atol=1e-4)
    assert sharded.alpha.shape == local.alpha.shape


def test_task_farm_pads_to_device_multiple(rng, mesh):
    x = rng.normal(size=(100, 4)).astype(np.float32)
    y = (x[:, 0] > 0).astype(int)
    fac = compute_factor(jnp.asarray(x), KernelParams("rbf", gamma=0.5), 64)
    tasks, _ = build_ovo_tasks(y, 2, C=1.0)     # 1 task only
    res = solve_tasks_sharded(fac.G, tasks, SolverConfig(tol=1e-2), mesh)
    assert res.w.shape[0] == 1                  # padding stripped


def test_stage1_gram_sharded_matches_ref(rng, mesh):
    kp = KernelParams("rbf", gamma=0.3)
    x = jnp.asarray(rng.normal(size=(64, 16)), jnp.float32)
    z = jnp.asarray(rng.normal(size=(32, 16)), jnp.float32)
    dist = stage1_gram_sharded(mesh, kp)
    got = dist(x, z)
    np.testing.assert_allclose(np.asarray(got), np.asarray(gram(x, z, kp)),
                               atol=1e-4)


def test_moe_sharded_strategies_match_local(rng, mesh):
    """a2a and replicated EP must agree with the single-device path when the
    mesh divides the experts (same routing, same capacities)."""
    if mesh.shape["model"] < 2:
        pytest.skip("needs >= 2 model shards")
    import dataclasses
    from repro.configs import get_config
    from repro.models.common import activation
    from repro.models.moe import init_moe, moe_ffn

    cfg = get_config("jamba-v0.1-52b", reduced=True)
    cfg = dataclasses.replace(cfg, n_experts=4, top_k=2, moe_d_ff=64,
                              d_model=32, capacity_factor=8.0)  # no drops
    params, _ = init_moe(jax.random.PRNGKey(1), cfg, jnp.float32)
    T = 32
    x = jnp.asarray(rng.normal(size=(T, 32)), jnp.float32)
    act = activation(cfg.act)
    out_local, aux_local = moe_ffn(params, cfg, x, act, strategy="local")
    with set_mesh(mesh):
        out_a2a, aux_a2a = jax.jit(
            lambda p, x: moe_ffn(p, cfg, x, act, strategy="a2a"))(params, x)
        from jax.sharding import PartitionSpec as P
        out_rep, aux_rep = jax.jit(
            lambda p, x: moe_ffn(p, cfg, x, act, strategy="replicated",
                                 token_spec=P(None, None)))(params, x)
    np.testing.assert_allclose(np.asarray(out_a2a), np.asarray(out_local),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(out_rep), np.asarray(out_local),
                               rtol=2e-3, atol=2e-3)
    assert abs(float(aux_a2a - aux_local)) < 1e-3
