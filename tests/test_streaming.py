"""Out-of-core stage 1: the chunked pipeline must be invisible numerically.

Pins down (a) chunked == monolithic G for awkward shapes, (b) the memory
budget model routes `compute_factor` / `LPDSVM.fit` onto the chunked path,
(c) the Pallas gram kernel slots into the streaming loop, and (d) disjoint
chunk streams over several devices still produce the same factor.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (KernelParams, LPDSVM, StreamConfig, auto_chunk_rows,
                        compute_factor, compute_factor_streamed, should_stream,
                        stream_factor_rows)
from repro.core.streaming import chunk_bytes, monolithic_bytes, resident_bytes

KP = KernelParams("rbf", gamma=0.5)


def _data(n, p=9, seed=0):
    return np.random.default_rng(seed).normal(size=(n, p)).astype(np.float32)


@pytest.mark.parametrize("n,budget,chunk", [
    (256, 64, 64),      # divisible
    (257, 64, 64),      # one straggler row
    (300, 48, 77),      # nothing divides anything
    (100, 32, 512),     # single chunk covers everything
    (200, 200, 33),     # budget >= n: landmarks are all of x
])
def test_chunked_matches_monolithic(n, budget, chunk):
    x = _data(n)
    mono = compute_factor(x, KP, budget)
    cfg = StreamConfig(chunk_rows=chunk)
    stre = compute_factor(x, KP, budget, stream=True, stream_config=cfg)
    assert stre.streamed and not mono.streamed
    assert isinstance(stre.G, np.ndarray)          # host-resident buffer
    assert stre.effective_rank == mono.effective_rank
    np.testing.assert_allclose(stre.G, np.asarray(mono.G),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("prefetch", [1, 2, 4])
def test_prefetch_depth_does_not_change_results(prefetch):
    x = _data(310)
    fac = compute_factor(x, KP, 64)
    out = stream_factor_rows(x, fac.landmarks, fac.projector, KP,
                             chunk_rows=49, prefetch=prefetch)
    np.testing.assert_allclose(out, np.asarray(fac.G), rtol=1e-5, atol=1e-5)


def test_preallocated_out_buffer_is_filled_in_place():
    x = _data(128)
    fac = compute_factor(x, KP, 32)
    out = np.full((128, fac.projector.shape[1]), np.nan, np.float32)
    ret = stream_factor_rows(x, fac.landmarks, fac.projector, KP,
                             chunk_rows=50, out=out)
    assert ret is out and np.isfinite(out).all()


def test_pallas_gram_fn_streams():
    from repro.kernels.ops import gram as gram_pallas
    x = _data(140, p=5)
    mono = compute_factor(x, KP, 48)
    stre = compute_factor_streamed(x, KP, 48, gram_fn=gram_pallas,
                                   config=StreamConfig(chunk_rows=33))
    # Pallas pads/tiles differently from the jnp reference: fp32 tolerance.
    np.testing.assert_allclose(stre.G, np.asarray(mono.G),
                               rtol=2e-4, atol=2e-4)


# ----------------------------------------------------------- int8 wire chunks

def test_int8_chunks_quarter_bytes_and_stay_close():
    """`StreamConfig.stage1_dtype="int8"`: chunk H2D bytes quarter (scales
    included, exact byte model) and the factor stays within the codec's
    error bound of the f32 streamed factor."""
    from repro.core.quant import quant_bytes
    x = _data(700, p=9)
    cfg32 = StreamConfig(chunk_rows=128)
    cfg8 = StreamConfig(chunk_rows=128, stage1_dtype="int8")
    s32 = compute_factor_streamed(x, KP, 64, config=cfg32)
    s8 = compute_factor_streamed(x, KP, 64, config=cfg8)
    st32, st8 = s32.stage1_stats, s8.stage1_stats
    assert st32.wire_dtype == "f32" and st8.wire_dtype == "int8"
    assert st32.bytes_h2d == 700 * 9 * 4
    expected = sum(quant_bytes(min(128, 700 - s), 9, cfg8.quant_group_rows)
                   for s in range(0, 700, 128))
    assert st8.bytes_h2d == expected
    assert st8.bytes_scales > 0
    assert st32.bytes_h2d > 3 * st8.bytes_h2d          # >= 3x incl. scales
    # parity: the kernel epilogue contracts the quantisation noise; the
    # factor stays close to the exact streamed one
    assert np.abs(s8.G - s32.G).max() < 0.05
    assert np.abs(s8.G - s32.G).mean() < 0.005
    assert s8.effective_rank == s32.effective_rank


def test_int8_chunks_through_fit():
    """End-to-end: an LPDSVM fit with a quantised stage-1 wire classifies
    like the f32 fit (both stages streamed)."""
    x = _data(600, p=6, seed=1)
    y = (x[:, 0] * x[:, 1] > 0).astype(int)
    kp = KernelParams("rbf", gamma=1.0)
    plain = LPDSVM(kp, C=2.0, budget=96).fit(x, y)
    cfg = StreamConfig(device_budget_bytes=256 << 10, stage1_dtype="int8",
                       block_dtype="int8")
    svm = LPDSVM(kp, C=2.0, budget=96, stream_config=cfg).fit(x, y)
    assert svm.stats.stage1_streamed and svm.stats.stage2_streamed
    assert svm.stats.stage1_stats is not None
    assert svm.stats.stage1_stats.wire_dtype == "int8"
    assert svm.stats.stage2_stats.block_dtype == "int8"
    assert abs(svm.score(x, y) - plain.score(x, y)) <= 0.02


def test_int8_gram_q8_fn_injectable():
    """The Pallas fused-dequant kernel slots in as gram_q8_fn (interpret
    off-TPU), matching the jnp dequant oracle path."""
    from repro.core.streaming import stream_factor_blocks
    from repro.kernels import ops
    x = _data(140, p=5)
    fac = compute_factor(x, KP, 48)

    def pallas_q8(v, s, z, params, group):
        return ops.gram_q8(v, s, z, params, group=group, tn=32, tm=16, tp=8,
                           interpret=True)

    blocks = (x[s:s + 33] for s in range(0, 140, 33))
    out = stream_factor_blocks(
        blocks, 140, fac.landmarks, fac.projector, KP, wire_dtype="int8",
        gram_q8_fn=pallas_q8)
    oracle = stream_factor_rows(x, fac.landmarks, fac.projector, KP,
                                chunk_rows=33, wire_dtype="int8")
    np.testing.assert_allclose(out, oracle, rtol=2e-4, atol=2e-4)


# -------------------------------------------------------- stage-1 autotune

def test_stage1_autotune_plumbing(monkeypatch):
    """`tune_prefetch` is applied ONCE, after the first full pipeline
    window, and the tuned depth surfaces in the stats (ROADMAP stage-1
    overlap item)."""
    import repro.core.streaming as streaming
    calls = []

    def fake_tune(put, drain, prefetch, cap):
        calls.append((prefetch, cap))
        return 5

    monkeypatch.setattr(streaming, "tune_prefetch", fake_tune)
    x = _data(900)
    fac = compute_factor(x, KP, 32)
    from repro.core.streaming import Stage1StreamStats, stream_factor_rows
    st = Stage1StreamStats()
    out = stream_factor_rows(x, fac.landmarks, fac.projector, KP,
                             chunk_rows=64, prefetch=2,
                             autotune_prefetch=True, prefetch_cap=6,
                             stats=st)
    assert calls == [(2, 6)]
    assert st.prefetch_final == 5
    np.testing.assert_allclose(out, np.asarray(fac.G), rtol=1e-5, atol=1e-5)
    # disabled: depth untouched
    calls.clear()
    st2 = Stage1StreamStats()
    stream_factor_rows(x, fac.landmarks, fac.projector, KP,
                       chunk_rows=64, prefetch=3, stats=st2)
    assert not calls and st2.prefetch_final == 3


def test_stage1_autotune_routed_from_config():
    """`compute_factor_streamed` threads the config's autotune knobs through
    and records the chunk traffic on the factor."""
    x = _data(800)
    cfg = StreamConfig(chunk_rows=64, autotune_prefetch=True, prefetch_cap=4)
    fac = compute_factor_streamed(x, KP, 48, config=cfg)
    st = fac.stage1_stats
    assert st is not None and st.chunks == -(-800 // 64)
    assert st.rows == 800
    assert 2 <= st.prefetch_final <= 4     # tuned within [prefetch, cap]
    off = StreamConfig(chunk_rows=64, autotune_prefetch=False)
    st_off = compute_factor_streamed(x, KP, 48, config=off).stage1_stats
    assert st_off.prefetch_final == off.prefetch


# ------------------------------------------------------------- budget model

def test_memory_model_accounting():
    n, p, B = 10_000, 64, 512
    assert monolithic_bytes(n, p, B) == \
        (n * p + 2 * n * B) * 4 + resident_bytes(p, B)
    assert chunk_bytes(100, p, B) == 100 * (p + 2 * B) * 4
    # bigger budget -> bigger auto chunks, clamped to n
    small = auto_chunk_rows(n, p, B, StreamConfig(device_budget_bytes=8 << 20))
    large = auto_chunk_rows(n, p, B, StreamConfig(device_budget_bytes=1 << 30))
    assert small < large <= n
    # the chosen chunk respects the budget (above the min-chunk floor)
    cfg = StreamConfig(device_budget_bytes=64 << 20)
    r = auto_chunk_rows(n, p, B, cfg)
    if r > cfg.min_chunk_rows:
        assert cfg.prefetch * chunk_bytes(r, p, B) + resident_bytes(p, B) \
            <= cfg.device_budget_bytes


def test_should_stream_thresholds():
    cfg = StreamConfig(device_budget_bytes=1 << 20)
    assert should_stream(100_000, 32, 512, cfg)
    assert not should_stream(100, 8, 32, StreamConfig(device_budget_bytes=1 << 30))


def test_fit_routes_through_streaming_when_budget_forces_it():
    x = _data(600, p=6, seed=1)
    y = (x[:, 0] * x[:, 1] > 0).astype(int)
    kp = KernelParams("rbf", gamma=1.0)
    plain = LPDSVM(kp, C=2.0, budget=96).fit(x, y)
    assert not plain.stats.stage1_streamed
    # 256 KiB budget: monolithic (600 x 96) working set cannot fit
    tiny = StreamConfig(device_budget_bytes=256 << 10)
    routed = LPDSVM(kp, C=2.0, budget=96, stream_config=tiny).fit(x, y)
    assert routed.stats.stage1_streamed and routed.factor.streamed
    np.testing.assert_allclose(np.asarray(routed.W_), np.asarray(plain.W_),
                               rtol=1e-4, atol=1e-4)
    assert routed.score(x, y) == plain.score(x, y)


def test_fit_stays_monolithic_under_roomy_budget():
    x = _data(200, p=4, seed=2)
    y = (x[:, 0] > 0).astype(int)
    roomy = StreamConfig(device_budget_bytes=1 << 30)
    svm = LPDSVM(KernelParams("rbf", gamma=1.0), C=1.0, budget=64,
                 stream_config=roomy).fit(x, y)
    assert not svm.stats.stage1_streamed


# --------------------------------------------------------------- multi-device

def test_disjoint_chunk_streams_over_devices():
    """4 fake CPU devices, each owning a disjoint chunk stream (subprocess:
    XLA device-count flags must precede jax import)."""
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = src
    code = r"""
import jax, numpy as np
from repro.compat import make_mesh
from repro.core import KernelParams, compute_factor
from repro.core.distributed import compute_factor_streamed_mesh, stream_factor_over_mesh
from repro.core.streaming import StreamConfig

assert len(jax.devices()) == 4
kp = KernelParams("rbf", gamma=0.5)
x = np.random.default_rng(0).normal(size=(403, 7)).astype(np.float32)
mono = compute_factor(x, kp, 64)
mesh = make_mesh((2, 2), ("data", "model"))
out = stream_factor_over_mesh(mesh, x, mono.landmarks, mono.projector, kp,
                              chunk_rows=37)
np.testing.assert_allclose(out, np.asarray(mono.G), rtol=1e-5, atol=1e-5)
fac = compute_factor_streamed_mesh(mesh, x, kp, 64,
                                   stream_config=StreamConfig(chunk_rows=50))
assert fac.streamed
np.testing.assert_allclose(fac.G, np.asarray(mono.G), rtol=1e-5, atol=1e-5)
print("MESH-STREAM-OK")
"""
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=900, env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "MESH-STREAM-OK" in out.stdout
