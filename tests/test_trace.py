"""Observability substrate (core/trace.py).

Pins down (a) the Chrome-trace export schema (Perfetto-loadable JSON with
thread-name metadata, complete spans, instants, counters); (b) thread safety
under the real 2-device farm — spans arrive from the shared reader thread
AND every device worker thread; (c) the disabled-mode contract: a live but
UNINSTALLED tracer records zero events, and a traced solve is bit-identical
to an untraced one (tracing observes, never steers); (d) the derived-rate
properties (`h2d_gbps`, `overlap_efficiency`) shared by the stats
dataclasses and the benchmarks; (e) the timeline overlap-efficiency
computation on synthetic spans with known geometry.
"""
import io
import json
import threading

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (KernelParams, SolverConfig, StreamConfig,
                        compute_factor, solve_batch_streamed)
from repro.core.ovo import build_ovo_tasks
from repro.core.solver_stream import Stage2StreamStats
from repro.core.streaming import Stage1StreamStats
from repro.core.svm import LPDSVM
from repro.core import trace as T
from repro.core.trace import (NULL, NullTracer, ProgressPrinter, Tracer,
                              install, resolve, uninstall)
from repro.data import make_multiclass

from tests.test_stage2_mesh import run_sub


def _problem(n=240, classes=3, budget=48, C=2.0, seed=3):
    x, y = make_multiclass(n, p=5, n_classes=classes, seed=seed)
    _, labels = np.unique(y, return_inverse=True)
    fac = compute_factor(jnp.asarray(x, jnp.float32),
                         KernelParams("rbf", gamma=0.25), budget)
    tasks, _ = build_ovo_tasks(labels, classes, C)
    return np.asarray(fac.G), tasks


# ------------------------------------------------------------- recording

def test_record_span_instant_counter():
    tr = Tracer()
    t0 = tr.begin()
    dt = tr.end("h2d", "put", t0, bytes=1024)
    assert dt >= 0.0
    with tr.span("kernel", "sweep", rows=8) as sp:
        sp.set(extra=1)
    tr.instant("cache", "hit", bytes=64)
    tr.counter("queue_depth/dev0", 3)
    cats = tr.categories()
    assert cats == {"h2d": 1, "kernel": 1, "cache": 1, "counter": 1}
    evs = tr.events()
    ph = sorted(e[0] for e in evs)
    assert ph == ["C", "X", "X", "i"]
    kern = [e for e in evs if e[1] == "kernel"][0]
    assert kern[6] == {"rows": 8, "extra": 1}


def test_end_duration_feeds_stats_semantics():
    """`end` returns the same elapsed-seconds quantity a perf_counter pair
    would, so `put_seconds += tr.end(...)` preserves stats meanings."""
    tr = Tracer()
    t0 = tr.begin()
    dt = tr.end("h2d", "put", t0)
    ev = tr.events()[0]
    assert ev[4] == pytest.approx(dt)
    assert ev[3] == pytest.approx(t0)


def test_listener_sees_raw_tuples():
    tr = Tracer()
    seen = []
    tr.add_listener(seen.append)
    tr.instant("cache", "miss", bytes=7)
    assert len(seen) == 1
    assert seen[0][0] == "i" and seen[0][1] == "cache"


# ---------------------------------------------------------- export schema

def test_export_chrome_trace_schema(tmp_path):
    tr = Tracer()
    t0 = tr.begin()
    tr.end("h2d", "put", t0, bytes=int(np.int64(4096)))
    tr.instant("cache", "hit", bytes=np.int32(64))
    tr.counter("depth", np.float32(2.0))
    path = tmp_path / "t.json"
    tr.export(str(path))
    d = json.load(open(path))
    assert set(d) >= {"traceEvents"}
    evs = d["traceEvents"]
    meta = [e for e in evs if e["ph"] == "M"]
    assert meta and meta[0]["name"] == "thread_name"
    spans = [e for e in evs if e["ph"] == "X"]
    assert spans
    for e in spans:
        assert {"name", "cat", "ts", "dur", "pid", "tid"} <= set(e)
        assert e["ts"] >= 0.0
    inst = [e for e in evs if e["ph"] == "i"]
    assert inst and inst[0]["s"] == "t"
    # numpy attrs must have degraded to plain JSON numbers
    assert spans[0]["args"]["bytes"] == 4096
    ctr = [e for e in evs if e["ph"] == "C"][0]
    assert ctr["args"]["value"] == 2.0


def test_export_thread_rows(tmp_path):
    tr = Tracer()
    tr.instant("cache", "main")

    def worker():
        tr.instant("cache", "side")

    th = threading.Thread(target=worker, name="worker/devX")
    th.start()
    th.join()
    path = tmp_path / "t.json"
    tr.export(str(path))
    d = json.load(open(path))
    names = {e["args"]["name"] for e in d["traceEvents"] if e["ph"] == "M"}
    assert "worker/devX" in names
    tids = {e["tid"] for e in d["traceEvents"] if e["ph"] == "i"}
    assert len(tids) == 2


# ------------------------------------------------------------- aggregation

def _synthetic_span(tr, cat, name, t_abs, dur, tid_thread=None, **attrs):
    """Record a span with controlled geometry (optionally from a named
    thread so overlap sees distinct tids)."""
    if tid_thread is None:
        tr._record("X", cat, name, t_abs, dur, attrs)
        return
    th = threading.Thread(
        target=lambda: tr._record("X", cat, name, t_abs, dur, attrs),
        name=tid_thread)
    th.start()
    th.join()


def test_overlap_efficiency_geometry():
    """h2d [0,2) vs other-thread kernel [1,3): exactly half hidden."""
    tr = Tracer()
    _synthetic_span(tr, "h2d", "put", 0.0, 2.0)
    _synthetic_span(tr, "kernel", "sweep", 1.0, 2.0, tid_thread="w0")
    assert tr.overlap_efficiency() == pytest.approx(0.5)


def test_overlap_efficiency_same_thread_not_hidden():
    """Compute on the SAME thread cannot hide that thread's transfers."""
    tr = Tracer()
    _synthetic_span(tr, "h2d", "put", 0.0, 2.0)
    _synthetic_span(tr, "kernel", "sweep", 0.0, 2.0)
    assert tr.overlap_efficiency() == pytest.approx(0.0)


def test_overlap_efficiency_none_without_transfers():
    tr = Tracer()
    _synthetic_span(tr, "kernel", "sweep", 0.0, 1.0)
    assert tr.overlap_efficiency() is None


def test_merge_and_overlap_helpers():
    merged = T._merge_intervals([(3.0, 4.0), (0.0, 1.0), (0.5, 2.0)])
    assert merged == [(0.0, 2.0), (3.0, 4.0)]
    assert T._overlap_with(0.5, 3.5, merged) == pytest.approx(2.0)


def test_summary_reports_figures():
    tr = Tracer()
    _synthetic_span(tr, "h2d", "put", 0.0, 1.0, bytes=10**9)
    _synthetic_span(tr, "kernel", "sweep", 0.5, 1.5, tid_thread="w0",
                    rows=1000)
    s = tr.summary()
    assert "effective H2D" in s
    assert "rows/s" in s
    assert "overlap efficiency" in s


def test_progress_printer_line():
    buf = io.StringIO()
    pp = ProgressPrinter(stream=buf)
    tr = Tracer()
    tr.add_listener(pp)
    t0 = tr.begin()
    tr.end("epoch", "epoch_3", t0, epoch=3, kind="cheap", bytes=10**6,
           hit_bytes=3, miss_bytes=1, rows=100, active=42, viol=0.25)
    line = buf.getvalue()
    assert "epoch    3" in line and "[cheap]" in line
    assert "active=      42" in line and "hit=75.0%" in line
    # non-epoch events must not print
    tr.instant("cache", "hit")
    assert buf.getvalue() == line


# ------------------------------------------------------ disabled-mode no-op

def test_null_tracer_records_nothing_and_still_times():
    t0 = NULL.begin()
    dt = NULL.end("h2d", "put", t0, bytes=1)
    assert isinstance(dt, float) and dt >= 0.0
    with NULL.span("kernel", "sweep") as sp:
        sp.set(rows=1)
    NULL.instant("cache", "hit")
    NULL.counter("q", 1)
    assert not NULL.enabled


def test_resolve_precedence():
    assert resolve(None) is NULL
    tr = Tracer()
    install(tr)
    try:
        assert resolve(None) is tr
        other = Tracer()
        assert resolve(other) is other
    finally:
        uninstall()
    assert resolve(None) is NULL


def test_uninstalled_spy_records_zero_events():
    """A live tracer that is neither installed nor passed must see NOTHING
    from a full streamed solve — proof the default path is the no-op."""
    spy = Tracer()
    G, tasks = _problem()
    cfg = StreamConfig(tile_rows=64)
    solve_batch_streamed(jnp.asarray(G), tasks, SolverConfig(tol=1e-2),
                         stream_config=cfg)
    assert spy.n_events == 0


def test_traced_solve_bit_identical_to_untraced():
    """Tracing observes the pipeline; it must not steer it."""
    G, tasks = _problem()
    cfg0 = StreamConfig(tile_rows=64)
    res0, st0 = solve_batch_streamed(jnp.asarray(G), tasks,
                                     SolverConfig(tol=1e-2),
                                     stream_config=cfg0, return_stats=True)
    tr = Tracer()
    cfg1 = StreamConfig(tile_rows=64, trace=tr)
    res1, st1 = solve_batch_streamed(jnp.asarray(G), tasks,
                                     SolverConfig(tol=1e-2),
                                     stream_config=cfg1, return_stats=True)
    assert tr.n_events > 0
    assert np.array_equal(np.asarray(res0.alpha), np.asarray(res1.alpha))
    assert np.array_equal(np.asarray(res0.w), np.asarray(res1.w))
    assert np.array_equal(np.asarray(res0.epochs), np.asarray(res1.epochs))
    assert st0.bytes_h2d == st1.bytes_h2d
    assert st0.epoch_bytes == st1.epoch_bytes


# ----------------------------------------------------- derived-rate dedup

def test_stage1_stats_properties():
    st = Stage1StreamStats(bytes_h2d=2 * 10**9, put_seconds=1.0,
                           drain_seconds=1.0, seconds=4.0)
    assert st.h2d_gbps == pytest.approx(2.0)
    assert st.overlap_efficiency == pytest.approx(0.5)
    assert Stage1StreamStats().overlap_efficiency == 0.0


def test_stage2_stats_properties():
    st = Stage2StreamStats(bytes_put=3 * 10**9, put_seconds=2.0,
                           drain_seconds=1.0, seconds=10.0)
    assert st.h2d_gbps == pytest.approx(1.5)
    assert st.overlap_efficiency == pytest.approx(0.7)
    # fully busy clamps at 0, never negative
    st2 = Stage2StreamStats(put_seconds=9.0, drain_seconds=9.0, seconds=1.0)
    assert st2.overlap_efficiency == 0.0


# ------------------------------------------------------- pipeline coverage

def test_streamed_solve_emits_pipeline_spans():
    G, tasks = _problem()
    tr = Tracer()
    cfg = StreamConfig(tile_rows=64, trace=tr)
    _, st = solve_batch_streamed(jnp.asarray(G), tasks, SolverConfig(tol=1e-2),
                                 stream_config=cfg, return_stats=True)
    cats = tr.categories()
    for want in ("h2d", "kernel", "epoch"):
        assert cats.get(want, 0) > 0, cats
    # span durations ARE the stats: the h2d spans sum to put_seconds
    h2d = sum(e[4] for e in tr.events()
              if e[0] == "X" and e[1] == "h2d")
    assert h2d == pytest.approx(st.put_seconds, rel=1e-6)


def test_fit_trace_kwarg_records_both_stages():
    x, y = make_multiclass(200, p=5, n_classes=3, seed=1)
    tr = Tracer()
    svm = LPDSVM(KernelParams("rbf", gamma=0.25), C=2.0, budget=48,
                 stream=True, stream_config=StreamConfig(tile_rows=64,
                                                         chunk_rows=64))
    svm.fit(x, y, trace=tr)
    cats = tr.categories()
    assert cats.get("fit", 0) == 2          # stage1 + stage2 spans
    assert cats.get("read", 0) > 0          # stage-1 chunk staging
    assert cats.get("h2d", 0) > 0
    names = {e[2] for e in tr.events() if e[1] == "fit"}
    assert names == {"stage1", "stage2"}


def test_fit_trace_without_stream_config_covers_polish():
    """An explicit fit(trace=) with NO StreamConfig must still record both
    stage spans and the polish ladder levels (tracer threading must not
    depend on a stream config existing)."""
    x, y = make_multiclass(200, p=5, n_classes=3, seed=2)
    tr = Tracer()
    svm = LPDSVM(KernelParams("rbf", gamma=0.25), C=2.0, budget=48,
                 polish=True, polish_levels=2)
    svm.fit(x, y, trace=tr)
    fit_names = {e[2] for e in tr.events() if e[1] == "fit"}
    assert fit_names == {"stage1", "stage2"}
    levels = [e[2] for e in tr.events() if e[1] == "polish"]
    assert levels == [f"level_{i}" for i in range(len(levels))] and levels


# ------------------------------------------------- 2-device farm (subprocess)

FARM_CODE = r"""
import json
import numpy as np
import jax
import jax.numpy as jnp
from repro.core import (KernelParams, SolverConfig, StreamConfig,
                        compute_factor, solve_tasks_streamed)
from repro.core.ovo import build_ovo_tasks
from repro.core.trace import Tracer
from repro.data import make_multiclass

x, y = make_multiclass(300, p=5, n_classes=4, seed=7)
_, labels = np.unique(y, return_inverse=True)
fac = compute_factor(jnp.asarray(x, jnp.float32),
                     KernelParams("rbf", gamma=0.25), 48)
tasks, _ = build_ovo_tasks(labels, 4, 2.0)
tr = Tracer()
cfg = StreamConfig(tile_rows=64, trace=tr)
solve_tasks_streamed(np.asarray(fac.G), tasks, SolverConfig(tol=1e-2),
                     devices=jax.local_devices(), stream_config=cfg,
                     overlap=True)
tr.export("/tmp/_trace_farm_test.json")
d = json.load(open("/tmp/_trace_farm_test.json"))
evs = d["traceEvents"]
names = sorted({e["args"]["name"] for e in evs if e["ph"] == "M"})
span_tids = sorted({e["tid"] for e in evs if e["ph"] == "X"})
cats = sorted({e["cat"] for e in evs if e["ph"] == "X"})
print("NAMES:" + json.dumps(names))
print("TIDS:%d" % len(span_tids))
print("CATS:" + json.dumps(cats))
print("SUMMARY_OK:%d" % ("overlap" in tr.summary()))
"""


def test_farm_trace_covers_all_threads():
    """Under the real 2-device farm the trace must carry spans from the
    shared reader (main thread) AND every device worker thread, with the
    queue/backpressure category present — the lock survives concurrency."""
    out = run_sub(FARM_CODE, n_dev=2)
    lines = dict(ln.split(":", 1) for ln in out.strip().splitlines()
                 if ":" in ln)
    names = json.loads(lines["NAMES"])
    assert "worker/dev0" in names and "worker/dev1" in names
    assert int(lines["TIDS"]) >= 3
    cats = json.loads(lines["CATS"])
    for want in ("read", "h2d", "kernel", "queue", "epoch"):
        assert want in cats, cats
    assert lines["SUMMARY_OK"] == "1"
