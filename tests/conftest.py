import os
import sys

# Tests run on the real (single) CPU device — the 512-device override is for
# launch/dryrun.py ONLY (see the multi-pod dry-run instructions).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
