"""Paper Table 3: hyperparameter grid search + cross-validation speed-up.

Two measurements:

  1. The original Table-3 story — the full monolithic grid (gammas x Cs x
     folds x OVO pairs) vs solving each binary problem from scratch: the
     G-reuse + warm-start + task-parallel batching gains.

  2. The grid TASK FARM (`build_cv_grid_tasks` + streamed stage 2) vs the
     per-cell serial streamed loop, cold cells in both (concurrent farm
     mode, per-cell trajectories bit-identical to solo solves).  The
     headline is G H2D bytes: the farm trains every (C, fold, pair) cell of
     a gamma in ONE G stream, so its per-gamma stage-2 G bytes stay within
     ~1x of a SINGLE cell's pass set while the serial loop pays one pass
     set per C.  The ladder mode (ascending-C warm starts inside the
     engine via `chain_next`) is recorded too — honestly: its levels are
     sequential, so it buys epochs, not bytes.

The full record set is written to ``BENCH_cv_grid.json``.

    PYTHONPATH=src python -m benchmarks.run table3
    BENCH_SMOKE=1 PYTHONPATH=src python -m benchmarks.run table3   # fast
"""
from __future__ import annotations

import dataclasses
import json
import os
import time

import jax
import numpy as np

from benchmarks.common import emit, provenance
from repro.core import (KernelParams, LPDSVM, SolverConfig, StreamConfig,
                        build_cv_grid_tasks, compute_factor, grid_search,
                        kfold_masks, solve_batch_streamed)
from repro.core.cv import _cv_error, build_cv_tasks
from repro.data import make_multiclass

OUT_PATH = os.environ.get("BENCH_CV_GRID_JSON", "BENCH_cv_grid.json")
SMOKE = bool(os.environ.get("BENCH_SMOKE"))

if SMOKE:
    N, P, CLASSES, BUDGET = 480, 8, 3, 96
    GAMMAS, CS, FOLDS = [0.05, 0.15], [1.0, 8.0], 2
    TILE = 128
    CONFIG = SolverConfig(tol=1e-2, max_epochs=300)
else:
    N, P, CLASSES, BUDGET = 1500, 10, 4, 250
    GAMMAS, CS, FOLDS = [0.02, 0.06, 0.18], [1.0, 4.0, 16.0], 3
    TILE = 256
    CONFIG = SolverConfig(tol=1e-2, max_epochs=800)


def _monolithic_reference(x, y, records) -> None:
    """Original Table-3 rows: monolithic grid vs per-binary from scratch."""
    t0 = time.perf_counter()
    res = grid_search(x, y, GAMMAS, CS, budget=BUDGET, folds=FOLDS,
                      config=CONFIG)
    total = time.perf_counter() - t0
    n_binary = res.n_binary_solved
    per_binary = total / n_binary

    svm = LPDSVM(KernelParams("rbf", gamma=res.best_gamma), C=res.best_C,
                 budget=BUDGET, tol=1e-2)
    t0 = time.perf_counter()
    svm.fit(x, y)
    t_single = time.perf_counter() - t0
    per_binary_scratch = t_single / svm.stats.n_tasks
    speedup = per_binary_scratch / per_binary

    emit("table3/grid/total", total * 1e6,
         f"n_binary={n_binary};best_err={res.best_error:.4f}")
    emit("table3/grid/per_binary", per_binary * 1e6,
         f"speedup_vs_scratch=x{speedup:.2f}")
    emit("table3/grid/stage1_frac", res.stage1_seconds * 1e6,
         f"stage1_runs={len(GAMMAS)}")
    records.append({"mode": "monolithic_grid", "n": N, "folds": FOLDS,
                    "gammas": GAMMAS, "Cs": CS, "n_binary": n_binary,
                    "seconds": total, "per_binary_seconds": per_binary,
                    "speedup_vs_scratch": speedup,
                    "best_error": res.best_error})


def _farm_vs_serial(x, y, records) -> None:
    """Streamed grid farm vs per-cell serial streamed loop, per gamma."""
    _, labels = np.unique(np.asarray(y), return_inverse=True)
    n_classes = int(labels.max()) + 1
    val_masks = kfold_masks(len(labels), FOLDS, seed=0)
    scfg = StreamConfig(tile_rows=TILE)

    for gamma in GAMMAS:
        factor = compute_factor(x, KernelParams("rbf", gamma=float(gamma)),
                                BUDGET, key=jax.random.PRNGKey(0))
        G = np.asarray(factor.G)

        # serial: one cold streamed solve per C — one G pass set per cell
        cells = []
        t_serial = 0.0
        for C in CS:
            tasks, pairs = build_cv_tasks(labels, n_classes, C, val_masks)
            t0 = time.perf_counter()
            res, st = solve_batch_streamed(G, tasks, CONFIG,
                                           stream_config=scfg,
                                           return_stats=True)
            err = _cv_error(factor, labels, n_classes, res.w, val_masks)
            dt = time.perf_counter() - t0
            t_serial += dt
            cells.append({"C": C, "seconds": dt, "error": err,
                          "epochs": st.epochs, "bytes_g": st.bytes_g,
                          "bytes_h2d": st.bytes_h2d})

        # farm: EVERY (C, fold, pair) cell in one streamed TaskBatch —
        # concurrent mode (ladder=False), so each cell's trajectory is
        # bit-identical to its cold solo solve above
        gtasks, pairs, chain = build_cv_grid_tasks(labels, n_classes, CS,
                                                   val_masks, ladder=False)
        FP = FOLDS * len(pairs)
        t0 = time.perf_counter()
        fres, fst = solve_batch_streamed(G, gtasks, CONFIG,
                                         stream_config=scfg,
                                         chain_next=chain, return_stats=True)
        W = np.asarray(fres.w)
        ferrs = [_cv_error(factor, labels, n_classes,
                           W[ci * FP:(ci + 1) * FP], val_masks)
                 for ci in range(len(CS))]
        t_farm = time.perf_counter() - t0

        serrs = [c["error"] for c in cells]
        if ferrs != serrs:      # bit-equal by construction; surface loudly
            raise AssertionError(f"farm/serial divergence at gamma={gamma}: "
                                 f"{ferrs} vs {serrs}")
        serial_g = sum(c["bytes_g"] for c in cells)
        max_cell_g = max(c["bytes_g"] for c in cells)
        ratio = fst.bytes_g / max(max_cell_g, 1)
        n_binary = len(CS) * FP
        emit(f"cv_grid_farm_g{gamma}", t_farm * 1e6,
             f"{ratio:.2f}x G bytes vs max single cell "
             f"(serial grid {serial_g / max(max_cell_g, 1):.2f}x); "
             f"{t_serial / t_farm:.2f}x faster than serial")
        records.append({
            "mode": "farm", "gamma": gamma, "n": N, "rank": G.shape[1],
            "folds": FOLDS, "Cs": CS, "tile_rows": TILE, "ladder": False,
            "n_binary": n_binary, "seconds": t_farm,
            "per_binary_seconds": t_farm / n_binary,
            "speedup_vs_serial": t_serial / t_farm,
            "bytes_g": fst.bytes_g, "bytes_h2d": fst.bytes_h2d,
            "bytes_d2h": fst.bytes_d2h, "epochs": fst.epochs,
            "g_bytes_vs_max_cell": ratio, "errors": ferrs})
        records.append({
            "mode": "serial", "gamma": gamma, "n": N, "rank": G.shape[1],
            "folds": FOLDS, "Cs": CS, "tile_rows": TILE,
            "n_binary": n_binary, "seconds": t_serial,
            "per_binary_seconds": t_serial / n_binary,
            "bytes_g": serial_g,
            "bytes_h2d": sum(c["bytes_h2d"] for c in cells),
            "g_bytes_vs_max_cell": serial_g / max(max_cell_g, 1),
            "cells": cells, "errors": serrs})

        if SMOKE:
            continue
        # ladder mode: the paper's ascending-C warm start, run INSIDE the
        # engine via chain_next — buys epochs (each level starts near its
        # predecessor's optimum), not bytes (levels are sequential)
        ltasks, pairs, chain = build_cv_grid_tasks(labels, n_classes, CS,
                                                   val_masks, ladder=True)
        farm_cfg = dataclasses.replace(
            CONFIG, max_epochs=CONFIG.max_epochs * len(CS) + len(CS))
        t0 = time.perf_counter()
        lres, lst = solve_batch_streamed(G, ltasks, farm_cfg,
                                         stream_config=scfg,
                                         chain_next=chain, return_stats=True)
        Wl = np.asarray(lres.w)
        lerrs = [_cv_error(factor, labels, n_classes,
                           Wl[ci * FP:(ci + 1) * FP], val_masks)
                 for ci in range(len(CS))]
        t_ladder = time.perf_counter() - t0
        emit(f"cv_grid_ladder_g{gamma}", t_ladder * 1e6,
             f"{lst.epochs} ladder epochs vs {fst.epochs} concurrent; "
             f"{lst.bytes_g / max(max_cell_g, 1):.2f}x G bytes")
        records.append({
            "mode": "farm", "gamma": gamma, "n": N, "rank": G.shape[1],
            "folds": FOLDS, "Cs": CS, "tile_rows": TILE, "ladder": True,
            "n_binary": n_binary, "seconds": t_ladder,
            "per_binary_seconds": t_ladder / n_binary,
            "bytes_g": lst.bytes_g, "bytes_h2d": lst.bytes_h2d,
            "bytes_d2h": lst.bytes_d2h, "epochs": lst.epochs,
            "g_bytes_vs_max_cell": lst.bytes_g / max(max_cell_g, 1),
            "errors": lerrs})


def run() -> None:
    x, y = make_multiclass(N, p=P, n_classes=CLASSES, seed=5)
    records = []
    _monolithic_reference(x, y, records)
    _farm_vs_serial(x, y, records)
    payload = {"benchmark": "cv_grid",
               "backend": jax.default_backend(),
               "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
               "provenance": provenance(),
               "records": records}
    with open(OUT_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {OUT_PATH} ({len(records)} records)", flush=True)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
