"""Paper Table 3: hyperparameter grid search + cross-validation speed-up.

Measures the full grid (gammas x Cs x folds x OVO pairs) and derives the
time-per-binary-problem and the speed-up factor vs solving each binary
problem from scratch — the paper's G-reuse + warm-start + task-parallel
batching gains.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core import KernelParams, LPDSVM, SolverConfig, grid_search
from repro.data import make_multiclass


def run() -> None:
    x, y = make_multiclass(1500, p=10, n_classes=4, seed=5)
    gammas = [0.02, 0.06, 0.18]
    Cs = [1.0, 4.0, 16.0]
    folds = 3
    cfg = SolverConfig(tol=1e-2, max_epochs=800)

    t0 = time.perf_counter()
    res = grid_search(x, y, gammas, Cs, budget=250, folds=folds, config=cfg)
    total = time.perf_counter() - t0
    n_binary = res.n_binary_solved
    per_binary = total / n_binary

    # reference: a single full fit (one (gamma, C), all pairs) from scratch,
    # scaled to the same number of binary problems
    svm = LPDSVM(KernelParams("rbf", gamma=res.best_gamma), C=res.best_C,
                 budget=250, tol=1e-2)
    t0 = time.perf_counter()
    svm.fit(x, y)
    t_single = time.perf_counter() - t0
    per_binary_scratch = t_single / svm.stats.n_tasks
    speedup = per_binary_scratch / per_binary

    emit("table3/grid/total", total * 1e6,
         f"n_binary={n_binary};best_err={res.best_error:.4f}")
    emit("table3/grid/per_binary", per_binary * 1e6,
         f"speedup_vs_scratch=x{speedup:.2f}")
    emit("table3/grid/stage1_frac", res.stage1_seconds * 1e6,
         f"stage1_runs={len(gammas)}")


if __name__ == "__main__":
    run()
