"""Paper Figure 3: timing breakdown into preparation / G computation / SMO.

Stage 1a (landmark selection + K_mm + eigendecomposition), stage 1b (K_nm @
projector = the matrix G), stage 2 (linear SVM training), and prediction —
the paper's four bars, per dataset size, on the host device.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import KernelParams, SolverConfig, solve_one
from repro.core.kernel_fn import gram
from repro.core.nystrom import _eig_projector, select_landmarks
from repro.data import make_checker


def run() -> None:
    for n, budget in ((2000, 200), (8000, 400)):
        x_np, y_np = make_checker(n, cells=3, seed=11)
        x = jnp.asarray(x_np)
        y = jnp.asarray(np.where(y_np == 0, 1.0, -1.0).astype(np.float32))
        kp = KernelParams("rbf", gamma=8.0)

        t0 = time.perf_counter()
        lm = select_landmarks(x, budget, jax.random.PRNGKey(0))
        k_mm = gram(lm, lm, kp)
        projector, evals, rank = _eig_projector(k_mm, kp, 1e-6)
        projector.block_until_ready()
        t_prep = time.perf_counter() - t0

        t0 = time.perf_counter()
        G = (gram(x, lm, kp) @ projector)
        G.block_until_ready()
        t_g = time.perf_counter() - t0

        t0 = time.perf_counter()
        cfg = SolverConfig(tol=1e-2, max_epochs=1000)
        res = solve_one(G, jnp.arange(n, dtype=jnp.int32), y,
                        jnp.full((n,), 16.0, jnp.float32),
                        jnp.zeros((n,), jnp.float32), cfg)
        res.w.block_until_ready()
        t_smo = time.perf_counter() - t0

        t0 = time.perf_counter()
        pred = jnp.sign(gram(x, lm, kp) @ projector @ res.w)
        pred.block_until_ready()
        t_pred = time.perf_counter() - t0

        emit(f"fig3/n{n}/preparation", t_prep * 1e6, f"rank={int(rank)}")
        emit(f"fig3/n{n}/matrix_G", t_g * 1e6, f"G={n}x{int(rank)}")
        emit(f"fig3/n{n}/smo_training", t_smo * 1e6,
             f"epochs={int(res.epochs)}")
        emit(f"fig3/n{n}/prediction", t_pred * 1e6,
             f"train_acc={float(jnp.mean((pred > 0) == (y > 0))):.4f}")


if __name__ == "__main__":
    run()
