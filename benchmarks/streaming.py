"""Stage-1 scaling curve: chunked out-of-core pipeline vs monolithic path.

For each n the same (landmarks, projector) pair is timed through
  * the monolithic device-resident projection (one gram + one matmul), and
  * the chunked host-resident pipeline at several chunk sizes / prefetch
    depths (`core/streaming.py`),
reporting rows/second.  Besides the CSV rows every suite emits, the full
record set is written to ``BENCH_streaming.json`` so the BENCH trajectory
can track the stage-1 scaling curve across PRs.

    PYTHONPATH=src python -m benchmarks.run streaming
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, provenance, timeit
from repro.core import KernelParams, StreamConfig, auto_chunk_rows
from repro.core.kernel_fn import gram
from repro.core.nystrom import _eig_projector, select_landmarks
from repro.core.streaming import Stage1StreamStats, stream_factor_rows
from repro.data import make_checker

OUT_PATH = os.environ.get("BENCH_STREAMING_JSON", "BENCH_streaming.json")
SMOKE = bool(os.environ.get("BENCH_SMOKE"))

# (n, budget); BENCH_SMOKE=1 shrinks everything for the fast CI loop.
# BENCH_STREAMING_N pins a single row count (with optional
# BENCH_STREAMING_BUDGET) so the ROADMAP's n ~ 10^6 trajectory can be
# recorded on real accelerators without code edits.
SIZES = ((2_000, 128),) if SMOKE else ((2_000, 128), (8_000, 256), (20_000, 256))
_N = int(os.environ.get("BENCH_STREAMING_N", "0"))
if _N:
    SIZES = ((_N, int(os.environ.get("BENCH_STREAMING_BUDGET", "256"))),)
CHUNKS = (512,) if SMOKE else (1_024, 4_096)
PREFETCH = (2,) if SMOKE else (1, 2)
# Wire dtype axis (the int8 rows ride in the smoke set too, so CI exercises
# the quantised chunk path on every run).
DTYPES = ("f32", "int8")


def _stage1_inputs(n: int, budget: int, gamma: float = 8.0):
    x_np, _ = make_checker(n, cells=3, seed=11)
    kp = KernelParams("rbf", gamma=gamma)
    lm = select_landmarks(jnp.asarray(x_np), budget, jax.random.PRNGKey(0))
    projector, _, _ = _eig_projector(gram(lm, lm, kp), kp, 1e-6)
    return x_np, lm, projector, kp


def run() -> None:
    records = []
    for n, budget in SIZES:
        x_np, lm, projector, kp = _stage1_inputs(n, budget)
        x_dev = jnp.asarray(x_np)

        def mono():
            (gram(x_dev, lm, kp) @ projector).block_until_ready()

        t = timeit(mono)
        emit(f"stage1_mono_n{n}_B{budget}", t * 1e6, f"{n / t:.0f} rows/s")
        records.append({"mode": "monolithic", "n": n, "budget": budget,
                        "chunk_rows": n, "prefetch": 1, "dtype": "f32",
                        "seconds": t, "rows_per_s": n / t})

        for chunk in CHUNKS:
            if chunk >= n:
                continue
            for pf in PREFETCH:
                wire0 = None                   # f32 chunk wire bytes
                for dtype in DTYPES:
                    out = np.empty((n, projector.shape[1]), np.float32)
                    holder = {}

                    def chunked():
                        st = Stage1StreamStats()
                        stream_factor_rows(x_np, lm, projector, kp,
                                           chunk_rows=chunk, prefetch=pf,
                                           out=out, wire_dtype=dtype,
                                           stats=st)
                        holder["st"] = st

                    t = timeit(chunked)
                    st = holder["st"]
                    gbps = st.h2d_gbps
                    emit(f"stage1_stream_n{n}_B{budget}_c{chunk}_pf{pf}"
                         f"_{dtype}", t * 1e6,
                         f"{n / t:.0f} rows/s "
                         f"{st.bytes_h2d / 2**20:.2f}MiB h2d {gbps:.2f}GB/s")
                    records.append({"mode": "streamed", "n": n,
                                    "budget": budget, "chunk_rows": chunk,
                                    "prefetch": pf, "dtype": dtype,
                                    "seconds": t, "rows_per_s": n / t,
                                    "bytes_h2d": st.bytes_h2d,
                                    "bytes_scales": st.bytes_scales,
                                    "h2d_gbps": gbps,
                                    "overlap_efficiency":
                                        st.overlap_efficiency})
                    if dtype == "f32":
                        wire0 = st.bytes_h2d
                    elif wire0 is not None:
                        emit(f"stage1_wire_bytes_n{n}_c{chunk}_pf{pf}"
                             f"_{dtype}", 0.0,
                             f"{wire0 / max(st.bytes_h2d, 1):.2f}x chunk "
                             f"byte reduction vs f32")

        # what the auto-router would pick at the default 2 GiB budget
        auto = auto_chunk_rows(n, x_np.shape[1], budget, StreamConfig())
        records.append({"mode": "auto_chunk", "n": n, "budget": budget,
                        "chunk_rows": auto, "prefetch": StreamConfig().prefetch,
                        "seconds": None, "rows_per_s": None})

    payload = {"benchmark": "stage1_streaming",
               "backend": jax.default_backend(),
               "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
               "provenance": provenance(),
               "records": records}
    with open(OUT_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {OUT_PATH} ({len(records)} records)", flush=True)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
