"""Paper sec. 4 eigenvalue-dropping ablation.

"As soon as the eigenvalues fall below a threshold close to the machine
precision times the largest eigenvalue, the subspaces are subject to strong
numerical noise while contributing only minimally" — sweep the drop
threshold and report effective rank + test error + stage-2 time.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import KernelParams, LPDSVM
from repro.core.nystrom import compute_factor
from repro.data import make_checker, train_test_split


def run() -> None:
    x, y = make_checker(2500, cells=3, seed=13)
    xtr, ytr, xte, yte = train_test_split(x, y, 0.3)
    kp = KernelParams("rbf", gamma=32.0)   # sharp kernel -> skewed spectrum
    for rtol in (0.0, 1e-10, 1e-6, 1e-3, 1e-1):
        t0 = time.perf_counter()
        factor = compute_factor(jnp.asarray(xtr, jnp.float32), kp, 500,
                                eig_rtol=rtol)
        svm = LPDSVM(kp, C=16.0, budget=500, tol=1e-2)
        svm.fit(xtr, ytr, factor=factor)
        dt = time.perf_counter() - t0
        err = svm.error(xte, yte)
        emit(f"eigdrop/rtol{rtol:g}", dt * 1e6,
             f"rank={factor.effective_rank};err={err:.4f}")


if __name__ == "__main__":
    run()
