"""Stage-2 task-farm scaling: serial per-device streams vs the overlapped
shared-reader farm (`core/distributed.py::solve_tasks_streamed`).

For each device count D the same (G, TaskBatch) pair is solved by
  * the legacy SERIAL farm (each device's block stream driven to completion
    in turn — G re-read once per device, wall-clock ~ sum of shards), and
  * the OVERLAPPED farm (one shared host reader stages each (tile, B) block
    once per pass and fans it out to per-device worker queues),
recording wall-clock and the mesh-level H2D bytes of the first full pass —
the number that must NOT scale with D for the overlapped farm (the paper's
"parallelism + more RAM" leg: many cores feeding multiple devices out of one
large-RAM host copy of G).  Device counts beyond the container's real
hardware come from `--xla_force_host_platform_device_count`, which must be
set before jax imports, so each D runs in a fresh subprocess (worker mode).

    PYTHONPATH=src python -m benchmarks.run stage2_mesh
    BENCH_SMOKE=1 PYTHONPATH=src python -m benchmarks.run stage2_mesh  # fast
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

OUT_PATH = os.environ.get("BENCH_STAGE2_MESH_JSON", "BENCH_stage2_mesh.json")
SMOKE = bool(os.environ.get("BENCH_SMOKE"))

# Virtual host devices beyond the PHYSICAL core count measure thread
# oversubscription, not the farm (the real target is D actual accelerators
# fed by many host cores), so device counts are capped at cpu_count.
_CORES = os.cpu_count() or 1
DEVICE_COUNTS = tuple(d for d in ((1, 2) if SMOKE else (1, 2, 4))
                      if d <= max(_CORES, 1)) or (1,)
# (n, budget, classes, max_epochs); blocks are kept fat (TILE) so per-call
# XLA compute — which releases the GIL and genuinely parallelises across
# device worker threads — dominates the Python dispatch per block
PROBLEM = (2_400, 128, 4, 12) if SMOKE else (8_000, 192, 4, 25)
TILE = 1_024 if SMOKE else 2_048


def _worker(n_dev: int) -> None:
    """Runs inside the XLA_FLAGS=...device_count=D subprocess: solve the same
    problem through both farms and print one JSON record per mode."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from benchmarks.common import timeit
    from repro.core import (KernelParams, SolverConfig, StreamConfig,
                            compute_factor, solve_tasks_streamed)
    from repro.core.ovo import build_ovo_tasks
    from repro.data import make_multiclass

    n, budget, classes, max_epochs = PROBLEM
    x, y = make_multiclass(n, p=8, n_classes=classes, seed=7)
    _, labels = np.unique(y, return_inverse=True)
    fac = compute_factor(jnp.asarray(x, jnp.float32),
                         KernelParams("rbf", gamma=0.2), budget)
    G = np.asarray(fac.G)
    tasks, _ = build_ovo_tasks(labels, classes, 4.0)
    config = SolverConfig(tol=1e-2, max_epochs=max_epochs)
    scfg = StreamConfig(tile_rows=TILE)
    devices = jax.local_devices()
    assert len(devices) == n_dev, (len(devices), n_dev)

    records = []
    for mode, overlap in (("serial", False), ("overlapped", True)):
        holder = {}

        def solve():
            holder["st"] = solve_tasks_streamed(
                G, tasks, config, devices=devices, stream_config=scfg,
                overlap=overlap, return_stats=True)[1]

        # warmup compiles this mode's jits; the median of 5 timed solves
        # tames the scheduler noise of a small container (smoke: 1 run)
        t = timeit(solve, repeats=1 if SMOKE else 5)
        st = holder["st"]
        records.append({
            "mode": mode, "n_devices": n_dev, "n": n, "rank": G.shape[1],
            "n_tasks": tasks.n_tasks, "tile_rows": st.tile_rows,
            "seconds": t, "bytes_h2d": st.bytes_h2d,
            "bytes_put": st.bytes_put,
            "first_pass_bytes": st.epoch_bytes[0] if st.epoch_bytes else None,
            "epochs": st.epochs, "full_passes": st.full_passes,
            "prefetch_final": st.prefetch_final,
        })
    print("BENCH_JSON:" + json.dumps(records), flush=True)


def run() -> None:
    from benchmarks.common import emit, provenance

    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    records = []
    for n_dev in DEVICE_COUNTS:
        env = dict(os.environ)
        # Single-threaded eigen pins ONE compute thread per virtual device:
        # device parallelism then comes only from the farm itself, not from
        # the intra-op pool racing the scheduler (which swamps the
        # measurement with 2x run-to-run noise on small containers).
        env["XLA_FLAGS"] = ("--xla_cpu_multi_thread_eigen=false "
                            f"--xla_force_host_platform_device_count={n_dev}")
        env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
        out = subprocess.run(
            [sys.executable, "-m", "benchmarks.stage2_mesh", "--worker",
             str(n_dev)],
            capture_output=True, text=True, timeout=3600, env=env,
            cwd=os.path.join(os.path.dirname(__file__), ".."))
        if out.returncode != 0:
            raise RuntimeError(f"stage2_mesh worker D={n_dev} failed:\n"
                               f"{out.stderr[-3000:]}")
        payload = [ln for ln in out.stdout.splitlines()
                   if ln.startswith("BENCH_JSON:")][-1]
        recs = json.loads(payload[len("BENCH_JSON:"):])
        records.extend(recs)
        by_mode = {r["mode"]: r for r in recs}
        speedup = by_mode["serial"]["seconds"] / by_mode["overlapped"]["seconds"]
        for r in recs:
            emit(f"stage2_mesh_{r['mode']}_D{n_dev}", r["seconds"] * 1e6,
                 f"{r['first_pass_bytes'] / 2**20:.1f}MiB/pass h2d")
        emit(f"stage2_mesh_speedup_D{n_dev}", 0.0,
             f"{speedup:.2f}x overlapped vs serial")

    one_dev = [r for r in records
               if r["mode"] == "overlapped" and r["n_devices"] == 1]
    if one_dev:
        base = one_dev[0]["first_pass_bytes"]
        for r in records:
            if r["mode"] == "overlapped":
                emit(f"stage2_mesh_pass_bytes_D{r['n_devices']}", 0.0,
                     f"{r['first_pass_bytes'] / base:.2f}x the 1-device "
                     f"per-pass bytes")

    payload = {"benchmark": "stage2_mesh",
               "backend": "cpu",        # workers force host devices
               "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
               "provenance": provenance(),
               "problem": {"n": PROBLEM[0], "budget": PROBLEM[1],
                           "classes": PROBLEM[2], "max_epochs": PROBLEM[3],
                           "tile_rows": TILE},
               "records": records}
    with open(OUT_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {OUT_PATH} ({len(records)} records)", flush=True)


if __name__ == "__main__":
    if len(sys.argv) > 2 and sys.argv[1] == "--worker":
        _worker(int(sys.argv[2]))
    else:
        print("name,us_per_call,derived")
        run()
