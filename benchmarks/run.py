"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Roofline numbers come from the
dry-run artifacts (results/dryrun) via ``repro.analysis.roofline``, not from
wall-clock — this container is CPU-only and TPU v5e is the target.

    PYTHONPATH=src python -m benchmarks.run [table2 table3 shrinking fig3
                                             eigdrop kernels]
"""
import sys


def main() -> None:
    from benchmarks import (disk_stream, eigdrop, fig3_stages, kernel_micro,
                            polish, shrinking, stage2_mesh, stage2_stream,
                            streaming, table2_solvers, table3_cv_grid,
                            trace_smoke)
    suites = {
        "table2": table2_solvers.run,
        "table3": table3_cv_grid.run,
        "shrinking": shrinking.run,
        "fig3": fig3_stages.run,
        "eigdrop": eigdrop.run,
        "kernels": kernel_micro.run,
        "streaming": streaming.run,
        "stage2": stage2_stream.run,
        "stage2_mesh": stage2_mesh.run,
        "disk": disk_stream.run,
        "polish": polish.run,
        "trace_smoke": trace_smoke.run,
    }
    picked = sys.argv[1:] or list(suites)
    print("name,us_per_call,derived")
    for name in picked:
        suites[name]()


if __name__ == "__main__":
    main()
