"""Stage-2 scaling: streamed row-block SMO vs the monolithic jit solver.

For each problem size the same (G, TaskBatch) pair is solved by
  * the monolithic `solve_batch` (full G re-materialised on device), and
  * the chunked `solve_batch_streamed` at several tile sizes
    (`core/solver_stream.py`),
reporting coordinate visits/second and — the point of the exercise — the H2D
bytes streamed per epoch, which drop as shrinking compacts the active-row
union (the paper's "memory demand for the relevant sub-matrix of G reduces",
turned into bandwidth savings).  Each streamed configuration runs twice, with
the hot-row HBM block cache on (the default) and off, so the record set shows
how many of those compacted-epoch bytes stop crossing the wire at all once
the active set is pinned device-side.  The full record set is written to
``BENCH_stage2_stream.json`` for the BENCH trajectory.

    PYTHONPATH=src python -m benchmarks.run stage2
    BENCH_SMOKE=1 PYTHONPATH=src python -m benchmarks.run stage2   # fast
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, provenance, timeit
from repro.core import (KernelParams, SolverConfig, StreamConfig,
                        compute_factor, solve_batch, solve_batch_streamed)
from repro.core.ovo import build_ovo_tasks
from repro.data import make_multiclass

OUT_PATH = os.environ.get("BENCH_STAGE2_STREAM_JSON", "BENCH_stage2_stream.json")
SMOKE = bool(os.environ.get("BENCH_SMOKE"))

# (n, budget, classes); overridable for quick smoke runs
SIZES = (((600, 96, 3),) if SMOKE
         else ((2_000, 128, 3), (5_000, 192, 3)))
TILES = ((128,) if SMOKE else (512, 1_536))
# Wire dtype axis: int8 rides in the smoke set too, so CI exercises the
# quantised path on every run.
DTYPES = (("f32", "int8") if SMOKE else ("f32", "bf16", "int8"))
CONFIG = SolverConfig(tol=1e-2, max_epochs=200 if SMOKE else 400)


def _problem(n: int, budget: int, classes: int):
    x, y = make_multiclass(n, p=8, n_classes=classes, seed=7)
    _, labels = np.unique(y, return_inverse=True)
    fac = compute_factor(jnp.asarray(x, jnp.float32),
                         KernelParams("rbf", gamma=0.2), budget)
    tasks, _ = build_ovo_tasks(labels, classes, 4.0)
    return np.asarray(fac.G), tasks


def run() -> None:
    records = []
    for n, budget, classes in SIZES:
        G, tasks = _problem(n, budget, classes)
        rank = G.shape[1]

        def mono():
            solve_batch(jnp.asarray(G), tasks, CONFIG).w.block_until_ready()

        t = timeit(mono, repeats=1 if SMOKE else 3)
        res = solve_batch(jnp.asarray(G), tasks, CONFIG)
        visits = int(np.asarray(res.epochs).sum()) * n
        emit(f"stage2_mono_n{n}_B{rank}", t * 1e6, f"{visits / t:.0f} visits/s")
        records.append({"mode": "monolithic", "n": n, "rank": rank,
                        "n_tasks": tasks.n_tasks, "tile_rows": n,
                        "dtype": "f32",
                        "seconds": t, "visits_per_s": visits / t,
                        "bytes_h2d": G.nbytes, "epoch_bytes": None})

        for tile in TILES:
            if tile >= n:
                continue
            pass0 = None                       # f32 first-full-pass bytes
            for dtype in DTYPES:
                nocache_h2d = None
                for cached in (False, True):   # uncached first = the baseline
                    cfg = StreamConfig(tile_rows=tile, block_dtype=dtype,
                                       cache_blocks=cached)
                    holder = {}

                    def streamed():
                        holder["st"] = solve_batch_streamed(
                            G, tasks, CONFIG, stream_config=cfg,
                            return_stats=True)[1]

                    # warmup (jit compile) + ONE timed run whose stats we
                    # keep — a full solve is already minutes of dispatch at
                    # these sizes
                    t = timeit(streamed, repeats=1)
                    st = holder["st"]
                    # every kernel call sweeps one task's WINDOW of a
                    # block, so this matches the monolithic epochs.sum() * n
                    # visit count without the inert padding
                    visits = st.coord_visits
                    # effective host->device throughput: physical DMA bytes
                    # over the host time spent inside puts (the quantised
                    # wire's point: same rows, fewer bytes, higher effective
                    # rows/s) -- the shared Stage2StreamStats property
                    gbps = st.h2d_gbps
                    tag = "cached" if cached else "nocache"
                    emit(f"stage2_stream_n{n}_B{rank}_t{tile}_{dtype}_{tag}",
                         t * 1e6,
                         f"{visits / t:.0f} visits/s "
                         f"{st.bytes_h2d / 2**20:.1f}MiB h2d {gbps:.2f}GB/s")
                    records.append({"mode": "streamed", "n": n, "rank": rank,
                                    "n_tasks": tasks.n_tasks,
                                    "tile_rows": tile,
                                    "dtype": dtype, "cache": cached,
                                    "seconds": t, "visits_per_s": visits / t,
                                    "bytes_h2d": st.bytes_h2d,
                                    "bytes_scales": st.bytes_scales,
                                    "bytes_d2h": st.bytes_d2h,
                                    "bytes_hit": st.bytes_hit,
                                    "bytes_miss": st.bytes_miss,
                                    "cache_resident_bytes":
                                        st.cache_resident_bytes,
                                    "h2d_gbps": gbps,
                                    "overlap_efficiency":
                                        st.overlap_efficiency,
                                    "epochs": st.epochs,
                                    "full_passes": st.full_passes,
                                    "epoch_bytes": st.epoch_bytes,
                                    "epoch_hit_bytes": st.epoch_hit_bytes,
                                    "epoch_miss_bytes": st.epoch_miss_bytes,
                                    "active_history": st.active_history})
                    if not cached:
                        nocache_h2d = st.bytes_h2d
                        continue
                    # the cache's headline: compacted-epoch G bytes served
                    # from HBM instead of the wire, and the resulting total
                    # H2D drop vs the identical uncached solve
                    served = st.bytes_hit + st.bytes_miss
                    if served:
                        emit(f"stage2_cache_hits_n{n}_t{tile}_{dtype}", 0.0,
                             f"{st.bytes_hit / served:.1%} of compacted-"
                             f"epoch G bytes from HBM cache "
                             f"({st.cache_resident_bytes / 2**20:.1f}MiB "
                             f"resident)")
                    if nocache_h2d:
                        emit(f"stage2_cache_h2d_n{n}_t{tile}_{dtype}", 0.0,
                             f"{nocache_h2d / max(st.bytes_h2d, 1):.2f}x "
                             f"total H2D reduction vs uncached")
                    # shrinking must turn into bandwidth savings: compare
                    # the first (uncompacted) epoch's H2D bytes with the
                    # cheapest later epoch
                    if st.epoch_bytes:
                        first = st.epoch_bytes[0]
                        floor = min(st.epoch_bytes)
                        emit(f"stage2_shrink_bytes_n{n}_t{tile}_{dtype}",
                             0.0,
                             f"{first / max(floor, 1):.1f}x epoch-byte "
                             f"reduction")
                        if dtype == "f32":
                            pass0 = first
                        elif pass0 is not None:
                            emit(f"stage2_wire_bytes_n{n}_t{tile}_{dtype}",
                                 0.0,
                                 f"{pass0 / max(first, 1):.2f}x per-pass "
                                 f"byte reduction vs f32")

    payload = {"benchmark": "stage2_streaming",
               "backend": jax.default_backend(),
               "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
               "provenance": provenance(),
               "records": records}
    with open(OUT_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {OUT_PATH} ({len(records)} records)", flush=True)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
