"""Pallas kernel microbenchmarks: interpret-mode vs jnp-reference parity.

On the CPU container the Pallas kernels execute in interpret mode (Python),
so wall-time is NOT the TPU story; what this bench pins down is (a) numeric
parity at benchmark sizes and (b) the reference path's throughput, which the
CPU-side solver actually uses.  The derived column reports achieved GFLOP/s
of the jnp path and the kernels' VMEM working set per tile.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timeit
from repro.core.kernel_fn import KernelParams
from repro.kernels import ops, ref


def run() -> None:
    rng = np.random.default_rng(17)
    kp = KernelParams("rbf", gamma=0.1)
    for n, m, p in ((1024, 512, 512), (2048, 1024, 256)):
        x = jnp.asarray(rng.normal(size=(n, p)), jnp.float32)
        z = jnp.asarray(rng.normal(size=(m, p)), jnp.float32)
        want = ref.gram_ref(x, z, kp)
        dt = timeit(lambda: ref.gram_ref(x, z, kp).block_until_ready())
        gflops = 2 * n * m * p / dt / 1e9
        err = float(jnp.max(jnp.abs(ops.gram(x, z, kp) - want)))
        vmem_kb = (128 * 512 + 128 * 512 + 128 * 128) * 4 / 1024
        emit(f"kernel/gram/{n}x{m}x{p}", dt * 1e6,
             f"ref_gflops={gflops:.1f};pallas_err={err:.2e};"
             f"tile_vmem_kb={vmem_kb:.0f}")

    # SMO epoch: rows/second of the reference path + kernel parity
    n, B = 512, 256
    G = jnp.asarray(rng.normal(size=(n, B)) / np.sqrt(B), jnp.float32)
    y = jnp.asarray(rng.choice([-1.0, 1.0], size=n), jnp.float32)
    c = jnp.full((n,), 2.0, jnp.float32)
    q = jnp.sum(G ** 2, axis=1)
    alpha = jnp.zeros((n,), jnp.float32)
    unch = jnp.zeros((n,), jnp.int32)
    w = jnp.zeros((B,), jnp.float32)

    def ref_epoch():
        a2, u2, w2, v2 = ref.smo_epoch_ref(
            G, y[:, None], c[:, None], q[:, None], alpha[:, None],
            unch[:, None], w[None, :], full_pass=True)
        w2.block_until_ready()

    dt = timeit(ref_epoch)
    a_p, _, w_p, _ = ops.smo_epoch(G, y, c, q, alpha, unch, w, full_pass=True)
    a_r, _, w_r, _ = ref.smo_epoch_ref(
        G, y[:, None], c[:, None], q[:, None], alpha[:, None],
        unch[:, None], w[None, :], full_pass=True)
    err = float(jnp.max(jnp.abs(w_p - w_r[0])))
    emit(f"kernel/smo_epoch/{n}x{B}", dt * 1e6,
         f"rows_per_s={n / dt:,.0f};pallas_err={err:.2e};"
         f"w_scratch_kb={B * 4 / 1024:.1f}")


if __name__ == "__main__":
    run()
