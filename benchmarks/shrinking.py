"""Paper sec. 5 "Shrinking": SMO-phase time with shrinking on vs off.

The paper reports x220 (Adult) / x350 (Epsilon) on the second phase.  The
CPU container reproduces the *mechanism* at smaller scale: epochs-to-converge
and streamed-row counts with the bucket-compaction path, plus wall time of
the mask-based jit solver.  The speed-up grows with problem size and with
the fraction of non-support-vectors — checker with a large margin band makes
most points bounded SVs quickly.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import KernelParams, SolverConfig, compute_factor, solve_one
from repro.core.compact import solve_compact
from repro.data import make_blobs


def run() -> None:
    # sharp-kernel checker with a tight tolerance: convergence has a long
    # "polishing" phase where most variables sit at bounds — the regime where
    # the paper measures its x220/x350 (late-phase active set << n)
    from repro.data import make_checker
    x, y = make_checker(4000, cells=3, seed=9)
    y_pm = np.where(y == 0, 1.0, -1.0).astype(np.float32)
    fac = compute_factor(jnp.asarray(x), KernelParams("rbf", gamma=8.0),
                         budget=300)
    n = x.shape[0]
    c = jnp.full((n,), 32.0, jnp.float32)
    idx = jnp.arange(n, dtype=jnp.int32)

    for shrink in (True, False):
        cfg = SolverConfig(tol=1e-4, max_epochs=2000, shrink=shrink)
        t0 = time.perf_counter()
        res = solve_one(fac.G, idx, jnp.asarray(y_pm), c,
                        jnp.zeros((n,), jnp.float32), cfg)
        res.w.block_until_ready()
        dt = time.perf_counter() - t0
        emit(f"shrinking/jit_solver/{'on' if shrink else 'off'}", dt * 1e6,
             f"epochs={int(res.epochs)};dual={float(res.dual_obj):.2f}")

    # compaction path: the HBM-traffic (streamed rows) view of the same effect
    for shrink in (True, False):
        cfg = SolverConfig(tol=1e-4, max_epochs=2000, shrink=shrink)
        t0 = time.perf_counter()
        alpha, w, st = solve_compact(fac.G, jnp.asarray(y_pm), c, cfg)
        dt = time.perf_counter() - t0
        dense_rows = st.epochs * n
        emit(f"shrinking/compact/{'on' if shrink else 'off'}", dt * 1e6,
             f"rows_streamed={st.rows_streamed};dense_equiv={dense_rows};"
             f"traffic_saving=x{dense_rows / max(st.rows_streamed, 1):.2f}")


if __name__ == "__main__":
    run()
