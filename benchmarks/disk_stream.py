"""Disk tier: shard-store streaming vs text re-parse and RAM re-stream.

Three questions the durable shard store (`core/shards.py`) has to answer
with numbers:

  * parse-once: how much does ingesting LIBSVM text into checksummed binary
    shards cost up front, and how fast does every later epoch's pass get
    when it re-reads shards instead of re-parsing text?
  * wire cost: shard sweep throughput (payload GB/s) for the f32 store and
    the int8-quantised store (4x fewer payload bytes for the same rows),
    against an in-RAM re-stream of the same row blocks (the no-disk upper
    bound).
  * integrity tax: the same sweep with footer-digest verification on
    (the default) and off — the overhead column of the acceptance
    criteria.

Records land in ``BENCH_disk_stream.json`` for the BENCH trajectory.

    PYTHONPATH=src python -m benchmarks.run disk
    BENCH_SMOKE=1 PYTHONPATH=src python -m benchmarks.run disk   # fast
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import time

import jax
import numpy as np

from benchmarks.common import emit, provenance, timeit
from repro.core import ShardStore, ShardStoreStats, open_or_ingest
from repro.data import read_libsvm

OUT_PATH = os.environ.get("BENCH_DISK_STREAM_JSON", "BENCH_disk_stream.json")
SMOKE = bool(os.environ.get("BENCH_SMOKE"))

# (rows, features); shard_rows sized so each config spans several shards
SIZES = (((3_000, 48),) if SMOKE else ((20_000, 64), (60_000, 96)))
SHARD_ROWS = 512 if SMOKE else 4_096
DTYPES = ("f32", "int8")
SWEEPS = 2 if SMOKE else 3          # epochs amortising the one-time ingest


def _write_libsvm(path: str, x: np.ndarray, y: np.ndarray) -> None:
    with open(path, "w") as f:
        for row, lab in zip(x, y):
            feats = " ".join(f"{j + 1}:{v:.6g}"
                             for j, v in enumerate(row) if v)
            f.write(f"{int(lab)} {feats}\n")


def _sweep(store: ShardStore, chunk: int) -> int:
    """Full pass over the store in chunk-row blocks; returns payload bytes."""
    total = 0
    for lo in range(0, store.n, chunk):
        block = store.read_rows(lo, min(lo + chunk, store.n))
        total += block.nbytes
    return total


def run() -> None:
    records = []
    workdir = tempfile.mkdtemp(prefix="bench_disk_")
    try:
        for n, p in SIZES:
            rng = np.random.default_rng(3)
            x = rng.standard_normal((n, p)).astype(np.float32)
            y = rng.integers(0, 3, n)
            text = os.path.join(workdir, f"data_{n}.svm")
            _write_libsvm(text, x, y)
            text_bytes = os.path.getsize(text)
            chunk = SHARD_ROWS

            # -- the baseline every epoch pays without the disk tier --------
            t_parse = timeit(lambda: read_libsvm(text, n_features=p),
                             repeats=1 if SMOKE else 3)
            emit(f"disk_text_parse_n{n}", t_parse * 1e6,
                 f"{text_bytes / t_parse / 2**30:.2f}GB/s text")
            records.append({"mode": "text_parse", "n": n, "p": p,
                            "dtype": "f32", "seconds": t_parse,
                            "bytes": text_bytes,
                            "gbps": text_bytes / t_parse / 2**30})

            # -- the no-disk upper bound: re-stream host RAM ----------------
            def ram_sweep():
                for lo in range(0, n, chunk):
                    np.ascontiguousarray(x[lo:lo + chunk])

            t_ram = timeit(ram_sweep, repeats=1 if SMOKE else 3)
            emit(f"disk_ram_restream_n{n}", t_ram * 1e6,
                 f"{x.nbytes / t_ram / 2**30:.2f}GB/s RAM")
            records.append({"mode": "ram_restream", "n": n, "p": p,
                            "dtype": "f32", "seconds": t_ram,
                            "bytes": x.nbytes,
                            "gbps": x.nbytes / t_ram / 2**30})

            for dtype in DTYPES:
                d = os.path.join(workdir, f"store_{n}_{dtype}")
                stats = ShardStoreStats()
                t0 = time.perf_counter()
                store, _ = open_or_ingest(text, d, n_features=p,
                                          shard_rows=SHARD_ROWS, dtype=dtype,
                                          stats=stats)
                t_ingest = time.perf_counter() - t0
                payload = sum(int(s["nbytes"])
                              for s in store.manifest["shards"])
                emit(f"disk_ingest_n{n}_{dtype}", t_ingest * 1e6,
                     f"{store.n_shards} shards "
                     f"{payload / 2**20:.1f}MiB on disk")
                records.append({"mode": "ingest", "n": n, "p": p,
                                "dtype": dtype, "seconds": t_ingest,
                                "shards": store.n_shards,
                                "bytes": payload, "shard_rows": SHARD_ROWS})

                # verify on/off sweep: cache_shards=0 so every block is a
                # real read+decode, not an LRU hit
                t_by_verify = {}
                for verify in (True, False):
                    st = ShardStoreStats()
                    rd = ShardStore(d, verify=verify, cache_shards=0,
                                    stats=st)
                    t_sweep = timeit(lambda: _sweep(rd, chunk),
                                     repeats=1 if SMOKE else 3)
                    t_by_verify[verify] = t_sweep
                    disk_bytes = st.bytes_read / max(st.shards_read, 1) \
                        * rd.n_shards
                    tag = "verify" if verify else "noverify"
                    emit(f"disk_shard_sweep_n{n}_{dtype}_{tag}",
                         t_sweep * 1e6,
                         f"{disk_bytes / t_sweep / 2**30:.2f}GB/s disk "
                         f"{x.nbytes / t_sweep / 2**30:.2f}GB/s rows")
                    records.append({"mode": "shard_sweep", "n": n, "p": p,
                                    "dtype": dtype, "verify": verify,
                                    "seconds": t_sweep,
                                    "bytes": int(disk_bytes),
                                    "rows_gbps": x.nbytes / t_sweep / 2**30,
                                    "gbps": disk_bytes / t_sweep / 2**30})
                overhead = t_by_verify[True] / max(t_by_verify[False], 1e-12)
                emit(f"disk_verify_overhead_n{n}_{dtype}", 0.0,
                     f"{overhead:.3f}x sweep time with checksums on")
                records.append({"mode": "verify_overhead", "n": n, "p": p,
                                "dtype": dtype, "ratio": overhead})

                # parse-once amortisation over SWEEPS epochs
                rd = ShardStore(d, cache_shards=0)
                t_shard = timeit(lambda: _sweep(rd, chunk), repeats=1)
                once = t_ingest + SWEEPS * t_shard
                always = SWEEPS * t_parse
                emit(f"disk_parse_once_n{n}_{dtype}", 0.0,
                     f"{always / max(once, 1e-12):.2f}x faster over "
                     f"{SWEEPS} epochs vs re-parsing text")
                records.append({"mode": "parse_once", "n": n, "p": p,
                                "dtype": dtype, "epochs": SWEEPS,
                                "seconds_ingest_plus_sweeps": once,
                                "seconds_reparse": always,
                                "speedup": always / max(once, 1e-12)})
    finally:
        shutil.rmtree(workdir, ignore_errors=True)

    payload = {"benchmark": "disk_stream",
               "backend": jax.default_backend(),
               "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
               "provenance": provenance(),
               "records": records}
    with open(OUT_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {OUT_PATH} ({len(records)} records)", flush=True)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
