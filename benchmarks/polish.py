"""Polished vs cold stage-2 training: duality-gap-matched comparison.

For each problem the same (factor, TaskBatch) pair is solved by
  * the cold full-data `solve_batch` at the repo's default config (the
    paper's eta ~ 5% shrinking cadence, `full_pass_period = 20`),
  * the cold solver with per-epoch verification (`full_pass_period = 1`) —
    recorded so the ladder's cadence effect is not silently attributed to
    the warm starts, and
  * the coarse-to-fine polish ladder (`core/polish.py`, default schedule
    n/16 -> n/4 -> n with tolerance annealing),
reporting wall-clock, total coordinate row-visits, and the final duality
gap (all modes must reach the cold solve's gap — the comparison is
gap-matched, not just KKT-matched).  Data is near-separable multiclass
(the deep-features regime the paper's polishing targets); fine-structure
problems transfer coarse solutions poorly and break even — see
docs/architecture.md.  Full record set -> ``BENCH_polish.json``.

    PYTHONPATH=src python -m benchmarks.run polish
    BENCH_SMOKE=1 PYTHONPATH=src python -m benchmarks.run polish   # fast
"""
from __future__ import annotations

import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, provenance, timeit
from repro.core import (KernelParams, SolverConfig, StreamConfig,
                        compute_factor, make_schedule, solve_batch,
                        solve_batch_streamed, solve_polished)
from repro.core.dual_solver import duality_gap
from repro.core.ovo import build_ovo_tasks
from repro.data import make_multiclass

OUT_PATH = os.environ.get("BENCH_POLISH_JSON", "BENCH_polish.json")
SMOKE = bool(os.environ.get("BENCH_SMOKE"))

# (n, budget, classes); near-separable blobs (sep = 2): polishing's regime
SIZES = (((800, 96, 3),) if SMOKE
         else ((3_000, 192, 3), (6_000, 256, 3)))
CONFIG = SolverConfig(tol=1e-3, max_epochs=1000 if SMOKE else 4000)
REPEATS = 1 if SMOKE else 3


def _problem(n: int, budget: int, classes: int):
    x, y = make_multiclass(n, p=8, n_classes=classes, sep=2.0, seed=7)
    _, labels = np.unique(y, return_inverse=True)
    factor = compute_factor(jnp.asarray(x, jnp.float32),
                            KernelParams("rbf", gamma=0.5), budget)
    tasks, _ = build_ovo_tasks(labels, classes, 8.0)
    return factor, tasks


def _max_gap(G, tasks, alpha) -> float:
    return max(float(duality_gap(jnp.asarray(G), tasks.idx[t], tasks.y[t],
                                 tasks.c[t], jnp.asarray(alpha)[t]))
               for t in range(tasks.n_tasks))


def run() -> None:
    records = []
    for n, budget, classes in SIZES:
        factor, tasks = _problem(n, budget, classes)
        G, n_pad = factor.G, tasks.idx.shape[1]
        rank = G.shape[1]

        cold_by_period = {}
        for period, mode in ((CONFIG.full_pass_period, "cold"),
                             (1, "cold_p1")):
            cfg = dataclasses.replace(CONFIG, full_pass_period=period)

            def cold():
                solve_batch(G, tasks, cfg).w.block_until_ready()

            t = timeit(cold, repeats=REPEATS)
            res = solve_batch(G, tasks, cfg)
            visits = int(np.asarray(res.epochs).sum()) * n_pad
            gap = _max_gap(G, tasks, res.alpha)
            cold_by_period[mode] = (visits, gap, t)
            emit(f"polish_{mode}_n{n}_B{rank}", t * 1e6,
                 f"{visits} visits gap {gap:.2e}")
            records.append({"mode": mode, "n": n, "rank": rank,
                            "n_tasks": tasks.n_tasks,
                            "full_pass_period": period, "seconds": t,
                            "row_visits": visits, "max_duality_gap": gap,
                            "epochs": int(np.asarray(res.epochs).sum())})

        sched = make_schedule(3)
        holder = {}

        def polished():
            holder["out"] = solve_polished(factor, tasks, CONFIG, sched,
                                           return_trace=True, gap_trace=False)
            np.asarray(holder["out"][0].w)

        t = timeit(polished, repeats=REPEATS)
        res, trace = holder["out"]
        gap = _max_gap(G, tasks, res.alpha)
        visits = trace.total_row_visits
        cold_v, cold_gap, cold_t = cold_by_period["cold"]
        # gap-matched: the target is the cold solve's gap, tol-scaled (both
        # runs stop at the same KKT tolerance; see tests/test_polish.py)
        target = cold_gap + CONFIG.tol * (
            1.0 + float(np.max(np.abs(np.asarray(res.dual_obj)))))
        emit(f"polish_ladder_n{n}_B{rank}", t * 1e6,
             f"{visits} visits gap {gap:.2e} "
             f"{cold_v / visits:.2f}x fewer visits {cold_t / t:.2f}x faster")
        records.append({
            "mode": "polished", "n": n, "rank": rank,
            "n_tasks": tasks.n_tasks, "seconds": t, "row_visits": visits,
            "max_duality_gap": gap, "gap_target": target,
            "reaches_target": bool(gap <= target),
            "visits_ratio_vs_cold": cold_v / visits,
            "speedup_vs_cold": cold_t / t,
            "levels": [{"fraction": lv.fraction, "tol": lv.tol,
                        "n_rows": lv.n_rows, "streamed": lv.streamed,
                        "epochs": int(lv.epochs.sum()),
                        "row_visits": lv.row_visits,
                        "seconds": lv.seconds}
                       for lv in trace.levels]})

        if not SMOKE and n == SIZES[-1][0]:
            # streamed pair: host-resident G, polish vs cold row-block solver
            G_host = np.asarray(G)
            sfac = dataclasses.replace(factor, G=G_host, streamed=True)
            scfg = StreamConfig(tile_rows=1_024)

            def cold_stream():
                solve_batch_streamed(G_host, tasks, CONFIG,
                                     stream_config=scfg)

            t_cs = timeit(cold_stream, repeats=1)
            _, st = solve_batch_streamed(G_host, tasks, CONFIG,
                                         stream_config=scfg,
                                         return_stats=True)

            def pol_stream():
                holder["out"] = solve_polished(
                    sfac, tasks, CONFIG, sched, stream=True,
                    stream_config=scfg, return_trace=True, gap_trace=False)

            t_ps = timeit(pol_stream, repeats=1)
            _, tr = holder["out"]
            fin = tr.final.stream_stats
            emit(f"polish_stream_n{n}_B{rank}", t_ps * 1e6,
                 f"{tr.total_row_visits} visits "
                 f"{fin.bytes_h2d / 2**20:.1f}MiB h2d "
                 f"(cold {st.coord_visits} visits "
                 f"{st.bytes_h2d / 2**20:.1f}MiB)")
            records.append({
                "mode": "streamed_pair", "n": n, "rank": rank,
                "cold_seconds": t_cs, "polished_seconds": t_ps,
                "cold_row_visits": st.coord_visits,
                "polished_row_visits": tr.total_row_visits,
                "cold_bytes_h2d": st.bytes_h2d,
                "polished_final_bytes_h2d": fin.bytes_h2d})

    payload = {"benchmark": "polish",
               "backend": jax.default_backend(),
               "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
               "provenance": provenance(),
               "config": {"tol": CONFIG.tol, "max_epochs": CONFIG.max_epochs,
                          "schedule": {"fractions": make_schedule(3).fractions,
                                       "tol_factors":
                                           make_schedule(3).tol_factors,
                                       "full_pass_period": 1}},
               "records": records}
    with open(OUT_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {OUT_PATH} ({len(records)} records)", flush=True)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
