"""Observability smoke: the tracer must see the whole pipeline and cost
nothing when disabled.

Three assertions, CI-fatal on regression:

  1. **Coverage** — one streamed multi-class fit under a `Tracer` exports
     Chrome-trace JSON that loads back with >= 1 span in every core
     category (read / h2d / kernel / drain / epoch): an instrumentation
     hole in a hot path fails here, not in a production trace.
  2. **No-op** — a live but uninstalled spy tracer records ZERO events
     across the same fit: the default path really is the `NULL` fast path.
  3. **Overhead** — the disabled `NULL.begin()`/`end()` pair stays within a
     small multiple of a bare `perf_counter` pair (it IS two perf_counter
     calls plus a subtract), so leaving instrumentation in hot loops is
     free in the shipped configuration.

Writes the validated trace to ``TRACE_SMOKE_JSON`` (default
``/tmp/trace_smoke.json``) so CI can upload it as an artifact.

    PYTHONPATH=src python -m benchmarks.run trace_smoke
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import emit

OUT_PATH = os.environ.get("TRACE_SMOKE_JSON", "/tmp/trace_smoke.json")
REQUIRED_CATEGORIES = ("read", "h2d", "kernel", "drain", "epoch")

# disabled begin/end vs bare perf_counter pair; generous bound — this guards
# against accidentally routing the NULL path through recording, not against
# scheduler noise
OVERHEAD_MULT = 25.0


def _traced_fit(trace):
    from repro.core import KernelParams, StreamConfig
    from repro.core.svm import LPDSVM
    from repro.data import make_multiclass

    x, y = make_multiclass(400, p=6, n_classes=3, seed=11)
    svm = LPDSVM(KernelParams("rbf", gamma=0.25), C=2.0, budget=64,
                 stream=True,
                 stream_config=StreamConfig(chunk_rows=128, tile_rows=128))
    svm.fit(x, y, trace=trace)
    return svm


def run() -> None:
    from repro.core.trace import NULL, Tracer

    # 1. coverage: every core category shows up in the exported JSON
    tr = Tracer()
    t0 = time.perf_counter()
    _traced_fit(tr)
    fit_s = time.perf_counter() - t0
    tr.export(OUT_PATH)
    d = json.load(open(OUT_PATH))
    spans = [e for e in d["traceEvents"] if e["ph"] == "X"]
    by_cat = {}
    for e in spans:
        by_cat[e["cat"]] = by_cat.get(e["cat"], 0) + 1
    missing = [c for c in REQUIRED_CATEGORIES if not by_cat.get(c)]
    assert not missing, f"trace missing categories {missing}: {by_cat}"
    summary = tr.summary()
    assert "overlap" in summary and "rows/s" in summary
    emit("trace_smoke_coverage", fit_s * 1e6,
         f"{len(spans)} spans over {len(by_cat)} categories -> {OUT_PATH}")

    # 2. no-op: an uninstalled tracer must never hear from the pipeline
    spy = Tracer()
    _traced_fit(None)
    assert spy.n_events == 0, \
        f"disabled-mode leak: spy recorded {spy.n_events} events"
    emit("trace_smoke_noop", 0.0, "uninstalled spy saw 0 events")

    # 3. overhead: NULL.begin/end vs a bare perf_counter pair
    reps = 20000

    def loop_null():
        t = 0.0
        for _ in range(reps):
            t0 = NULL.begin()
            t += NULL.end("h2d", "put", t0)
        return t

    def loop_bare():
        t = 0.0
        for _ in range(reps):
            t0 = time.perf_counter()
            t += time.perf_counter() - t0
        return t

    loop_null(), loop_bare()            # warm
    t0 = time.perf_counter(); loop_bare(); bare = time.perf_counter() - t0
    t0 = time.perf_counter(); loop_null(); null = time.perf_counter() - t0
    ratio = null / max(bare, 1e-12)
    assert ratio < OVERHEAD_MULT, \
        f"NULL begin/end {ratio:.1f}x a perf_counter pair (cap {OVERHEAD_MULT})"
    emit("trace_smoke_null_overhead", null / reps * 1e6,
         f"{ratio:.2f}x bare perf_counter pair")


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
