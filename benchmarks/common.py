"""Shared benchmark plumbing: CSV emission in `name,us_per_call,derived`
plus the provenance stamp every ``BENCH_*.json`` payload carries."""
from __future__ import annotations

import socket
import subprocess
import time
from typing import Callable


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def provenance() -> dict:
    """Environment stamp for BENCH records: a number without the machine,
    backend, and commit that produced it cannot anchor a trajectory."""
    import jax

    try:
        sha = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=10).stdout.strip() or None
    except (OSError, subprocess.SubprocessError):
        sha = None
    devs = jax.local_devices()
    return {
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "device_kind": devs[0].device_kind if devs else None,
        "device_count": len(devs),
        "hostname": socket.gethostname(),
        "git_sha": sha,
    }


def timeit(fn: Callable, *, repeats: int = 3, warmup: int = 1) -> float:
    """Median wall seconds of fn() (fn must block on device results)."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]
