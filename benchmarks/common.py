"""Shared benchmark plumbing: CSV emission in `name,us_per_call,derived`."""
from __future__ import annotations

import time
from typing import Callable


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def timeit(fn: Callable, *, repeats: int = 3, warmup: int = 1) -> float:
    """Median wall seconds of fn() (fn must block on device results)."""
    for _ in range(warmup):
        fn()
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]
