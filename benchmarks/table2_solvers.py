"""Paper Table 2: solver comparison (training/prediction time + error).

LPD-SVM vs the exact dense dual solver (ThunderSVM stand-in), the
LLSVM-style chunked solver, and primal SGD, on scaled-down synthetic
counterparts of the paper's data sets (binary: checker ~ SUSY/Epsilon;
multiclass: gaussian mixture ~ MNIST).  CPU-container sizes — the paper's
relative ordering (LPD ~ exact accuracy at a fraction of the time; LLSVM
fast but unconverged) is the reproduced claim.
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.baselines import ExactDualSVM, LLSVMStyle, PrimalSGDSVM
from repro.core import KernelParams, LPDSVM
from repro.data import make_checker, make_multiclass, train_test_split


def run() -> None:
    datasets = {
        "checker3k": (make_checker(3000, cells=3, seed=1),
                      KernelParams("rbf", gamma=8.0), 16.0, 400),
        "mc5x2k": (make_multiclass(2000, p=12, n_classes=5, seed=2),
                   KernelParams("rbf", gamma=0.06), 8.0, 300),
    }
    for dname, ((x, y), kp, C, budget) in datasets.items():
        xtr, ytr, xte, yte = train_test_split(x, y, 0.3, seed=3)
        solvers = {
            "lpd": LPDSVM(kp, C=C, budget=budget, tol=1e-2),
            "exact": ExactDualSVM(kp, C=C, tol=1e-2),
        }
        if len(np.unique(y)) == 2:
            solvers["llsvm"] = LLSVMStyle(kp, C=C, budget=budget,
                                          chunk_size=1000)
            solvers["sgd"] = PrimalSGDSVM(kp, C=C, budget=budget, steps=3000)
        for sname, solver in solvers.items():
            t0 = time.perf_counter()
            solver.fit(xtr, ytr)
            t_train = time.perf_counter() - t0
            t0 = time.perf_counter()
            err = solver.error(xte, yte)
            t_pred = time.perf_counter() - t0
            emit(f"table2/{dname}/{sname}/train", t_train * 1e6,
                 f"err={err:.4f}")
            emit(f"table2/{dname}/{sname}/predict", t_pred * 1e6,
                 f"n_test={len(yte)}")


if __name__ == "__main__":
    run()
